//! Fig. 4 reproduction: epochs-to-converge vs GPU count (global batch)
//! for Inception-V3, GNMT and BigLSTM, from the calibrated E(B) models.
//!
//! Anchor values from the paper's text: Inception 4 epochs → 7 beyond 32
//! GPUs → 23 at 256; GNMT slight dip at 4 GPUs, rapid growth past 64;
//! BigLSTM 3.2× more epochs at 32-way vs 16-way, divergence beyond 32.

use hybridpar::bench::Table;
use hybridpar::statistical::EpochModel;

fn main() {
    let nets: Vec<(EpochModel, usize)> = vec![
        (EpochModel::inception_v3(), 64),
        (EpochModel::gnmt(), 128),
        (EpochModel::biglstm(), 64),
    ];
    let gpu_counts = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];

    let mut table = Table::new(&["gpus", "inception-v3", "gnmt", "biglstm"]);
    for &g in &gpu_counts {
        let mut row = vec![g.to_string()];
        for (model, mb) in &nets {
            let b = (g * mb) as f64;
            row.push(match model.epochs(b) {
                Some(e) => format!("{e:.1}"),
                None => "diverged".into(),
            });
        }
        table.row(&row);
    }
    table.print("Fig. 4 — epochs to converge vs #GPUs (global batch = \
                 gpus × mini-batch)");

    // The paper's anchor assertions live in tier-1 now —
    // `fig4_epoch_anchors_hold` in tests/integration_training.rs — so
    // `cargo test` guards them on every run, not just bench invocations.
    println!("fig4_epochs OK (anchors enforced by integration_training)");
}
