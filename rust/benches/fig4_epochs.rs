//! Fig. 4 reproduction: epochs-to-converge vs GPU count (global batch)
//! for Inception-V3, GNMT and BigLSTM, from the calibrated E(B) models.
//!
//! Anchor values from the paper's text: Inception 4 epochs → 7 beyond 32
//! GPUs → 23 at 256; GNMT slight dip at 4 GPUs, rapid growth past 64;
//! BigLSTM 3.2× more epochs at 32-way vs 16-way, divergence beyond 32.

use hybridpar::bench::Table;
use hybridpar::statistical::EpochModel;

fn main() {
    let nets: Vec<(EpochModel, usize)> = vec![
        (EpochModel::inception_v3(), 64),
        (EpochModel::gnmt(), 128),
        (EpochModel::biglstm(), 64),
    ];
    let gpu_counts = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];

    let mut table = Table::new(&["gpus", "inception-v3", "gnmt", "biglstm"]);
    for &g in &gpu_counts {
        let mut row = vec![g.to_string()];
        for (model, mb) in &nets {
            let b = (g * mb) as f64;
            row.push(match model.epochs(b) {
                Some(e) => format!("{e:.1}"),
                None => "diverged".into(),
            });
        }
        table.row(&row);
    }
    table.print("Fig. 4 — epochs to converge vs #GPUs (global batch = \
                 gpus × mini-batch)");

    // Anchor assertions from the paper's text.
    let inc = EpochModel::inception_v3();
    assert_eq!(inc.epochs(32.0 * 64.0).unwrap().round() as i64, 4);
    assert_eq!(inc.epochs(64.0 * 64.0).unwrap().round() as i64, 7);
    assert_eq!(inc.epochs(256.0 * 64.0).unwrap().round() as i64, 23);

    let gn = EpochModel::gnmt();
    assert!(gn.epochs(4.0 * 128.0).unwrap() < gn.epochs(2.0 * 128.0).unwrap(),
            "GNMT dips slightly at 4 GPUs (tuned LR)");
    assert!(gn.epochs(256.0 * 128.0).unwrap()
            > 1.5 * gn.epochs(64.0 * 128.0).unwrap(),
            "GNMT grows rapidly past 64 GPUs");

    let bl = EpochModel::biglstm();
    let e16 = bl.epochs(16.0 * 64.0).unwrap();
    let e32 = bl.epochs(32.0 * 64.0).unwrap();
    assert!((e32 / e16 - 3.2).abs() < 0.05,
            "BigLSTM 32-way needs 3.2x epochs of 16-way (got {})",
            e32 / e16);
    assert!(bl.epochs(64.0 * 64.0).is_none(),
            "BigLSTM diverges beyond 32-way");
    println!("fig4_epochs OK (all paper anchors hold)");
}
