//! Fig. 3 reproduction: the paper's hypothetical scenario — DP-only vs
//! hybrid speedup curves with SU² = 1.45 and SU⁴ = 1.65 — with the device
//! grid evaluated in parallel on the sweep engine's [`parallel_map`].
//!
//! Expected shape (paper §3.4): DP-only scales well to 32 devices then
//! saturates; 32-way DP × 2-way MP beats 64-way DP; the 4-way-MP hybrid
//! underperforms the 2-way hybrid because SU⁴ does not pay for using 4
//! devices per worker.

use hybridpar::bench::{f2, Table};
use hybridpar::parallel::{NetworkModel, ScalingEfficiency};
use hybridpar::planner::sweep::parallel_map;
use hybridpar::statistical::EpochModel;

fn main() {
    let net = NetworkModel {
        name: "fig3-hypothetical".into(),
        epochs: EpochModel::fig3_example(),
        mini_batch: 1,
        se: ScalingEfficiency::Perfect,
        mp_speedups: vec![(2, 1.45), (4, 1.65)],
    };

    // The figure's device grid, one scenario per power of two, evaluated
    // across all cores.  parallel_map's deterministic ordering keeps the
    // table rows in grid order no matter the thread count.
    let counts: Vec<usize> =
        std::iter::successors(Some(1usize),
                              |&n| (n < 256).then_some(n * 2))
            .collect();
    let rows = parallel_map(0, &counts, |_, &n| {
        (n, net.su_dp(n), net.su_hybrid(n, 2), net.su_hybrid(n, 4))
    });

    let mut table = Table::new(&["devices", "DP-only", "hybrid M=2",
                                 "hybrid M=4"]);
    let cell = |v: Option<f64>| v.map(f2).unwrap_or_else(|| "-".into());
    for (n, dp, h2, h4) in &rows {
        table.row(&[n.to_string(), cell(*dp), cell(*h2), cell(*h4)]);
    }
    table.print("Fig. 3 — hypothetical DP vs hybrid speedup");

    // Paper-shape assertions.
    let dp64 = net.su_dp(64).unwrap();
    let hy64 = net.su_hybrid(64, 2).unwrap();
    assert!(hy64 > dp64, "hybrid must beat DP at 64 ({hy64} vs {dp64})");
    let hy128_2 = net.su_hybrid(128, 2).unwrap();
    let hy128_4 = net.su_hybrid(128, 4).unwrap();
    assert!(hy128_2 > hy128_4, "2-way hybrid must beat 4-way at 128");
    let x = net.crossover_point(2, 1024).unwrap();
    println!("\ncrossover (Eq. 6): {x} devices (paper narrative: between \
              32 and 64)");
    assert!(x == 64, "crossover expected at 64, got {x}");
    println!("fig3_hypothetical OK");
}
