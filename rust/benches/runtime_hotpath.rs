//! L3 hot-path micro-benchmarks (§Perf baseline): PJRT execution latency
//! per artifact, literal clone/flatten costs, and the end-to-end DP step
//! breakdown.  Skips (exit 0) when artifacts are absent.

use std::path::PathBuf;

use hybridpar::bench::{bench, Table};
use hybridpar::cluster;
use hybridpar::coordinator::{flatten_grads, unflatten_grads, Coordinator,
                             Strategy, TrainConfig};
use hybridpar::data::Corpus;
use hybridpar::runtime::Engine;
use hybridpar::util::fmt_secs;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.json").exists() {
        println!("runtime_hotpath: skipping (run `make artifacts`)");
        return;
    }
    let eng = Engine::load(&dir, &["grad_step", "train_step",
                                   "apply_update", "stage0_fwd"])
        .unwrap();
    let tm = eng.meta.transformer.clone();
    let n = tm.param_specs.len();
    let params = eng.meta.load_init_params(&tm).unwrap();
    let mut rng = hybridpar::util::rng::Rng::new(5);
    let tok: Vec<i32> = (0..tm.batch * tm.seq_len)
        .map(|_| rng.range(0, tm.vocab as i64 - 1) as i32)
        .collect();
    let tok_l = Engine::i32_tensor(&tok, &[tm.batch, tm.seq_len]).unwrap();
    let tgt_l = Engine::i32_tensor(&tok, &[tm.batch, tm.seq_len]).unwrap();

    // --- PJRT execution latencies ---------------------------------------
    let mut results = Vec::new();
    let m = bench("exec:grad_step", 5, 3.0, || {
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(|p| Engine::clone_literal(p).unwrap())
            .collect();
        inputs.push(Engine::clone_literal(&tok_l).unwrap());
        inputs.push(Engine::clone_literal(&tgt_l).unwrap());
        let outs = eng.exec("grad_step", &inputs).unwrap();
        std::hint::black_box(outs.len());
    });
    results.push(("grad_step (incl. clones)", m.mean_s));

    let m = bench("exec:train_step", 5, 3.0, || {
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(|p| Engine::clone_literal(p).unwrap())
            .collect();
        inputs.push(Engine::clone_literal(&tok_l).unwrap());
        inputs.push(Engine::clone_literal(&tgt_l).unwrap());
        inputs.push(Engine::f32_scalar(0.1));
        let outs = eng.exec("train_step", &inputs).unwrap();
        std::hint::black_box(outs.len());
    });
    results.push(("train_step (incl. clones)", m.mean_s));

    // --- host-side data movement costs ----------------------------------
    let m = bench("clone_params", 10, 1.0, || {
        let c: Vec<xla::Literal> = params
            .iter()
            .map(|p| Engine::clone_literal(p).unwrap())
            .collect();
        std::hint::black_box(c.len());
    });
    results.push(("clone all params", m.mean_s));

    let grads: Vec<xla::Literal> = params
        .iter()
        .map(|p| Engine::clone_literal(p).unwrap())
        .collect();
    let m = bench("flatten+unflatten", 10, 1.0, || {
        let flat = flatten_grads(&grads).unwrap();
        let back = unflatten_grads(&grads, &flat).unwrap();
        std::hint::black_box(back.len());
    });
    results.push(("flatten+unflatten grads", m.mean_s));

    // --- end-to-end DP step ----------------------------------------------
    let coord = Coordinator::new(&dir, cluster::dgx1(2)).unwrap();
    let mut corpus = Corpus::new(tm.vocab, 1_000_000, 9);
    let cfg = TrainConfig {
        strategy: Strategy::DataParallel { workers: 2, delayed_factor: 1 },
        steps: 8,
        log_every: 0,
        ..Default::default()
    };
    let report = coord.train(&mut corpus, &cfg).unwrap();
    results.push(("DP-2 full step (wall)", report.mean_step_wall_s));
    let grad_exec = results[0].1;
    let overhead = report.mean_step_wall_s - 2.0 * grad_exec;
    results.push(("  coordinator overhead", overhead.max(0.0)));

    let mut table = Table::new(&["path", "mean"]);
    for (name, t) in &results {
        table.row(&[name.to_string(), fmt_secs(*t)]);
    }
    table.print("L3 hot-path latencies");
    println!("runtime_hotpath OK");
}
