//! DLPlacer scaling ablation (DESIGN.md §Placer-scale): ILP solve time vs
//! DFG size and device count, and solution quality vs the list-scheduling
//! heuristic.  The paper reports 11–18 min for Inception at TF-op
//! granularity on an 18-core Xeon; our branch-level decomposition solves
//! in seconds — the ablation quantifies what the heuristic gives up.

use hybridpar::bench::{bench, f2, f3, Table};
use hybridpar::cluster::dgx1;
use hybridpar::dfg::Dfg;
use hybridpar::placer::{self, anneal};
use hybridpar::util::rng::Rng;

/// Random layered DAG: `layers` layers of `width` ops, random edges
/// forward, block-sync every `sync_every` layers (inception-like).
fn random_dag(layers: usize, width: usize, sync_every: usize, seed: u64)
              -> Dfg {
    let mut rng = Rng::new(seed);
    let mut g = Dfg::new("random");
    let mut prev_layer: Vec<usize> = vec![g.add_op("src", 1e9, 1e5, 1e6)];
    for l in 0..layers {
        if l % sync_every == sync_every - 1 {
            // sync vertex
            let s = g.add_op(&format!("sync{l}"), 1e8, 1e5, 1e6);
            for &p in &prev_layer {
                g.add_edge(p, s);
            }
            prev_layer = vec![s];
            continue;
        }
        let mut cur = Vec::new();
        for w in 0..width {
            let flops = 1e9 * (1.0 + rng.f64() * 3.0);
            let op = g.add_op(&format!("l{l}w{w}"), flops, 1e5, 1e6);
            // connect to 1-2 random parents
            let p1 = prev_layer[rng.below(prev_layer.len() as u64) as usize];
            g.add_edge(p1, op);
            cur.push(op);
        }
        prev_layer = cur;
    }
    let sink = g.add_op("sink", 1e8, 1e5, 1e6);
    for &p in &prev_layer {
        g.add_edge(p, sink);
    }
    g
}

fn main() {
    let hw = dgx1(2);
    // Bounded B&B budget per segment keeps the sweep's wall time sane;
    // quality still dominates the heuristic (candidate-min guarantees it).
    let opts = placer::PlacerOptions {
        bnb: hybridpar::milp::BnbConfig {
            max_nodes: 5_000,
            time_limit: std::time::Duration::from_secs(5),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut table = Table::new(&["ops", "ilp s", "heur s", "ilp makespan",
                                 "heur makespan", "anneal makespan",
                                 "heur/ilp"]);
    for (layers, width) in [(3usize, 3usize), (6, 3), (9, 4), (12, 4)] {
        let g = random_dag(layers, width, 3, 42 + layers as u64);
        let times = g.op_times(7e12, 15e-6);
        let mi = bench(&format!("ilp_{}ops", g.n_ops()), 1, 0.0, || {
            let p = placer::place(&g, &hw, &times, &opts).unwrap();
            std::hint::black_box(p.predicted_time);
        });
        let mh = bench(&format!("heur_{}ops", g.n_ops()), 2, 0.5, || {
            let p = placer::place_heuristic(&g, &hw, &times, 2).unwrap();
            std::hint::black_box(p.predicted_time);
        });
        let ilp = placer::place(&g, &hw, &times, &opts).unwrap();
        let heur = placer::place_heuristic(&g, &hw, &times, 2).unwrap();
        // §7.4 comparison class: stochastic search (anytime, no optimality
        // certificate — the paper's criticism of RL placement).
        let sa = anneal::place_annealed(&g, &hw, &times, 2,
                                        anneal::AnnealOptions::default())
            .unwrap();
        placer::validate_placement(&g, &hw, &ilp.assignment).unwrap();
        assert!(ilp.predicted_time <= heur.predicted_time + 1e-9,
                "ILP must never lose to the heuristic");
        table.row(&[
            g.n_ops().to_string(),
            f3(mi.mean_s),
            f3(mh.mean_s),
            f3(ilp.predicted_time * 1e3),
            f3(heur.predicted_time * 1e3),
            f3(sa.predicted_time * 1e3),
            f2(heur.predicted_time / ilp.predicted_time),
        ]);
    }
    table.print("DLPlacer ILP vs heuristic — solve time and quality \
                 (makespans in ms)");
    println!("placer_scaling OK");
}
