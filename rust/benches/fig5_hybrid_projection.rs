//! Fig. 5 reproduction: projected speedup of hybrid MP-DP vs DP-only for
//! Inception-V3 (5a), GNMT (5b) and BigLSTM (5c) — the whole grid runs as
//! one parallel [`run_sweep`] call instead of three serial planner queries.
//!
//! Headline numbers from the paper: the hybrid strategy beats what DP
//! alone can achieve at scale by **≥26.5%** (Inception, 256 GPUs), **8%**
//! (GNMT, 256 GPUs) and **22%** (BigLSTM, vs best DP at 16 GPUs).
//!
//! SU² values come from the same machinery as Table 1 (DLPlacer /
//! pipeline, now with explicit pipelined candidates competing too) via the
//! planner's analytical cost model; SE_N = 1 per the paper's conservative
//! §4.3 assumption.  The batch axis is `BatchSpec::Paper` — the §4.2
//! epoch-methodology mini-batches (64/128/64) — so the E(B) curves line up.

use hybridpar::bench::{f2, Table};
use hybridpar::planner::sweep::{run_sweep, BatchSpec, StrategyFamily,
                                SweepSpec};
use hybridpar::planner::Objective;

fn main() {
    let spec = SweepSpec {
        models: vec!["inception-v3".into(), "gnmt".into(),
                     "biglstm".into()],
        topologies: vec!["dgx1".into()],
        devices: vec![256],
        batches: vec![BatchSpec::Paper],
        families: vec![StrategyFamily::Hybrid],
        mp_degrees: vec![2],
        objective: Objective::TimeToConverge,
        cost_model: "analytical".into(), // SE_N = 1
        curve_max_devices: 256,
        threads: 0, // one worker per core: the three figures in parallel
        // Default memory model + the 32 GB V100 topology: every paper
        // candidate stays feasible, so the fig5 headline gains are
        // untouched by the memory layer.
        ..Default::default()
    };
    let sweep = run_sweep(&spec).expect("fig5 grid must evaluate");
    let mut headlines = Vec::new();

    for result in &sweep.results {
        let plan = result
            .plan
            .as_ref()
            .unwrap_or_else(|| panic!("{}: {:?}", result.scenario.model,
                                      result.error));
        let su_2 = plan
            .scorecard
            .iter()
            .find(|c| c.mp_degree == 2)
            .map(|c| c.su_m)
            .unwrap();
        let mut table =
            Table::new(&["devices", "DP-only", "hybrid M=2", "hybrid/DP"]);
        let mut best_dp: f64 = 0.0;
        let mut best_hybrid: f64 = 0.0;
        for p in plan.curve.iter().filter(|p| p.devices >= 2) {
            if let Some(d) = p.dp {
                best_dp = best_dp.max(d);
            }
            if let Some(h) = p.hybrid {
                best_hybrid = best_hybrid.max(h);
            }
            let ratio = match (p.hybrid, p.dp) {
                (Some(h), Some(d)) => Some(h / d),
                _ => None,
            };
            table.row(&[
                p.devices.to_string(),
                p.dp.map(f2).unwrap_or("diverged".into()),
                p.hybrid.map(f2).unwrap_or("-".into()),
                ratio.map(f2).unwrap_or("-".into()),
            ]);
        }
        table.print(&format!("Fig. 5 — {} (SU^2 = {:.3})", plan.model,
                             su_2));

        // Headline, as the paper frames it: the best the hybrid achieves
        // at scale vs the best DP alone can achieve at ANY scale
        // ("compared to what DP alone can achieve at scale").
        let gain = (best_hybrid / best_dp - 1.0) * 100.0;
        println!("  best hybrid = {best_hybrid:.2}, best DP-only = \
{best_dp:.2} => hybrid gain {gain:.1}%");
        println!("  planner pick at 256-GPU budget: {:?} \
                  ({} devices used)\n",
                 plan.strategy, plan.devices_used);
        headlines.push((plan.model.clone(), gain));
    }

    // Paper headline shape: Inception ≥ 26.5%, GNMT ≥ 8%, BigLSTM ≥ 22%
    // ("at least" bounds under SE=1; our SU² differs slightly so require
    // the same ordering and the "hybrid wins at scale" conclusion).
    let inc = headlines[0].1;
    let gn = headlines[1].1;
    let bl = headlines[2].1;
    println!("headline gains: inception {inc:.1}% (paper ≥26.5%), \
              gnmt {gn:.1}% (paper ≥8%), biglstm {bl:.1}% (paper ≥22%)");
    assert!(inc > 25.0, "inception hybrid gain too small: {inc}");
    assert!(gn > 4.0, "gnmt hybrid gain too small: {gn}");
    assert!(bl > 15.0, "biglstm hybrid gain too small: {bl}");
    assert!(gn < inc && gn < bl,
            "GNMT (scales well under DP) must show the smallest gain");
    println!("fig5_hybrid_projection OK");
}
