//! Fig. 5 reproduction: projected speedup of hybrid MP-DP vs DP-only for
//! Inception-V3 (5a), GNMT (5b) and BigLSTM (5c).
//!
//! Headline numbers from the paper: the hybrid strategy beats what DP
//! alone can achieve at scale by **≥26.5%** (Inception, 256 GPUs), **8%**
//! (GNMT, 256 GPUs) and **22%** (BigLSTM, vs best DP at 16 GPUs).
//!
//! SU² values come from the same machinery as Table 1 (DLPlacer /
//! pipeline); SE_N = 1 per the paper's conservative §4.3 assumption.

use hybridpar::bench::{f2, Table};
use hybridpar::cluster;
use hybridpar::models::{self, ModelProfile};
use hybridpar::parallel::{NetworkModel, ScalingEfficiency};
use hybridpar::pipeline;
use hybridpar::placer;

fn su2(prof: &ModelProfile, times: &[f64]) -> f64 {
    if prof.name.starts_with("inception") {
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let p = placer::place(&prof.dfg, &hw, times,
                              &placer::PlacerOptions::default()).unwrap();
        times.iter().sum::<f64>() / p.predicted_time
    } else {
        let cfg = pipeline::PipeConfig {
            mini_batch: prof.mini_batch,
            saturation_batch: prof.pipe_saturation,
            ..Default::default()
        };
        pipeline::pipeline_speedup(&prof.dfg, times, 2, 16, cfg)
            .unwrap()
            .speedup
    }
}

fn main() {
    // Mini-batches match the paper's §4.2 epoch-count methodology
    // (Inception 64/GPU, GNMT 128, BigLSTM 64) so the E(B) curves line up.
    let profiles = [models::inception_v3(64), models::gnmt(128),
                    models::biglstm(64)];
    let mut headlines = Vec::new();

    for prof in &profiles {
        let times = prof.dfg.op_times(7e12, 15e-6);
        let su_2 = su2(prof, &times);
        let net = NetworkModel {
            name: prof.name.clone(),
            epochs: prof.epochs.clone(),
            mini_batch: prof.mini_batch,
            se: ScalingEfficiency::Perfect,
            mp_speedups: vec![(2, su_2)],
        };
        let mut table =
            Table::new(&["devices", "DP-only", "hybrid M=2", "hybrid/DP"]);
        let mut best_dp: f64 = 0.0;
        let mut best_hybrid: f64 = 0.0;
        let mut n = 2usize;
        while n <= 256 {
            let dp = net.su_dp(n);
            let hy = net.su_hybrid(n, 2);
            if let Some(d) = dp {
                best_dp = best_dp.max(d);
            }
            if let Some(h) = hy {
                best_hybrid = best_hybrid.max(h);
            }
            let ratio = match (hy, dp) {
                (Some(h), Some(d)) => Some(h / d),
                _ => None,
            };
            table.row(&[
                n.to_string(),
                dp.map(f2).unwrap_or("diverged".into()),
                hy.map(f2).unwrap_or("-".into()),
                ratio.map(f2).unwrap_or("-".into()),
            ]);
            n *= 2;
        }
        table.print(&format!("Fig. 5 — {} (SU^2 = {:.3})", net.name, su_2));

        // Headline, as the paper frames it: the best the hybrid achieves
        // at scale vs the best DP alone can achieve at ANY scale
        // ("compared to what DP alone can achieve at scale").
        let gain = (best_hybrid / best_dp - 1.0) * 100.0;
        println!("  best hybrid = {best_hybrid:.2}, best DP-only = \
{best_dp:.2} => hybrid gain {gain:.1}%\n");
        headlines.push((net.name.clone(), gain));
    }

    // Paper headline shape: Inception ≥ 26.5%, GNMT ≥ 8%, BigLSTM ≥ 22%
    // ("at least" bounds under SE=1; our SU² differs slightly so require
    // the same ordering and the "hybrid wins at scale" conclusion).
    let inc = headlines[0].1;
    let gn = headlines[1].1;
    let bl = headlines[2].1;
    println!("headline gains: inception {inc:.1}% (paper ≥26.5%), \
              gnmt {gn:.1}% (paper ≥8%), biglstm {bl:.1}% (paper ≥22%)");
    assert!(inc > 25.0, "inception hybrid gain too small: {inc}");
    assert!(gn > 4.0, "gnmt hybrid gain too small: {gn}");
    assert!(bl > 15.0, "biglstm hybrid gain too small: {bl}");
    assert!(gn < inc && gn < bl,
            "GNMT (scales well under DP) must show the smallest gain");
    println!("fig5_hybrid_projection OK");
}
