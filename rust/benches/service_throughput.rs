//! Service throughput bench: drive a live planner daemon over loopback
//! with a mixed hot/cold request stream and report p50/p99 latency plus
//! the cache hit rate.
//!
//! Asserts the tentpole speedup claim: a warm-cache hit is served at
//! least 10× faster than a cold plan (the cold path pays a full planner
//! evaluation — DLPlacer ILP included for branchy models — where the
//! warm path pays one canonicalisation and an LRU lookup).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use hybridpar::bench::{f2, Table};
use hybridpar::service::{self, ServiceOptions};
use hybridpar::util::{fmt_secs, percentile};

/// POST /plan and time the full request (connect → last byte).
fn timed_plan(addr: SocketAddr, body: &str) -> (u16, f64) {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr).expect("connect");
    let raw = format!(
        "POST /plan HTTP/1.1\r\nHost: bench\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len());
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = Vec::new();
    stream.read_to_end(&mut response).unwrap();
    let status: u16 = std::str::from_utf8(&response)
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    (status, t0.elapsed().as_secs_f64())
}

fn main() {
    let handle = service::bind("127.0.0.1:0", ServiceOptions {
        threads: 4,
        cache_entries: 256,
        ..Default::default()
    })
    .expect("bind service")
    .spawn();
    let addr = handle.addr();

    // The hot key: one request repeated throughout the stream.  Seeded
    // once up front so every subsequent hot timing is a pure cache hit.
    let hot_body = r#"{"model":"inception-v3","devices":8}"#;
    let (status, seed_latency) = timed_plan(addr, hot_body);
    assert_eq!(status, 200);

    // The cold set: distinct device budgets (and models) so every
    // request is a fresh canonical key — each pays a full planner
    // evaluation.  Inception keeps the DLPlacer ILP on the cold path;
    // budgets start at 9 so no cold key collides with the hot one.
    let cold_bodies: Vec<String> = (0..24)
        .map(|i| {
            let model = ["inception-v3", "gnmt", "biglstm"][i % 3];
            format!(r#"{{"model":"{model}","devices":{}}}"#, 9 + i)
        })
        .collect();

    // Mixed stream: each cold request interleaved with 4 hot repeats.
    let mut cold = Vec::new();
    let mut warm = Vec::new();
    for body in &cold_bodies {
        let (status, dt) = timed_plan(addr, body);
        assert_eq!(status, 200, "cold request failed: {body}");
        cold.push(dt);
        for _ in 0..4 {
            let (status, dt) = timed_plan(addr, hot_body);
            assert_eq!(status, 200);
            warm.push(dt);
        }
    }

    let all: Vec<f64> =
        cold.iter().chain(warm.iter()).copied().collect();
    let cache = handle.service().cache();
    let (hits, misses) = (cache.hits(), cache.misses());
    let hit_rate = hits as f64 / (hits + misses) as f64;

    let mut table = Table::new(&["stream", "requests", "p50", "p99"]);
    for (name, xs) in [("cold (fills)", &cold), ("warm (hits)", &warm),
                       ("mixed", &all)] {
        table.row(&[name.to_string(), xs.len().to_string(),
                    fmt_secs(percentile(xs, 50.0)),
                    fmt_secs(percentile(xs, 99.0))]);
    }
    table.print("service /plan latency (loopback, 4 workers)");
    println!("cache: {hits} hits / {misses} fills (hit rate {})",
             f2(hit_rate));
    println!("cold seed request: {}", fmt_secs(seed_latency));

    let cold_p50 = percentile(&cold, 50.0);
    let warm_p50 = percentile(&warm, 50.0);
    let speedup = cold_p50 / warm_p50;
    println!("warm-over-cold speedup: {}x (p50 {} -> {})",
             f2(speedup), fmt_secs(cold_p50), fmt_secs(warm_p50));
    assert!(speedup >= 10.0,
            "a warm-cache hit must be served >= 10x faster than a cold \
             plan, got {speedup:.1}x ({cold_p50} vs {warm_p50})");
    // The stream was 1 seed + 24 cold fills and 96 pure hits.
    assert_eq!(misses, 25, "every cold request must be a fresh fill");
    assert_eq!(hits, 96, "every hot repeat must hit");
    assert!(hit_rate > 0.75);

    handle.stop();
    println!("service_throughput OK");
}
