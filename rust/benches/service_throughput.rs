//! Service throughput bench: drive a live planner daemon over loopback
//! with a mixed hot/cold request stream and report p50/p99 latency plus
//! the cache hit rate.
//!
//! Two phases:
//!
//! 1. **connect-per-request** (the original stream): asserts the
//!    tentpole speedup claim — a warm-cache hit is served at least 10×
//!    faster than a cold plan (the cold path pays a full planner
//!    evaluation, DLPlacer ILP included for branchy models; the warm
//!    path pays one canonicalisation and an LRU lookup);
//! 2. **keep-alive load**: 10 000 requests over a pool of persistent
//!    connections (plus an army of parked idle keep-alives the event
//!    loop must poll around), mixed hot/cold, gating the warm p99.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use hybridpar::bench::{f2, Table};
use hybridpar::metrics::Histogram;
use hybridpar::service::{self, ServiceOptions};
use hybridpar::util::fmt_secs;

/// Fold a sample vector into the service latency ladder so percentiles
/// come from the shared [`Histogram::percentile`] estimator — the same
/// math a Prometheus `histogram_quantile` over `/metrics` would do —
/// instead of a bench-local sort-and-index.
fn latency_hist(xs: &[f64]) -> Histogram {
    let h = Histogram::latency();
    for &x in xs {
        h.observe(x);
    }
    h
}

/// POST /plan on a fresh connection and time the full request
/// (connect → last byte).  `Connection: close` keeps `read_to_end`
/// well-defined against the keep-alive server.
fn timed_plan(addr: SocketAddr, body: &str) -> (u16, f64) {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr).expect("connect");
    let raw = format!(
        "POST /plan HTTP/1.1\r\nHost: bench\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = Vec::new();
    stream.read_to_end(&mut response).unwrap();
    let status: u16 = std::str::from_utf8(&response)
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    (status, t0.elapsed().as_secs_f64())
}

/// POST /plan on a *kept-alive* connection: write the request, read
/// exactly one `Content-Length`-framed response, leave the socket open.
fn keepalive_plan(stream: &mut TcpStream, body: &str) -> (u16, f64) {
    let t0 = Instant::now();
    let raw = format!(
        "POST /plan HTTP/1.1\r\nHost: bench\r\n\
         Content-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len());
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = stream.read(&mut tmp).expect("read head");
        assert!(n > 0, "server closed a keep-alive connection");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let content_length: usize = head
        .split("\r\n")
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().unwrap())
        })
        .expect("keep-alive response carries Content-Length");
    while buf.len() < head_end + content_length {
        let n = stream.read(&mut tmp).expect("read body");
        assert!(n > 0, "server closed mid-body");
        buf.extend_from_slice(&tmp[..n]);
    }
    (status, t0.elapsed().as_secs_f64())
}

fn main() {
    let handle = service::bind("127.0.0.1:0", ServiceOptions {
        threads: 4,
        cache_entries: 256,
        ..Default::default()
    })
    .expect("bind service")
    .spawn();
    let addr = handle.addr();

    // The hot key: one request repeated throughout the stream.  Seeded
    // once up front so every subsequent hot timing is a pure cache hit.
    let hot_body = r#"{"model":"inception-v3","devices":8}"#;
    let (status, seed_latency) = timed_plan(addr, hot_body);
    assert_eq!(status, 200);

    // The cold set: distinct device budgets (and models) so every
    // request is a fresh canonical key — each pays a full planner
    // evaluation.  Inception keeps the DLPlacer ILP on the cold path;
    // budgets start at 9 so no cold key collides with the hot one.
    let cold_bodies: Vec<String> = (0..24)
        .map(|i| {
            let model = ["inception-v3", "gnmt", "biglstm"][i % 3];
            format!(r#"{{"model":"{model}","devices":{}}}"#, 9 + i)
        })
        .collect();

    // Mixed stream: each cold request interleaved with 4 hot repeats.
    let mut cold = Vec::new();
    let mut warm = Vec::new();
    for body in &cold_bodies {
        let (status, dt) = timed_plan(addr, body);
        assert_eq!(status, 200, "cold request failed: {body}");
        cold.push(dt);
        for _ in 0..4 {
            let (status, dt) = timed_plan(addr, hot_body);
            assert_eq!(status, 200);
            warm.push(dt);
        }
    }

    let all: Vec<f64> =
        cold.iter().chain(warm.iter()).copied().collect();
    let cache = handle.service().cache();
    let (hits, misses) = (cache.hits(), cache.misses());
    let hit_rate = hits as f64 / (hits + misses) as f64;

    let mut table = Table::new(&["stream", "requests", "p50", "p99"]);
    for (name, xs) in [("cold (fills)", &cold), ("warm (hits)", &warm),
                       ("mixed", &all)] {
        let h = latency_hist(xs);
        table.row(&[name.to_string(), xs.len().to_string(),
                    fmt_secs(h.percentile(0.50).unwrap_or(0.0)),
                    fmt_secs(h.percentile(0.99).unwrap_or(0.0))]);
    }
    table.print("service /plan latency (loopback, 4 workers)");
    println!("cache: {hits} hits / {misses} fills (hit rate {})",
             f2(hit_rate));
    println!("cold seed request: {}", fmt_secs(seed_latency));

    let cold_p50 = latency_hist(&cold).percentile(0.50).unwrap();
    let warm_p50 = latency_hist(&warm).percentile(0.50).unwrap();
    let speedup = cold_p50 / warm_p50;
    println!("warm-over-cold speedup: {}x (p50 {} -> {})",
             f2(speedup), fmt_secs(cold_p50), fmt_secs(warm_p50));
    assert!(speedup >= 10.0,
            "a warm-cache hit must be served >= 10x faster than a cold \
             plan, got {speedup:.1}x ({cold_p50} vs {warm_p50})");
    // The stream was 1 seed + 24 cold fills and 96 pure hits.
    assert_eq!(misses, 25, "every cold request must be a fresh fill");
    assert_eq!(hits, 96, "every hot repeat must hit");
    assert!(hit_rate > 0.75);

    // ---- phase 2: keep-alive mixed load --------------------------------
    // 10k requests over a pool of persistent connections, with an army
    // of parked idle keep-alives the event loop has to poll around
    // (they exercise the cold-connection tier).  Every ~100th request
    // per connection is a fresh cold key; the rest are pure hits.
    const TOTAL_REQUESTS: usize = 10_000;
    const ACTIVE_CONNS: usize = 64;
    const IDLE_ARMY: usize = 256;
    const COLD_EVERY: usize = 100;
    const WARM_P99_BOUND_S: f64 = 0.5;

    let mut idle = Vec::new();
    for _ in 0..IDLE_ARMY {
        // Degrade gracefully under tight fd limits — the army's size is
        // incidental, its presence is the point.
        match TcpStream::connect(addr) {
            Ok(s) => idle.push(s),
            Err(_) => break,
        }
    }

    let per_conn = TOTAL_REQUESTS / ACTIVE_CONNS;
    let t_load = Instant::now();
    let per_conn_results: Vec<(Vec<f64>, Vec<f64>)> =
        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..ACTIVE_CONNS)
                .map(|c| {
                    scope.spawn(move || {
                        let mut stream =
                            TcpStream::connect(addr).expect("connect");
                        let mut warm = Vec::new();
                        let mut cold = Vec::new();
                        for i in 0..per_conn {
                            let fresh = i % COLD_EVERY == 0;
                            let body = if fresh {
                                // A unique canonical key per (conn,
                                // round) — batch echoes into the plan,
                                // so each is a guaranteed fill without
                                // growing the device graph.
                                format!(
                                    r#"{{"model":"gnmt","devices":8,
                                         "batch":{}}}"#,
                                    256 + c * per_conn + i)
                            } else {
                                r#"{"model":"inception-v3","devices":8}"#
                                    .to_string()
                            };
                            let (status, dt) =
                                keepalive_plan(&mut stream, &body);
                            assert_eq!(status, 200,
                                       "request {i} on conn {c}");
                            if fresh {
                                cold.push(dt);
                            } else {
                                warm.push(dt);
                            }
                        }
                        (warm, cold)
                    })
                })
                .collect();
            workers.into_iter().map(|w| w.join().unwrap()).collect()
        });
    let load_wall = t_load.elapsed().as_secs_f64();
    let idle_count = idle.len();
    drop(idle);

    let ka_warm: Vec<f64> = per_conn_results
        .iter()
        .flat_map(|(w, _)| w.iter().copied())
        .collect();
    let ka_cold: Vec<f64> = per_conn_results
        .iter()
        .flat_map(|(_, c)| c.iter().copied())
        .collect();
    let served = ka_warm.len() + ka_cold.len();
    let mut table = Table::new(&["stream", "requests", "p50", "p99"]);
    for (name, xs) in [("keep-alive warm", &ka_warm),
                       ("keep-alive cold", &ka_cold)] {
        let h = latency_hist(xs);
        table.row(&[name.to_string(), xs.len().to_string(),
                    fmt_secs(h.percentile(0.50).unwrap_or(0.0)),
                    fmt_secs(h.percentile(0.99).unwrap_or(0.0))]);
    }
    table.print(&format!(
        "service /plan keep-alive load ({ACTIVE_CONNS} active + \
         {idle_count} idle conns)"));
    println!("keep-alive load: {served} requests in {} \
              ({:.0} req/s wall)",
             fmt_secs(load_wall), served as f64 / load_wall);

    let warm_p99 = latency_hist(&ka_warm).percentile(0.99).unwrap();
    assert!(warm_p99 <= WARM_P99_BOUND_S,
            "warm keep-alive p99 must hold {WARM_P99_BOUND_S}s, \
             got {warm_p99}s");

    handle.stop();
    println!("service_throughput OK");
}
