//! Fig. 8 reproduction: DLPlacer-estimated vs "silicon" per-step speedup
//! for Inception-V3 on 1–4 GPUs.
//!
//! Paper: estimated speedup within 6% of silicon; 2-GPU speedup (1.32x)
//! nearly equals the 3- and 4-GPU optima because the network's inherent
//! branch parallelism is exhausted at 2 devices.
//!
//! Silicon here is the discrete-event simulator with link contention and
//! per-transfer software overhead — effects the ILP's idealised model
//! (paper §6 assumptions 1-2) does not see.

use hybridpar::bench::{f2, f3, Table};
use hybridpar::cluster;
use hybridpar::models;
use hybridpar::placer;
use hybridpar::sim;

fn main() {
    let prof = models::inception_v3(32);
    let times = prof.dfg.op_times(7e12, 15e-6);
    let serial: f64 = times.iter().sum();

    let mut table = Table::new(&["gpus", "DLPlacer est.", "silicon",
                                 "gap %", "solve s"]);
    let mut est = Vec::new();
    let mut sil = Vec::new();
    for nd in 1..=4usize {
        let hw = cluster::dgx1(nd);
        let t0 = std::time::Instant::now();
        let p = placer::place(&prof.dfg, &hw, &times,
                              &placer::PlacerOptions {
                                  max_devices: nd,
                                  ..Default::default()
                              })
            .unwrap();
        let solve = t0.elapsed().as_secs_f64();
        placer::validate_placement(&prof.dfg, &hw, &p.assignment).unwrap();
        let s = sim::simulate(&prof.dfg, &hw, &p.assignment, &times,
                              sim::SimConfig::default())
            .unwrap();
        let su_est = serial / p.predicted_time;
        let su_sil = serial / s.makespan;
        let gap = (su_est - su_sil).abs() / su_sil * 100.0;
        table.row(&[nd.to_string(), f3(su_est), f3(su_sil),
                    f2(gap), f2(solve)]);
        est.push(su_est);
        sil.push(su_sil);
    }
    table.print("Fig. 8 — DLPlacer estimate vs silicon, Inception-V3");

    // Shape assertions.
    assert!((est[0] - 1.0).abs() < 1e-6, "1 GPU = no speedup");
    assert!(est[1] > 1.2, "2-GPU speedup should be substantial: {}", est[1]);
    for (e, s) in est.iter().zip(&sil) {
        let gap = (e - s).abs() / s;
        assert!(gap < 0.10,
                "estimate gap {:.1}% exceeds 10% (paper: within 6%)",
                gap * 100.0);
    }
    // Marginal gains beyond 2 GPUs (paper: "almost the same as what is
    // optimally obtainable with three or four GPUs").
    let gain_3_4 = est[3] / est[1];
    assert!(gain_3_4 < 1.12,
            "3-4 GPU gain over 2 GPU should be marginal, got {gain_3_4}");
    println!("fig8_placer_accuracy OK");
}
