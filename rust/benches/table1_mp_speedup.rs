//! Table 1 reproduction: 2-way MP splitting strategy and speedup.
//!
//!   paper:  Inception-V3  Partitioned w/ DLPlacer   1.32x
//!           GNMT          Pipeline Parallelism      1.15x
//!           BigLSTM       Pipeline Parallelism      1.22x
//!
//! Here SU² comes from the actual machinery: the DLPlacer ILP over the
//! branch-level Inception DFG, and the GPipe scheduler (with the
//! microbatch-utilization model) over the GNMT/BigLSTM chains.  Absolute
//! matching is not expected (our substrate is a simulator); the *shape* —
//! ordering and rough magnitudes — must hold.

use hybridpar::bench::{bench, f2, Table};
use hybridpar::cluster;
use hybridpar::models;
use hybridpar::pipeline;
use hybridpar::placer;

fn main() {
    let paper: [(&str, f64); 3] =
        [("inception-v3", 1.32), ("gnmt", 1.15), ("biglstm", 1.22)];
    let mut measured = Vec::new();

    // Inception: DLPlacer ILP on 2 devices.
    let prof = models::inception_v3(32);
    let times = prof.dfg.op_times(7e12, 15e-6);
    let serial: f64 = times.iter().sum();
    let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
    let m = bench("dlplacer_inception_2gpu", 3, 1.0, || {
        let p = placer::place(&prof.dfg, &hw, &times,
                              &placer::PlacerOptions::default()).unwrap();
        std::hint::black_box(p.predicted_time);
    });
    let p = placer::place(&prof.dfg, &hw, &times,
                          &placer::PlacerOptions::default()).unwrap();
    measured.push(("inception-v3", prof.mp_strategy,
                   serial / p.predicted_time));
    println!("(DLPlacer solve: {:.2} s/run)", m.mean_s);

    // GNMT / BigLSTM: pipeline partitioner.
    for prof in [models::gnmt(128), models::biglstm(64)] {
        let times = prof.dfg.op_times(7e12, 15e-6);
        let cfg = pipeline::PipeConfig {
            mini_batch: prof.mini_batch,
            saturation_batch: prof.pipe_saturation,
            ..Default::default()
        };
        let r = pipeline::pipeline_speedup(&prof.dfg, &times, 2, 16, cfg)
            .unwrap();
        let name: &'static str = if prof.name == "gnmt" { "gnmt" }
                                 else { "biglstm" };
        measured.push((name, prof.mp_strategy, r.speedup));
    }

    let mut table = Table::new(&["network", "MP strategy", "paper SU^2",
                                 "measured SU^2", "ratio"]);
    for ((name, strategy, got), (pname, want)) in
        measured.iter().zip(paper.iter())
    {
        assert_eq!(name, pname);
        table.row(&[
            name.to_string(),
            strategy.to_string(),
            f2(*want),
            f2(*got),
            f2(got / want),
        ]);
    }
    table.print("Table 1 — 2-GPU model-parallel speedup");

    // Shape assertions: every speedup in (1.05, 1.6); Inception largest.
    for &(name, _, su) in &measured {
        assert!(su > 1.05 && su < 1.6,
                "{name} SU^2 {su} outside the paper's band");
    }
    let inc = measured[0].2;
    let gnmt = measured[1].2;
    let bl = measured[2].2;
    assert!(inc > gnmt && inc > bl,
            "Inception (DLPlacer) must lead: {inc} vs {gnmt}/{bl}");
    assert!(bl > gnmt, "BigLSTM pipelines better than GNMT \
                        ({bl} vs {gnmt}), as in the paper");
    println!("table1_mp_speedup OK");
}
