//! Collective micro-benchmarks: ring vs tree vs parameter-server across
//! payload sizes and worker counts, on NVLink-only and multi-node
//! topologies; host wall-clock + simulated time + α-β model agreement.
//!
//! This regenerates the scaling-efficiency substrate behind the paper's
//! SE_N discussion (§3.1/§4.3): ring all-reduce cost grows with N and
//! with crossing slow inter-node links, and PS collapses at scale.

use hybridpar::bench::{bench, f3, Table};
use hybridpar::cluster::{dgx1, multi_node, HwGraph};
use hybridpar::collective::compress::ring_allreduce_bf16;
use hybridpar::collective::{hierarchical_allreduce, parameter_server,
                            ring_allreduce, ring_cost, tree_allreduce};
use hybridpar::util::rng::Rng;

fn bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.f32()).collect()).collect()
}

fn main() {
    // --- sweep: payload size on a 4-GPU NVLink ring ----------------------
    let hw = dgx1(4);
    let devs = hw.devices();
    let mut table = Table::new(&["MB", "ring sim", "bf16 ring", "tree sim",
                                 "PS sim", "ring αβ model", "model err %"]);
    for mb in [0.25f64, 1.0, 4.0, 16.0, 64.0] {
        let len = (mb * 1e6 / 4.0) as usize;
        let mut b1 = bufs(4, len, 1);
        let ring = ring_allreduce(&mut b1, &hw, &devs).unwrap();
        let mut b2 = bufs(4, len, 1);
        let tree = tree_allreduce(&mut b2, &hw, &devs).unwrap();
        let mut b3 = bufs(4, len, 1);
        let ps = parameter_server(&mut b3, &hw, &devs).unwrap();
        let mut b4 = bufs(4, len, 1);
        let bf16 = ring_allreduce_bf16(&mut b4, &hw, &devs).unwrap();
        let model = ring_cost(4, mb * 1e6, 1.3e-6, 25e9);
        let err = (ring.sim_time - model).abs() / model * 100.0;
        table.row(&[
            format!("{mb}"),
            f3(ring.sim_time * 1e3),
            f3(bf16.sim_time * 1e3),
            f3(tree.sim_time * 1e3),
            f3(ps.sim_time * 1e3),
            f3(model * 1e3),
            format!("{err:.1}"),
        ]);
        assert!(err < 15.0, "ring sim should track the α-β model: {err}%");
        assert!(bf16.sim_time < 0.6 * ring.sim_time,
                "bf16 wire should ~halve the collective time");
    }
    table.print("all-reduce simulated time (ms) vs payload, 4x NVLink");

    // --- sweep: worker count, multi-node ---------------------------------
    let mut table = Table::new(&["workers", "topology", "ring sim ms",
                                 "hier sim ms", "PS sim ms", "PS/ring"]);
    for (workers, hw) in [(4usize, dgx1(4)),
                          (8, multi_node(2, 4)),
                          (16, multi_node(4, 4))] {
        let hw: HwGraph = hw;
        let devs: Vec<usize> = hw.devices();
        let len = 4_000_000; // 16 MB
        let mut b1 = bufs(workers, len, 2);
        let ring = ring_allreduce(&mut b1, &hw, &devs).unwrap();
        let mut b2 = bufs(workers, len, 2);
        let ps = parameter_server(&mut b2, &hw, &devs).unwrap();
        let mut b3 = bufs(workers, len, 2);
        let hier = hierarchical_allreduce(&mut b3, &hw, &devs).unwrap();
        table.row(&[
            workers.to_string(),
            hw.name.clone(),
            f3(ring.sim_time * 1e3),
            f3(hier.sim_time * 1e3),
            f3(ps.sim_time * 1e3),
            f3(ps.sim_time / ring.sim_time),
        ]);
        assert!(ps.sim_time > ring.sim_time,
                "PS must lose to ring at {workers} workers");
        if hw.is_multi_node() {
            assert!(hier.sim_time < ring.sim_time,
                    "two-level must beat the flat ring across nodes: \
                     {} vs {}", hier.sim_time, ring.sim_time);
        }
    }
    table.print("ring vs hierarchical vs parameter-server at scale \
                 (16 MB gradients)");

    // --- host-side throughput of the real reduction ----------------------
    let hw = dgx1(4);
    let devs = hw.devices();
    let len = 4_000_000;
    let m = bench("ring_allreduce_16MBx4_host", 5, 2.0, || {
        let mut b = bufs(4, len, 3);
        ring_allreduce(&mut b, &hw, &devs).unwrap();
        std::hint::black_box(&b);
    });
    let gbps = (2.0 * 3.0 / 4.0 * (len * 4 * 4) as f64) / m.mean_s / 1e9;
    println!("host reduction throughput ≈ {gbps:.2} GB/s of wire-equivalent \
              traffic");
    println!("allreduce OK");
}
