//! Discrete-event "silicon" simulator.
//!
//! Executes a placed DFG on a hardware graph and reports the per-step time.
//! This is the stand-in for the paper's real-GPU runs: Fig. 8 compares
//! DLPlacer's ILP-*predicted* step time against the *measured* step time on
//! silicon; here the measurement comes from this simulator, which models
//! effects the ILP deliberately ignores —
//!
//! * **link contention**: transfers serialise on each physical link
//!   (the ILP assumes fully-overlapped communication, paper §6 assumption 2);
//! * **per-transfer software overhead** (framework/driver cost the paper
//!   calls "framework-induced overheads and unmodeled operating system
//!   effects" that make exact prediction difficult).
//!
//! With both knobs set to zero the simulator converges to the ILP's
//! idealised model, which the property tests exploit.
//!
//! The simulator is schedule-agnostic: it executes whatever DAG it is
//! handed.  GPipe pipelines become visible to it through
//! [`crate::pipeline::pipeline_dfg`], which unrolls a stage partition into
//! its stage × micro-batch schedule — `SimulatorCost` places that unrolled
//! graph stage-per-device and measures the overlapped makespan, instead of
//! simulating one non-interleaved step and missing the overlap entirely.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::cluster::HwGraph;
use crate::dfg::Dfg;

/// Simulator configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Serialise transfers per link (true = silicon-like).
    pub link_contention: bool,
    /// Fixed software overhead added to every cross-device transfer.
    pub transfer_overhead_s: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { link_contention: true, transfer_overhead_s: 5e-6 }
    }
}

impl SimConfig {
    /// The ILP's idealised world: infinite link capacity, no sw overhead.
    pub fn ideal() -> Self {
        SimConfig { link_contention: false, transfer_overhead_s: 0.0 }
    }
}

/// One cross-device transfer slice on one physical link — the network
/// half of a timeline (`trace` turns these into Perfetto tracks, one per
/// link, alongside the per-device op tracks from `op_start`/`op_finish`).
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    /// Index into `hw.links`.
    pub link: usize,
    /// Producing / consuming op of the DFG edge being moved.
    pub src_op: usize,
    pub dst_op: usize,
    /// Payload bytes.
    pub bytes: f64,
    /// Slice start time on this link (seconds).
    pub start_s: f64,
    /// Slice duration on this link (seconds).
    pub dur_s: f64,
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Makespan of one training step (seconds).
    pub makespan: f64,
    /// Busy seconds per hardware node (devices only).
    pub device_busy: Vec<f64>,
    /// Busy seconds per link.
    pub link_busy: Vec<f64>,
    /// Start time per op.
    pub op_start: Vec<f64>,
    /// Finish time per op.
    pub op_finish: Vec<f64>,
    /// Every cross-device transfer slice, in delivery order.
    pub transfers: Vec<Transfer>,
}

impl SimResult {
    /// Mean compute utilization over devices that got work.
    pub fn utilization(&self) -> f64 {
        let used: Vec<f64> = self
            .device_busy
            .iter()
            .copied()
            .filter(|&b| b > 0.0)
            .collect();
        if used.is_empty() || self.makespan == 0.0 {
            return 0.0;
        }
        used.iter().sum::<f64>() / (used.len() as f64 * self.makespan)
    }
}

#[derive(PartialEq)]
struct Ev {
    t: f64,
    kind: EvKind,
}

#[derive(PartialEq, Eq)]
enum EvKind {
    /// Op finished computing on its device.
    OpDone(usize),
    /// Data of edge idx fully arrived at the consumer's device.
    EdgeDone(usize),
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on time (BinaryHeap is a max-heap).
        other.t.partial_cmp(&self.t).unwrap_or(Ordering::Equal)
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one step of `dfg` under `placement` (op -> hardware node index).
///
/// `op_times[k]` is Δ(k) on the assigned device.  Scheduling policy on each
/// device is FIFO over ready ops with critical-path-length priority —
/// matching the back-to-back execution assumption of the ILP (§6
/// assumption 1) while resolving ties deterministically.
pub fn simulate(dfg: &Dfg, hw: &HwGraph, placement: &[usize],
                op_times: &[f64], cfg: SimConfig) -> Result<SimResult> {
    let n = dfg.n_ops();
    if placement.len() != n || op_times.len() != n {
        bail!("placement/op_times length mismatch");
    }
    for &d in placement {
        if d >= hw.nodes.len() || !hw.nodes[d].is_compute {
            bail!("placement references non-compute node {d}");
        }
    }
    let preds = dfg.predecessors();
    // Priority = downstream critical-path length (classic HLFET list sched).
    let topo = dfg.topo_order()?;
    let succs = dfg.successors();
    let mut prio = vec![0.0f64; n];
    for &v in topo.iter().rev() {
        let down = succs[v]
            .iter()
            .map(|&s| prio[s])
            .fold(0.0f64, f64::max);
        prio[v] = op_times[v] + down;
    }

    let mut pending_inputs: Vec<usize> = (0..n).map(|i| {
        // Count inputs: same-device edges deliver at pred completion;
        // cross-device edges deliver at transfer completion. Both are
        // counted; completion events decrement.
        preds[i].len()
    }).collect();

    let mut ready: Vec<Vec<usize>> = vec![Vec::new(); hw.nodes.len()];
    let mut device_free = vec![0.0f64; hw.nodes.len()];
    let mut device_busy = vec![0.0f64; hw.nodes.len()];
    let mut link_free = vec![0.0f64; hw.links.len()];
    let mut link_busy = vec![0.0f64; hw.links.len()];
    let mut transfers: Vec<Transfer> = Vec::new();
    let mut op_start = vec![f64::NAN; n];
    let mut op_finish = vec![f64::NAN; n];
    let mut started = vec![false; n];

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    for i in 0..n {
        if pending_inputs[i] == 0 {
            ready[placement[i]].push(i);
        }
    }

    // Edge bookkeeping: for each op, list of out-edge indices.
    let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (ei, e) in dfg.edges.iter().enumerate() {
        out_edges[e.src].push(ei);
    }

    let mut now = 0.0f64;
    let mut completed = 0usize;

    //

    macro_rules! dispatch {
        ($dev:expr) => {{
            let dev = $dev;
            // Start the highest-priority ready op if the device is free.
            if !ready[dev].is_empty() && device_free[dev] <= now {
                ready[dev].sort_by(|&a, &b| {
                    prio[b].partial_cmp(&prio[a]).unwrap()
                        .then(a.cmp(&b))
                });
                let op = ready[dev].remove(0);
                debug_assert!(!started[op]);
                started[op] = true;
                op_start[op] = now;
                let t_done = now + op_times[op];
                device_free[dev] = t_done;
                device_busy[dev] += op_times[op];
                heap.push(Ev { t: t_done, kind: EvKind::OpDone(op) });
            }
        }};
    }

    for dev in 0..hw.nodes.len() {
        dispatch!(dev);
    }

    while let Some(ev) = heap.pop() {
        now = ev.t;
        match ev.kind {
            EvKind::OpDone(op) => {
                op_finish[op] = now;
                completed += 1;
                // Deliver outputs.
                for &ei in &out_edges[op] {
                    let e = dfg.edges[ei];
                    let (src_d, dst_d) = (placement[e.src], placement[e.dst]);
                    if src_d == dst_d {
                        heap.push(Ev { t: now, kind: EvKind::EdgeDone(ei) });
                    } else {
                        let (route_t, path) = hw.route(src_d, dst_d, e.bytes)?;
                        let mut t = now + cfg.transfer_overhead_s;
                        if cfg.link_contention {
                            // Serialise on each link along the path.
                            for li in &path {
                                let l = hw.links[*li];
                                let xfer = e.bytes / l.bandwidth + l.latency;
                                let start = t.max(link_free[*li]);
                                link_free[*li] = start + xfer;
                                link_busy[*li] += xfer;
                                transfers.push(Transfer {
                                    link: *li,
                                    src_op: e.src,
                                    dst_op: e.dst,
                                    bytes: e.bytes,
                                    start_s: start,
                                    dur_s: xfer,
                                });
                                t = start + xfer;
                            }
                        } else {
                            // Store-and-forward slices for the timeline,
                            // uncontended: each hop starts when the
                            // previous one ends.
                            let mut hop = t;
                            for li in &path {
                                let l = hw.links[*li];
                                let xfer = e.bytes / l.bandwidth + l.latency;
                                link_busy[*li] += xfer;
                                transfers.push(Transfer {
                                    link: *li,
                                    src_op: e.src,
                                    dst_op: e.dst,
                                    bytes: e.bytes,
                                    start_s: hop,
                                    dur_s: xfer,
                                });
                                hop += xfer;
                            }
                            t += route_t;
                        }
                        heap.push(Ev { t, kind: EvKind::EdgeDone(ei) });
                    }
                }
                dispatch!(placement[op]);
            }
            EvKind::EdgeDone(ei) => {
                let dst = dfg.edges[ei].dst;
                pending_inputs[dst] -= 1;
                if pending_inputs[dst] == 0 {
                    ready[placement[dst]].push(dst);
                    dispatch!(placement[dst]);
                }
            }
        }
        // A device may have become free exactly now with queued ready work.
        for dev in 0..hw.nodes.len() {
            dispatch!(dev);
        }
    }

    if completed != n {
        bail!("deadlock: only {completed}/{n} ops completed");
    }
    let makespan = op_finish.iter().fold(0.0f64, |a, &b| a.max(b));
    Ok(SimResult {
        makespan,
        device_busy,
        link_busy,
        op_start,
        op_finish,
        transfers,
    })
}

/// Execute one bucketed-overlap DP step as a DAG (the cross-check behind
/// `crate::parallel::overlap`): the analytic closed form
/// `T_k = max(C + c_k, (C − w) + w/k + k·c_k)` is a pipeline recursion
/// `f_i = max(f_{i−1}, r_i) + c_k`, and this function *runs* that
/// pipeline through the discrete-event machinery instead of evaluating
/// the formula — `tests/integration_overlap.rs` asserts the two agree.
///
/// Construction, on the first two compute devices of `hw`:
///
/// * `fwd` on device 0: the pre-window compute `C − w`;
/// * `bwd_i` (i = 1..=k) on device 0, chained: the hiding window in `k`
///   equal slices — bucket i's gradients are ready when `bwd_i` finishes;
/// * `ar_i` on device 1 with op time `c_k`: bucket i's all-reduce.  One
///   compute resource runs them back-to-back — the same
///   one-network-resource serialisation the closed form assumes.
///
/// The `bwd_i → ar_i` edges carry **zero** bytes: `c_k` already prices
/// the whole collective, so the only extra cost a cross-device edge adds
/// is `cfg.transfer_overhead_s` plus the hop latency — the µs-scale
/// discrepancy the integration test's tolerance documents.
pub fn simulate_bucketed_overlap(hw: &HwGraph, compute_s: f64,
                                 buckets: usize, bucket_cost_s: f64,
                                 window_s: f64, cfg: SimConfig)
                                 -> Result<SimResult> {
    if buckets == 0 {
        bail!("bucketed overlap needs at least one bucket");
    }
    if !(compute_s.is_finite() && window_s.is_finite()
         && bucket_cost_s.is_finite())
        || compute_s < 0.0
        || bucket_cost_s < 0.0
        || window_s < 0.0
        || window_s > compute_s
    {
        bail!("bad bucketed-overlap parameters: compute {compute_s}, \
               window {window_s}, bucket cost {bucket_cost_s}");
    }
    let devs = hw.devices();
    if devs.len() < 2 {
        bail!("bucketed overlap needs two compute devices (worker + \
               network stand-in), topology '{}' has {}",
              hw.name, devs.len());
    }
    let (worker, wire) = (devs[0], devs[1]);
    let mut g = Dfg::new("bucketed-overlap");
    let mut placement = Vec::new();
    let mut times = Vec::new();
    let fwd = g.add_op("fwd", 0.0, 0.0, 0.0);
    placement.push(worker);
    times.push(compute_s - window_s);
    let mut prev = fwd;
    for i in 1..=buckets {
        let bwd = g.add_op(&format!("bwd{i}"), 0.0, 0.0, 0.0);
        placement.push(worker);
        times.push(window_s / buckets as f64);
        g.add_edge_bytes(prev, bwd, 0.0);
        let ar = g.add_op(&format!("ar{i}"), 0.0, 0.0, 0.0);
        placement.push(wire);
        times.push(bucket_cost_s);
        g.add_edge_bytes(bwd, ar, 0.0);
        prev = bwd;
    }
    simulate(&g, hw, &placement, &times, cfg)
}

/// Convenience: simulate with Δ(k) derived from device FLOP rates.
pub fn simulate_auto(dfg: &Dfg, hw: &HwGraph, placement: &[usize],
                     launch_overhead_s: f64, cfg: SimConfig)
                     -> Result<SimResult> {
    let times: Vec<f64> = dfg
        .ops
        .iter()
        .enumerate()
        .map(|(i, o)| {
            o.flops / hw.nodes[placement[i]].flops_per_sec + launch_overhead_s
        })
        .collect();
    simulate(dfg, hw, placement, &times, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dgx1;

    fn diamond() -> Dfg {
        let mut g = Dfg::new("d");
        let a = g.add_op("a", 1e9, 4e6, 1.0);
        let b = g.add_op("b", 2e9, 4e6, 1.0);
        let c = g.add_op("c", 2e9, 4e6, 1.0);
        let d = g.add_op("d", 1e9, 4e6, 1.0);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn single_device_is_serial() {
        let g = diamond();
        let hw = dgx1(1);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        let r = simulate(&g, &hw, &[0, 0, 0, 0], &times,
                         SimConfig::ideal()).unwrap();
        assert!((r.makespan - 6.0).abs() < 1e-9);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn two_devices_overlap_branches() {
        let g = diamond();
        let hw = dgx1(2);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        // b on dev1, rest on dev0; ideal comm => cp-limited 4.0 + tiny xfer.
        let r = simulate(&g, &hw, &[0, 1, 0, 0], &times,
                         SimConfig::ideal()).unwrap();
        let xfer = 4e6 / 25e9 + 1.3e-6;
        assert!((r.makespan - (4.0 + 2.0 * xfer)).abs() < 1e-6,
                "makespan {}", r.makespan);
    }

    #[test]
    fn contention_never_faster_than_ideal() {
        let g = diamond();
        let hw = dgx1(2);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        for placement in [[0, 1, 0, 0], [0, 0, 1, 1], [1, 0, 1, 0]] {
            let ideal = simulate(&g, &hw, &placement, &times,
                                 SimConfig::ideal()).unwrap();
            let real = simulate(&g, &hw, &placement, &times,
                                SimConfig::default()).unwrap();
            assert!(real.makespan >= ideal.makespan - 1e-12);
        }
    }

    #[test]
    fn dependencies_respected() {
        let g = diamond();
        let hw = dgx1(4);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        let r = simulate(&g, &hw, &[0, 1, 2, 3], &times,
                         SimConfig::default()).unwrap();
        for e in &g.edges {
            assert!(r.op_start[e.dst] >= r.op_finish[e.src] - 1e-12,
                    "edge {:?} violated", e);
        }
    }

    #[test]
    fn chain_gains_nothing_from_more_devices() {
        let mut g = Dfg::new("chain");
        let mut prev = g.add_op("op0", 1e9, 1e6, 1.0);
        for i in 1..6 {
            let cur = g.add_op(&format!("op{}", i), 1e9, 1e6, 1.0);
            g.add_edge(prev, cur);
            prev = cur;
        }
        let hw = dgx1(4);
        let t = vec![1.0; 6];
        let one = simulate(&g, &hw, &[0; 6], &t, SimConfig::ideal()).unwrap();
        let spread = simulate(&g, &hw, &[0, 1, 2, 3, 0, 1], &t,
                              SimConfig::ideal()).unwrap();
        assert!(spread.makespan >= one.makespan, "chain can't speed up");
    }

    #[test]
    fn bucketed_overlap_executes_the_pipeline_recursion() {
        let hw = dgx1(2);
        let (compute, window, c_k) = (0.09, 0.06, 0.004);
        for k in [1usize, 2, 4, 8] {
            let r = simulate_bucketed_overlap(&hw, compute, k, c_k, window,
                                              SimConfig::ideal())
                .unwrap();
            // Closed form for exactly k buckets; the sim only adds hop
            // latency on the zero-byte ready edges (µs scale).
            let want = (compute + c_k).max(
                (compute - window) + window / k as f64 + k as f64 * c_k);
            assert!((r.makespan - want).abs() < 5e-5,
                    "k={k}: sim {} vs analytic {want}", r.makespan);
        }
        // Serial identity: one bucket is compute + exchange.
        let r = simulate_bucketed_overlap(&hw, compute, 1, c_k, window,
                                          SimConfig::ideal())
            .unwrap();
        assert!((r.makespan - (compute + c_k)).abs() < 5e-5);
        // Loud rejection of malformed schedules and 1-device topologies.
        assert!(simulate_bucketed_overlap(&dgx1(1), compute, 2, c_k,
                                          window, SimConfig::ideal())
            .is_err());
        assert!(simulate_bucketed_overlap(&hw, compute, 0, c_k, window,
                                          SimConfig::ideal())
            .is_err());
        assert!(simulate_bucketed_overlap(&hw, 0.01, 2, c_k, 0.02,
                                          SimConfig::ideal())
            .is_err(), "window larger than compute must be rejected");
    }

    #[test]
    fn transfers_record_every_cross_device_slice() {
        let g = diamond();
        let hw = dgx1(2);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        for cfg in [SimConfig::ideal(), SimConfig::default()] {
            let r = simulate(&g, &hw, &[0, 1, 0, 0], &times, cfg).unwrap();
            // Two cross-device edges (a->b, b->d), each at least one hop.
            assert!(r.transfers.len() >= 2, "{} slices", r.transfers.len());
            let sliced: f64 = r.transfers.iter().map(|t| t.dur_s).sum();
            let busy: f64 = r.link_busy.iter().sum();
            assert!((sliced - busy).abs() < 1e-12,
                    "slices must account exactly for link busy time");
            for t in &r.transfers {
                assert!(t.link < hw.links.len());
                assert!(t.start_s >= 0.0 && t.dur_s > 0.0);
                assert!(t.start_s + t.dur_s <= r.makespan + 1e-9,
                        "slices live inside the step");
            }
        }
        // Same-device placement moves nothing.
        let r = simulate(&g, &hw, &[0, 0, 0, 0], &times,
                         SimConfig::default())
            .unwrap();
        assert!(r.transfers.is_empty());
    }

    #[test]
    fn rejects_bad_placement() {
        let g = diamond();
        let hw = dgx1(2);
        assert!(simulate(&g, &hw, &[0, 0, 0, 9], &[1.0; 4],
                         SimConfig::default()).is_err());
        assert!(simulate(&g, &hw, &[0, 0], &[1.0; 4],
                         SimConfig::default()).is_err());
    }

    #[test]
    fn busy_times_account() {
        let g = diamond();
        let hw = dgx1(2);
        let times = vec![1.0, 2.0, 2.0, 1.0];
        let r = simulate(&g, &hw, &[0, 1, 0, 0], &times,
                         SimConfig::default()).unwrap();
        assert!((r.device_busy[0] - 4.0).abs() < 1e-9);
        assert!((r.device_busy[1] - 2.0).abs() < 1e-9);
        assert!(r.link_busy.iter().sum::<f64>() > 0.0);
    }
}
