//! Crate-wide tracing: a std-only span recorder with a Chrome
//! trace-event / Perfetto JSON writer.
//!
//! Everything the planner asserts about a strategy — GPipe fill/drain
//! bubbles, bucketed-overlap exchange slices, per-phase service latency —
//! is a claim about *where time goes inside a step*.  This module records
//! those claims as spans and serialises them in the Chrome trace-event
//! format (the `{"traceEvents":[...]}` JSON that <https://ui.perfetto.dev>
//! and `chrome://tracing` open directly), so every verdict in `docs/` can
//! be inspected on a timeline instead of trusted as a scalar.
//!
//! Design constraints, in order:
//!
//! * **no dependencies** — plain `std`, serialised through
//!   [`crate::util::json`];
//! * **deterministic** — time comes from an injected [`TraceClock`], not
//!   from ambient `Instant::now()`.  Under [`TraceClock::virtual_clock`]
//!   (or explicit-timestamp recording, the simulator path) two identical
//!   runs produce byte-identical documents, which
//!   `tests/integration_trace.rs` exploits to byte-compare timelines;
//! * **cheap** — spans append to a `Vec` behind one mutex; scoped spans
//!   keep their nesting on a thread-local stack so recording a child span
//!   costs no allocation beyond its name.
//!
//! Three producers feed it:
//!
//! 1. the **simulator** ([`crate::sim::simulate`] exposes per-op
//!    start/finish times and per-link transfer slices; `planner::timeline`
//!    converts them into one track per device + one per network resource);
//! 2. the **planner** (`plan --trace-out timeline.json`,
//!    `sweep --trace-dir DIR`);
//! 3. the **service** (request-scoped phase spans surface as `/metrics`
//!    histograms, the JSON-lines access log, and `GET /debug/trace`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// The time source a [`TraceRecorder`] stamps scoped spans with.
///
/// A wall clock anchors at its creation instant; a virtual clock is an
/// explicit microsecond counter the *caller* advances, so a recording is
/// a pure function of the calls made against it — the property the
/// byte-compare tests depend on.
#[derive(Debug)]
pub enum TraceClock {
    /// Monotonic wall time, microseconds since recorder creation.
    Wall(Instant),
    /// Virtual time: an explicit µs counter advanced by the caller.
    Virtual(AtomicU64),
}

impl TraceClock {
    /// A wall clock anchored now.
    pub fn wall() -> Self {
        TraceClock::Wall(Instant::now())
    }

    /// A virtual clock starting at 0 µs.
    pub fn virtual_clock() -> Self {
        TraceClock::Virtual(AtomicU64::new(0))
    }

    /// Current time in microseconds.
    pub fn now_us(&self) -> f64 {
        match self {
            TraceClock::Wall(t0) => t0.elapsed().as_secs_f64() * 1e6,
            TraceClock::Virtual(us) => us.load(Ordering::SeqCst) as f64,
        }
    }

    /// Advance a virtual clock by `us` microseconds (no-op on wall).
    pub fn advance_us(&self, us: u64) {
        if let TraceClock::Virtual(t) = self {
            t.fetch_add(us, Ordering::SeqCst);
        }
    }
}

/// One complete ("X") trace event: a span on track `(pid, tid)`.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub pid: u64,
    pub tid: u64,
    pub name: String,
    /// Start, microseconds.
    pub ts_us: f64,
    /// Duration, microseconds.
    pub dur_us: f64,
    /// Extra `args` rendered into the event (sorted by key on output).
    pub args: Vec<(String, Json)>,
}

#[derive(Default)]
struct Inner {
    events: Vec<TraceEvent>,
    /// pid -> process_name metadata.
    processes: BTreeMap<u64, String>,
    /// (pid, tid) -> thread_name metadata.
    threads: BTreeMap<(u64, u64), String>,
}

thread_local! {
    /// Per-thread stack of open scoped spans: (name, start µs).
    static SPAN_STACK: RefCell<Vec<(String, f64)>> = RefCell::new(Vec::new());
}

/// Span recorder: named tracks + complete events, serialisable as a
/// Chrome trace-event document.
pub struct TraceRecorder {
    clock: TraceClock,
    inner: Mutex<Inner>,
}

impl TraceRecorder {
    pub fn new(clock: TraceClock) -> Self {
        TraceRecorder { clock, inner: Mutex::new(Inner::default()) }
    }

    /// The injected clock (callers advance virtual clocks through this).
    pub fn clock(&self) -> &TraceClock {
        &self.clock
    }

    /// Name the `(pid, tid)` track; emitted as `process_name` /
    /// `thread_name` metadata so Perfetto shows labelled rows.
    pub fn track(&self, pid: u64, process: &str, tid: u64, thread: &str) {
        let mut g = self.inner.lock().unwrap();
        g.processes.entry(pid).or_insert_with(|| process.to_string());
        g.threads
            .entry((pid, tid))
            .or_insert_with(|| thread.to_string());
    }

    /// Record a complete span at an explicit virtual time (the simulator
    /// path: sim timestamps are already deterministic).
    pub fn complete(&self, pid: u64, tid: u64, name: &str, ts_us: f64,
                    dur_us: f64, args: Vec<(String, Json)>) {
        let mut g = self.inner.lock().unwrap();
        g.events.push(TraceEvent {
            pid,
            tid,
            name: name.to_string(),
            ts_us,
            dur_us,
            args,
        });
    }

    /// Open a scoped span stamped by the recorder's clock; the span is
    /// recorded when the guard drops.  Nesting is tracked on a
    /// thread-local stack: a child span's `parent` arg names the
    /// enclosing span, so request span *trees* reconstruct from the flat
    /// event list.
    pub fn scope<'a>(&'a self, pid: u64, tid: u64, name: &str)
                     -> SpanGuard<'a> {
        let start = self.clock.now_us();
        let parent = SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = s.last().map(|(n, _)| n.clone());
            s.push((name.to_string(), start));
            parent
        });
        SpanGuard { rec: self, pid, tid, name: name.to_string(), start,
                    parent }
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialise as a Chrome trace-event JSON value: metadata events
    /// first (track names, sorted by pid/tid), then complete events
    /// sorted by `(pid, tid, ts, -dur, name)` — parents before children,
    /// independent of recording interleaving, so the document is a pure
    /// function of the recorded spans.
    pub fn to_chrome_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut events: Vec<Json> = Vec::new();
        for (pid, name) in &g.processes {
            events.push(meta_event(*pid, 0, "process_name", name));
        }
        for ((pid, tid), name) in &g.threads {
            events.push(meta_event(*pid, *tid, "thread_name", name));
        }
        let mut xs: Vec<&TraceEvent> = g.events.iter().collect();
        xs.sort_by(|a, b| {
            (a.pid, a.tid)
                .cmp(&(b.pid, b.tid))
                .then(a.ts_us.partial_cmp(&b.ts_us).unwrap())
                .then(b.dur_us.partial_cmp(&a.dur_us).unwrap())
                .then(a.name.cmp(&b.name))
        });
        for e in xs {
            let mut o = BTreeMap::new();
            o.insert("ph".to_string(), Json::Str("X".to_string()));
            o.insert("pid".to_string(), Json::Num(e.pid as f64));
            o.insert("tid".to_string(), Json::Num(e.tid as f64));
            o.insert("name".to_string(), Json::Str(e.name.clone()));
            o.insert("ts".to_string(), Json::Num(e.ts_us));
            o.insert("dur".to_string(), Json::Num(e.dur_us));
            if !e.args.is_empty() {
                let mut a = BTreeMap::new();
                for (k, v) in &e.args {
                    a.insert(k.clone(), v.clone());
                }
                o.insert("args".to_string(), Json::Obj(a));
            }
            events.push(Json::Obj(o));
        }
        let mut doc = BTreeMap::new();
        doc.insert("displayTimeUnit".to_string(),
                   Json::Str("ms".to_string()));
        doc.insert("traceEvents".to_string(), Json::Arr(events));
        Json::Obj(doc)
    }

    /// The serialised document: compact sorted-key JSON plus a trailing
    /// newline (same framing as `Plan::to_json_string`).
    pub fn to_chrome_string(&self) -> String {
        let mut s = self.to_chrome_json().to_string();
        s.push('\n');
        s
    }
}

fn meta_event(pid: u64, tid: u64, kind: &str, name: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name.to_string()));
    let mut o = BTreeMap::new();
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("pid".to_string(), Json::Num(pid as f64));
    o.insert("tid".to_string(), Json::Num(tid as f64));
    o.insert("name".to_string(), Json::Str(kind.to_string()));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

/// RAII guard for a scoped span; records the complete event on drop.
pub struct SpanGuard<'a> {
    rec: &'a TraceRecorder,
    pid: u64,
    tid: u64,
    name: String,
    start: f64,
    parent: Option<String>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        SPAN_STACK.with(|s| {
            s.borrow_mut().pop();
        });
        let end = self.rec.clock.now_us();
        let args = match &self.parent {
            Some(p) => vec![("parent".to_string(), Json::Str(p.clone()))],
            None => Vec::new(),
        };
        self.rec.complete(self.pid, self.tid, &self.name, self.start,
                          (end - self.start).max(0.0), args);
    }
}

/// Fixed pid for device (compute) tracks in planner timelines.
pub const PID_DEVICES: u64 = 1;
/// Fixed pid for network-resource (link / collective) tracks.
pub const PID_NETWORK: u64 = 2;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_spans_are_deterministic() {
        let doc = |_: usize| {
            let rec = TraceRecorder::new(TraceClock::virtual_clock());
            rec.track(PID_DEVICES, "devices", 0, "dev0");
            {
                let _outer = rec.scope(PID_DEVICES, 0, "step");
                rec.clock().advance_us(10);
                {
                    let _inner = rec.scope(PID_DEVICES, 0, "forward");
                    rec.clock().advance_us(30);
                }
                rec.clock().advance_us(5);
            }
            rec.to_chrome_string()
        };
        let a = doc(0);
        let b = doc(1);
        assert_eq!(a, b, "virtual-clock recordings must byte-compare");
        assert!(a.ends_with('\n'));
        let j = Json::parse(a.trim_end()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 spans.
        assert_eq!(evs.len(), 4);
    }

    #[test]
    fn scoped_spans_record_nesting_and_durations() {
        let rec = TraceRecorder::new(TraceClock::virtual_clock());
        {
            let _outer = rec.scope(1, 7, "request");
            rec.clock().advance_us(100);
            {
                let _inner = rec.scope(1, 7, "plan");
                rec.clock().advance_us(40);
            }
        }
        let j = rec.to_chrome_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .collect();
        assert_eq!(spans.len(), 2);
        // Sorted parent-first: equal-or-earlier ts, longer dur wins.
        assert_eq!(spans[0].get("name").unwrap().as_str().unwrap(),
                   "request");
        assert_eq!(spans[0].get("dur").unwrap().as_f64().unwrap(), 140.0);
        assert_eq!(spans[1].get("name").unwrap().as_str().unwrap(), "plan");
        assert_eq!(spans[1].get("ts").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(spans[1].get("dur").unwrap().as_f64().unwrap(), 40.0);
        assert_eq!(spans[1]
                       .get("args")
                       .unwrap()
                       .get("parent")
                       .unwrap()
                       .as_str()
                       .unwrap(),
                   "request");
    }

    #[test]
    fn explicit_complete_events_sort_by_track_then_time() {
        let rec = TraceRecorder::new(TraceClock::virtual_clock());
        rec.track(PID_NETWORK, "network", 3, "link3");
        rec.track(PID_DEVICES, "devices", 1, "dev1");
        // Recorded out of order on purpose.
        rec.complete(PID_NETWORK, 3, "xfer", 50.0, 10.0, vec![]);
        rec.complete(PID_DEVICES, 1, "b", 20.0, 5.0, vec![]);
        rec.complete(PID_DEVICES, 1, "a", 0.0, 20.0, vec![]);
        let j = rec.to_chrome_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["a", "b", "xfer"]);
        // Metadata rows precede span rows.
        assert_eq!(evs[0].get("ph").unwrap().as_str().unwrap(), "M");
    }

    #[test]
    fn wall_clock_monotone() {
        let c = TraceClock::wall();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
        c.advance_us(1_000_000); // no-op on wall clocks
        assert!(c.now_us() < 60.0 * 1e6);
    }
}
