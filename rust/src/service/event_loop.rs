//! The readiness-polled event loop behind the planner daemon.
//!
//! One thread owns the listener and every connection; a small worker
//! pool runs planner evaluations.  The split is the classic
//! reactor-plus-executor shape (`mio`-style), built on nothing but
//! non-blocking `std` sockets:
//!
//! * the **loop thread** accepts, reads request bytes into per-connection
//!   buffers, parses incrementally ([`http::try_parse_request`]),
//!   answers the cheap `GET` endpoints inline, and hands `POST
//!   /plan`/`/sweep` bodies to the workers.  It also owns every write:
//!   completed responses queue on the connection and drain as the
//!   socket accepts them, so a slow reader never parks a worker;
//! * the **worker threads** only ever compute: a plan evaluation
//!   (through the single-flight cache) or a sweep stream.  Sweep bytes
//!   flow back to the loop through a bounded high-water-mark gate
//!   ([`ConnGate`]) — if the client cannot drain the stream, the worker
//!   waits instead of buffering without bound, and cancels outright if
//!   the client is gone.
//!
//! Without `epoll` (std-only), readiness is emulated by polling:
//! non-blocking reads/writes that return `WouldBlock` cost one syscall,
//! and connections idle for more than [`COLD_AFTER`] are only polled on
//! the [`FULL_SCAN_EVERY`] cadence, so a large keep-alive herd costs
//! O(conns) syscalls per *scan interval*, not per tick.  The loop
//! sleeps on the completion channel when nothing is ready, so worker
//! results still wake it instantly.
//!
//! Production-traffic policies, all surfaced in `/metrics`:
//!
//! * **keep-alive** — HTTP/1.1 connections persist across requests
//!   (`Connection: close`, parse failures, timeouts and chunked sweep
//!   responses close);
//! * **admission control** — when [`ServiceOptions::max_pending`]
//!   planner jobs are outstanding, new `POST`s are refused with a 503 +
//!   `Retry-After` instead of queueing without bound; past
//!   [`ServiceOptions::max_connections`], new connections get the same
//!   treatment;
//! * **per-request deadlines** — a head that does not complete within
//!   [`ServiceOptions::head_timeout`] is a 408 (slow-loris defence); a
//!   connection idle *between* requests past
//!   [`ServiceOptions::idle_timeout`] is closed silently; a client that
//!   stops reading its response for [`WRITE_STALL`] is dropped.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::http::{self, ParseStatus};
use super::{error_body, PlanPhases, PlannerService, ServiceOptions,
            SweepOutcome, CONTENT_JSON, CONTENT_PROM};

/// New connections accepted per tick (bounds time-to-first-read under
/// an accept storm).
const ACCEPT_BATCH: usize = 128;
/// Per-connection bytes read per tick (fairness under pipelining).
const READ_BATCH: usize = 64 * 1024;
/// Hard cap on a connection's unparsed input: one maximal request head
/// plus body, with slack for pipelined follow-ups.  Reads pause (TCP
/// backpressure) once the buffer is full.
const IN_BUF_CAP: usize = http::MAX_HEAD_BYTES + http::MAX_BODY_BYTES + 4096;
/// High-water mark on sweep bytes in flight between a worker and the
/// socket; past it the worker waits for the client to drain.
const STREAM_HIGH_WATER: usize = 1024 * 1024;
/// A connection with no pending work that has been quiet this long is
/// "cold": it is only polled on the full-scan cadence.
const COLD_AFTER: Duration = Duration::from_millis(500);
/// Cold connections and timeouts are scanned this often.
const FULL_SCAN_EVERY: Duration = Duration::from_millis(25);
/// Drop a connection whose response bytes have made no progress into
/// the socket for this long (client stopped reading).
const WRITE_STALL: Duration = Duration::from_secs(30);
/// Idle-sleep granularity when no socket and no completion is ready.
const IDLE_TICK: Duration = Duration::from_millis(1);
/// Cache snapshot cadence when persistence is configured.
const PERSIST_EVERY: Duration = Duration::from_secs(60);

/// Shared flow-control state between the loop and one sweep-streaming
/// worker.  `alive` flips off when the connection dies so the worker
/// cancels its sweep; `buffered` approximates the stream bytes the
/// loop has not yet written to the socket.
pub(super) struct ConnGate {
    alive: AtomicBool,
    buffered: AtomicUsize,
}

impl ConnGate {
    fn new() -> Self {
        ConnGate { alive: AtomicBool::new(true),
                   buffered: AtomicUsize::new(0) }
    }
}

/// Work handed from the loop to the worker pool.  `rid` is the
/// request's `X-Request-Id` (echoed on the chunked sweep head, which
/// the worker encodes itself).
enum Job {
    Plan { conn: u64, body: Vec<u8> },
    Sweep { conn: u64, body: Vec<u8>, gate: Arc<ConnGate>, rid: String },
}

/// Results handed back from workers to the loop (which owns all
/// sockets, so it alone encodes connection framing and writes).
enum Completion {
    /// A complete fixed-length response body (`phases` carries the
    /// plan-handler timings into the access log and debug ring).
    Respond {
        conn: u64,
        endpoint: &'static str,
        code: u16,
        body: Arc<String>,
        phases: Option<PlanPhases>,
    },
    /// Pre-encoded wire bytes of a chunked sweep stream.
    StreamBytes { conn: u64, bytes: Vec<u8> },
    /// The sweep finished (or died mid-stream); record and close.
    StreamDone { conn: u64, code: u16 },
}

enum ConnState {
    /// Reading/parsing; no request outstanding.
    Idle,
    /// A `POST /plan` is with a worker; reads pause until it answers.
    Busy,
    /// A `POST /sweep` is streaming through this connection.
    Streaming,
}

struct Conn {
    stream: TcpStream,
    state: ConnState,
    in_buf: Vec<u8>,
    /// Encoded response bytes awaiting the socket, from `out_pos`.
    out: Vec<u8>,
    out_pos: usize,
    /// Sweep wire bytes admitted from the worker but not yet moved to
    /// `out` (kept separate so `out` stays bounded by the high-water
    /// mark).
    pending_stream: VecDeque<Vec<u8>>,
    stream_done: bool,
    gate: Arc<ConnGate>,
    /// Set when the first byte of a request head arrives; cleared when
    /// its response is queued.  Drives both the head deadline and the
    /// latency histograms.
    req_start: Option<Instant>,
    last_activity: Instant,
    last_write_progress: Instant,
    requests_served: u64,
    /// Whether the in-flight worker response may keep the connection.
    keep_alive: bool,
    close_after_flush: bool,
    read_eof: bool,
    /// `X-Request-Id` of the request in flight: the client's own header
    /// echoed, or a generated id.  Empty until a request line parses.
    request_id: String,
}

impl Conn {
    fn new(stream: TcpStream, now: Instant) -> Self {
        Conn {
            stream,
            state: ConnState::Idle,
            in_buf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            pending_stream: VecDeque::new(),
            stream_done: false,
            gate: Arc::new(ConnGate::new()),
            req_start: None,
            last_activity: now,
            last_write_progress: now,
            requests_served: 0,
            keep_alive: true,
            close_after_flush: false,
            read_eof: false,
            request_id: String::new(),
        }
    }

    fn has_backlog(&self) -> bool {
        self.out_pos < self.out.len() || !self.pending_stream.is_empty()
    }

    /// Cold connections are parked keep-alives: nothing buffered in
    /// either direction, no request in flight, quiet for a while.
    fn is_cold(&self, now: Instant) -> bool {
        matches!(self.state, ConnState::Idle)
            && !self.has_backlog()
            && self.in_buf.is_empty()
            && now.duration_since(self.last_activity) > COLD_AFTER
    }

    /// Queue a complete response and the resulting connection fate.
    /// Every response echoes the request's `X-Request-Id`.
    fn push_response(&mut self, code: u16, content_type: &str, body: &[u8],
                     keep_alive: bool, extra: &[(&str, &str)]) {
        let mut headers: Vec<(&str, &str)> = extra.to_vec();
        if !self.request_id.is_empty() {
            headers.push(("X-Request-Id", self.request_id.as_str()));
        }
        self.out.extend_from_slice(&http::encode_response(
            code, content_type, body, keep_alive, &headers));
        if !keep_alive {
            self.close_after_flush = true;
        }
        self.requests_served += 1;
        self.req_start = None;
        self.state = ConnState::Idle;
    }
}

fn saturating_sub(counter: &AtomicUsize, n: usize) {
    let _ = counter.fetch_update(Ordering::Relaxed, Ordering::Relaxed,
                                 |v| Some(v.saturating_sub(n)));
}

/// Send pre-encoded sweep bytes toward the loop, honouring the
/// high-water mark.  Fails once the client (or the loop) is gone — the
/// error propagates into `stream_sweep`'s sink and cancels the sweep.
fn send_stream_bytes(gate: &ConnGate, done: &mpsc::Sender<Completion>,
                     conn: u64, bytes: Vec<u8>) -> Result<()> {
    loop {
        if !gate.alive.load(Ordering::Relaxed) {
            bail!("client disconnected mid-stream");
        }
        if gate.buffered.load(Ordering::Relaxed) <= STREAM_HIGH_WATER {
            break;
        }
        std::thread::sleep(IDLE_TICK);
    }
    gate.buffered.fetch_add(bytes.len(), Ordering::Relaxed);
    done.send(Completion::StreamBytes { conn, bytes })
        .map_err(|_| anyhow!("event loop stopped"))
}

/// One request-worker: pull jobs, compute, post completions.  Exits
/// when the loop drops the job channel.
fn run_worker(service: Arc<PlannerService>,
              jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
              done: mpsc::Sender<Completion>) {
    loop {
        // Hold the receiver lock only for the dequeue.
        let job = jobs.lock().unwrap().recv();
        let Ok(job) = job else { break };
        match job {
            Job::Plan { conn, body } => {
                let (code, doc, phases) = service.handle_plan_timed(&body);
                service.stats().queue_depth.dec();
                if done
                    .send(Completion::Respond {
                        conn, endpoint: "plan", code, body: doc,
                        phases: Some(phases) })
                    .is_err()
                {
                    break;
                }
            }
            Job::Sweep { conn, body, gate, rid } => {
                let mut first = true;
                let mut emit = |payload: &[u8]| -> Result<()> {
                    let mut bytes = Vec::new();
                    if first {
                        first = false;
                        bytes.extend_from_slice(&http::encode_chunked_head(
                            200, CONTENT_JSON,
                            &[("X-Request-Id", rid.as_str())]));
                    }
                    bytes.extend_from_slice(&http::encode_chunk(payload));
                    send_stream_bytes(&gate, &done, conn, bytes)
                };
                let outcome = service.respond_sweep(&body, &mut emit);
                service.stats().queue_depth.dec();
                let sent = match outcome {
                    SweepOutcome::Plain { code, body } => done
                        .send(Completion::Respond {
                            conn, endpoint: "sweep", code, body,
                            phases: None })
                        .is_ok(),
                    SweepOutcome::Streamed { code } => {
                        if code == 200 {
                            let _ = send_stream_bytes(
                                &gate, &done, conn,
                                http::CHUNK_END.to_vec());
                        }
                        done.send(Completion::StreamDone { conn, code })
                            .is_ok()
                    }
                };
                if !sent {
                    break;
                }
            }
        }
    }
}

/// The event loop proper.  Runs on the calling thread until `shutdown`
/// flips; owns the listener, every connection, and (via
/// [`ServiceOptions::persist_path`]) the periodic cache snapshot.
pub(super) fn serve_event_loop(listener: &TcpListener,
                               service: &Arc<PlannerService>,
                               opts: &ServiceOptions,
                               shutdown: &AtomicBool) -> Result<()> {
    listener.set_nonblocking(true)?;
    let n_workers = if opts.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        opts.threads
    }
    .max(1);
    let max_pending = opts.max_pending.max(1);

    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let service = service.clone();
            let jobs = job_rx.clone();
            let done = done_tx.clone();
            std::thread::spawn(move || run_worker(service, jobs, done))
        })
        .collect();

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut last_full_scan = Instant::now();
    let mut last_persist = Instant::now();
    let stats = service.stats();

    while !shutdown.load(Ordering::Relaxed) {
        let now = Instant::now();
        let mut progress = false;

        // --- worker completions --------------------------------------
        while let Ok(c) = done_rx.try_recv() {
            progress = true;
            handle_completion(&mut conns, c, service);
        }

        // --- accept --------------------------------------------------
        for _ in 0..ACCEPT_BATCH {
            match listener.accept() {
                Ok((mut stream, _)) => {
                    progress = true;
                    if conns.len() >= opts.max_connections.max(1) {
                        // Best-effort shed: the daemon is at its
                        // connection cap, tell the client to back off.
                        stats.rejected.inc();
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.write_all(&http::encode_response(
                            503, CONTENT_JSON,
                            error_body("connection limit reached")
                                .as_bytes(),
                            false, &[("Retry-After", "1")]));
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    next_id += 1;
                    conns.insert(next_id, Conn::new(stream, now));
                    stats.connections.inc();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break, // client reset mid-handshake
            }
        }

        // --- per-connection I/O --------------------------------------
        let full_scan =
            now.duration_since(last_full_scan) >= FULL_SCAN_EVERY;
        if full_scan {
            last_full_scan = now;
        }
        let ids: Vec<u64> = conns.keys().copied().collect();
        let mut dead: Vec<u64> = Vec::new();
        for id in ids {
            let conn = conns.get_mut(&id).expect("ids snapshot is live");
            if !full_scan && conn.is_cold(now) {
                continue;
            }
            if tick_conn(conn, id, service, opts, &job_tx, max_pending,
                         now, &mut progress)
                .is_err()
            {
                dead.push(id);
            }
        }
        for id in dead {
            remove_conn(&mut conns, id, service);
        }

        // --- cache persistence ---------------------------------------
        if let Some(path) = &opts.persist_path {
            if now.duration_since(last_persist) >= PERSIST_EVERY {
                last_persist = now;
                if let Err(e) = service.cache().persist(path) {
                    eprintln!("warning: cache persist failed: {e:#}");
                }
            }
        }

        // --- idle wait -----------------------------------------------
        // Sleep on the completion channel so worker results wake the
        // loop instantly; the timeout keeps shutdown/timeout scans
        // ticking.
        if !progress {
            if let Ok(c) = done_rx.recv_timeout(IDLE_TICK) {
                handle_completion(&mut conns, c, service);
            }
        }
    }

    // Shutdown: cancel in-flight streams, retire the workers, snapshot
    // the cache.
    for (_, conn) in conns.drain() {
        conn.gate.alive.store(false, Ordering::Relaxed);
        stats.connections.dec();
    }
    drop(job_tx);
    drop(done_rx);
    drop(done_tx);
    for w in workers {
        let _ = w.join();
    }
    if let Some(path) = &opts.persist_path {
        if let Err(e) = service.cache().persist(path) {
            eprintln!("warning: cache persist failed: {e:#}");
        }
    }
    Ok(())
}

fn remove_conn(conns: &mut HashMap<u64, Conn>, id: u64,
               service: &PlannerService) {
    if let Some(conn) = conns.remove(&id) {
        conn.gate.alive.store(false, Ordering::Relaxed);
        service.stats().connections.dec();
    }
}

fn handle_completion(conns: &mut HashMap<u64, Conn>, c: Completion,
                     service: &Arc<PlannerService>) {
    match c {
        Completion::Respond { conn, endpoint, code, body, phases } => {
            let Some(cn) = conns.get_mut(&conn) else { return };
            let keep = cn.keep_alive && !cn.close_after_flush;
            record_with(service, cn, endpoint, code, phases);
            cn.push_response(code, CONTENT_JSON, body.as_bytes(), keep, &[]);
        }
        Completion::StreamBytes { conn, bytes } => {
            let Some(cn) = conns.get_mut(&conn) else { return };
            cn.pending_stream.push_back(bytes);
        }
        Completion::StreamDone { conn, code } => {
            let Some(cn) = conns.get_mut(&conn) else { return };
            record(service, cn, "sweep", code);
            cn.requests_served += 1;
            cn.req_start = None;
            cn.stream_done = true;
            // Chunked responses advertise `Connection: close`; a sweep
            // that died before its 200 head was committed has nothing
            // queued and closes through the same flush path.
            cn.close_after_flush = true;
        }
    }
}

fn record(service: &PlannerService, conn: &Conn, endpoint: &'static str,
          code: u16) {
    record_with(service, conn, endpoint, code, None);
}

/// [`record`], threading plan-phase timings through to the access log
/// and the `/debug/trace` ring.
fn record_with(service: &PlannerService, conn: &Conn,
               endpoint: &'static str, code: u16,
               phases: Option<PlanPhases>) {
    let elapsed = conn
        .req_start
        .map(|t| t.elapsed().as_secs_f64())
        .unwrap_or(0.0);
    service.record_request(endpoint, code, elapsed);
    service.log_request(&conn.request_id, endpoint, code, elapsed, phases);
}

/// Advance one connection: admit stream bytes, write, read, parse,
/// dispatch, and (on full scans) enforce deadlines.  `Err` means the
/// connection is finished — flushed-and-closing or dead.
#[allow(clippy::too_many_arguments)]
fn tick_conn(conn: &mut Conn, id: u64, service: &Arc<PlannerService>,
             opts: &ServiceOptions, job_tx: &mpsc::Sender<Job>,
             max_pending: usize, now: Instant, progress: &mut bool)
             -> std::result::Result<(), ()> {
    let stats = service.stats();

    // Admit worker stream bytes into the write buffer up to the
    // high-water mark, crediting the gate as they move.
    while conn.out.len() - conn.out_pos < STREAM_HIGH_WATER {
        match conn.pending_stream.pop_front() {
            Some(bytes) => {
                saturating_sub(&conn.gate.buffered, bytes.len());
                conn.out.extend_from_slice(&bytes);
                *progress = true;
            }
            None => break,
        }
    }

    // Drain the write buffer as far as the socket allows.
    while conn.out_pos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => return Err(()),
            Ok(n) => {
                conn.out_pos += n;
                conn.last_write_progress = now;
                conn.last_activity = now;
                *progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
    if conn.out_pos >= conn.out.len() && !conn.out.is_empty() {
        conn.out.clear();
        conn.out_pos = 0;
    }

    let flushed = !conn.has_backlog();
    if flushed {
        if matches!(conn.state, ConnState::Streaming) && conn.stream_done {
            return Err(()); // sweep complete; chunked always closes
        }
        if conn.close_after_flush {
            return Err(());
        }
        if conn.read_eof && conn.in_buf.is_empty() {
            return Err(()); // peer hung up and nothing is owed
        }
    } else if now.duration_since(conn.last_write_progress) >= WRITE_STALL {
        return Err(()); // client stopped reading its response
    }

    // Read while idle (a worker-busy connection gets TCP backpressure
    // instead of an ever-growing pipeline buffer).
    if matches!(conn.state, ConnState::Idle)
        && !conn.read_eof
        && !conn.close_after_flush
        && conn.in_buf.len() < IN_BUF_CAP
    {
        let mut tmp = [0u8; 4096];
        let mut read_this_tick = 0usize;
        loop {
            match conn.stream.read(&mut tmp) {
                Ok(0) => {
                    conn.read_eof = true;
                    break;
                }
                Ok(n) => {
                    conn.in_buf.extend_from_slice(&tmp[..n]);
                    conn.last_activity = now;
                    *progress = true;
                    read_this_tick += n;
                    if read_this_tick >= READ_BATCH
                        || conn.in_buf.len() >= IN_BUF_CAP
                    {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
    }

    // Parse + dispatch at most one request per tick.
    if matches!(conn.state, ConnState::Idle)
        && !conn.close_after_flush
        && !conn.in_buf.is_empty()
    {
        if conn.req_start.is_none() {
            conn.req_start = Some(now);
        }
        match http::try_parse_request(&conn.in_buf) {
            Err(e) => {
                // The byte stream is unrecoverable after a framing
                // error: answer and close.  No parsed head means no
                // client id to echo — mint one so the 400 is traceable.
                conn.request_id = service.next_request_id();
                record(service, conn, "other", 400);
                conn.push_response(400, CONTENT_JSON,
                                   error_body(&format!("{e:#}")).as_bytes(),
                                   false, &[]);
                *progress = true;
            }
            Ok(ParseStatus::NeedMore) => {}
            Ok(ParseStatus::Complete { req, consumed }) => {
                conn.in_buf.drain(..consumed);
                if conn.requests_served > 0 {
                    stats.keepalive_reuses.inc();
                }
                dispatch(conn, id, &req, service, job_tx, max_pending);
                *progress = true;
            }
        }
    }

    // Deadlines (evaluated on every tick this connection is scanned;
    // cold connections see them on the full-scan cadence).
    if matches!(conn.state, ConnState::Idle) && flushed {
        match conn.req_start {
            Some(t0) => {
                if now.duration_since(t0) >= opts.head_timeout {
                    // Slow-loris: the head never completed in time.
                    stats.timeouts.inc();
                    conn.request_id = service.next_request_id();
                    record(service, conn, "other", 408);
                    conn.push_response(
                        408, CONTENT_JSON,
                        error_body("request head timed out").as_bytes(),
                        false, &[]);
                }
            }
            None => {
                if now.duration_since(conn.last_activity)
                    >= opts.idle_timeout
                {
                    return Err(()); // parked keep-alive expired
                }
            }
        }
    }
    Ok(())
}

/// Route one parsed request: cheap `GET`s answer inline on the loop
/// thread; planner work goes to the pool behind admission control.
fn dispatch(conn: &mut Conn, id: u64, req: &http::Request,
            service: &Arc<PlannerService>, job_tx: &mpsc::Sender<Job>,
            max_pending: usize) {
    let stats = service.stats();
    let endpoint = match req.path.as_str() {
        "/plan" => "plan",
        "/sweep" => "sweep",
        "/models" => "models",
        "/topologies" => "topologies",
        "/healthz" => "healthz",
        "/metrics" => "metrics",
        "/debug/trace" => "debug",
        _ => "other",
    };
    let keep = req.wants_keep_alive();
    conn.keep_alive = keep;
    // Echo the client's X-Request-Id, or mint one; every response path
    // below carries it back out.
    conn.request_id = match req.header("x-request-id") {
        Some(v) if !v.is_empty() => v.to_string(),
        _ => service.next_request_id(),
    };
    match (endpoint, req.method.as_str()) {
        (ep @ ("plan" | "sweep"), "POST") => {
            if stats.queue_depth.get() >= max_pending as u64 {
                // Backpressure: refuse instead of queueing unboundedly.
                stats.rejected.inc();
                record(service, conn, ep, 503);
                conn.push_response(
                    503, CONTENT_JSON,
                    error_body("planner queue is full; retry shortly")
                        .as_bytes(),
                    false, &[("Retry-After", "1")]);
                return;
            }
            stats.queue_depth.inc();
            let job = if ep == "plan" {
                conn.state = ConnState::Busy;
                Job::Plan { conn: id, body: req.body.clone() }
            } else {
                conn.state = ConnState::Streaming;
                conn.stream_done = false;
                conn.gate = Arc::new(ConnGate::new());
                Job::Sweep {
                    conn: id,
                    body: req.body.clone(),
                    gate: conn.gate.clone(),
                    rid: conn.request_id.clone(),
                }
            };
            if job_tx.send(job).is_err() {
                // Shutdown race: workers are gone.
                stats.queue_depth.dec();
                record(service, conn, ep, 503);
                conn.push_response(
                    503, CONTENT_JSON,
                    error_body("service is shutting down").as_bytes(),
                    false, &[("Retry-After", "1")]);
            }
        }
        ("models", "GET") => {
            record(service, conn, "models", 200);
            conn.push_response(200, CONTENT_JSON,
                               service.models_doc().as_bytes(), keep, &[]);
        }
        ("topologies", "GET") => {
            record(service, conn, "topologies", 200);
            conn.push_response(200, CONTENT_JSON,
                               service.topologies_doc().as_bytes(), keep,
                               &[]);
        }
        ("healthz", "GET") => {
            record(service, conn, "healthz", 200);
            conn.push_response(200, CONTENT_JSON, b"{\"status\":\"ok\"}\n",
                               keep, &[]);
        }
        ("metrics", "GET") => {
            record(service, conn, "metrics", 200);
            conn.push_response(200, CONTENT_PROM,
                               service.metrics_doc().as_bytes(), keep, &[]);
        }
        ("debug", "GET") => {
            let n = req.query
                .split('&')
                .find_map(|kv| kv.strip_prefix("n="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(32);
            record(service, conn, "debug", 200);
            conn.push_response(200, CONTENT_JSON,
                               service.debug_trace_doc(n).as_bytes(), keep,
                               &[]);
        }
        ("other", _) => {
            record(service, conn, "other", 404);
            conn.push_response(
                404, CONTENT_JSON,
                error_body(&format!(
                    "no endpoint '{}' (known: /plan, /sweep, /models, \
                     /topologies, /healthz, /metrics, /debug/trace)",
                    req.path))
                    .as_bytes(),
                keep, &[]);
        }
        (_, method) => {
            record(service, conn, endpoint, 405);
            conn.push_response(
                405, CONTENT_JSON,
                error_body(&format!("{} does not support {method}",
                                    req.path))
                    .as_bytes(),
                keep, &[]);
        }
    }
}
