//! Consistent-hash sharding for `POST /sweep` fan-out.
//!
//! A sweep grid is a list of scenarios in canonical order; to spread it
//! over N replica daemons the coordinator hashes each scenario's
//! **memo-affinity key** — the fields that feed the sweep engine's
//! `MemoKey` (model, topology, devices, nodes, device memory, batch) —
//! onto a ring of virtual nodes.  Scenarios that share planner work
//! (same model/topology/device point, different overlap or ZeRO
//! spelling) therefore land on the same replica and hit its `MemoCost`
//! memo, and adding or removing a replica only remaps ~1/N of the key
//! space instead of reshuffling everything.
//!
//! Everything is deterministic (FNV-1a, no RNG, no clock): the same
//! replica list and grid always produce the same assignment, which is
//! what makes the sharded sweep's merged output byte-identical to a
//! single-replica run.

use crate::planner::sweep::Scenario;

/// Virtual nodes per replica — enough to smooth the assignment across
/// a handful of replicas without making ring construction noticeable.
const VNODES: usize = 64;

/// 64-bit FNV-1a: tiny, dependency-free, and stable across platforms —
/// exactly what a deterministic ring needs (`std`'s `DefaultHasher` is
/// documented as unstable across releases).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The memo-affinity shard key for one scenario: the axes that change
/// which `MemoKey`s the evaluation touches.  Overlap/compression/ZeRO
/// and the strategy family are deliberately *excluded* — they revisit
/// the same memoised cost evaluations, so keeping them co-located is
/// the whole point.
pub fn shard_key(sc: &Scenario) -> String {
    format!("{}|{}|{}|{}|{}|{}",
            sc.model, sc.topology, sc.devices, sc.nodes,
            sc.device_mem_gb.map(|g| g.to_bits()).unwrap_or(0),
            sc.batch.label())
}

/// A consistent-hash ring over replica names (addresses).
pub struct HashRing {
    /// `(point, replica index)` sorted by point.
    points: Vec<(u64, usize)>,
    replicas: Vec<String>,
}

impl HashRing {
    /// Build a ring with [`VNODES`] virtual nodes per replica.  An
    /// empty replica list yields an empty ring ([`HashRing::owner`]
    /// returns `None`).
    pub fn new(replicas: &[String]) -> Self {
        let mut points = Vec::with_capacity(replicas.len() * VNODES);
        for (i, name) in replicas.iter().enumerate() {
            for v in 0..VNODES {
                points.push((fnv1a(format!("{name}#{v}").as_bytes()), i));
            }
        }
        // Ties (hash collisions between replicas) break toward the
        // lower replica index, deterministically.
        points.sort_unstable();
        HashRing { points, replicas: replicas.to_vec() }
    }

    pub fn replicas(&self) -> &[String] {
        &self.replicas
    }

    /// Index of the replica owning `key`: the first ring point at or
    /// clockwise-after the key's hash.
    pub fn owner_index(&self, key: &str) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = fnv1a(key.as_bytes());
        let at = self.points.partition_point(|&(p, _)| p < h);
        let (_, idx) = self.points[at % self.points.len()];
        Some(idx)
    }

    /// The replica name owning `key`.
    pub fn owner(&self, key: &str) -> Option<&str> {
        self.owner_index(key).map(|i| self.replicas[i].as_str())
    }

    /// Partition scenario indices `0..scenarios.len()` by owning
    /// replica: `result[r]` is the strictly increasing list of global
    /// indices assigned to replica `r`.
    pub fn assign(&self, scenarios: &[Scenario]) -> Vec<Vec<usize>> {
        let mut owned: Vec<Vec<usize>> =
            self.replicas.iter().map(|_| Vec::new()).collect();
        for (i, sc) in scenarios.iter().enumerate() {
            if let Some(r) = self.owner_index(&shard_key(sc)) {
                owned[r].push(i);
            }
        }
        owned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::sweep::SweepSpec;

    fn ring(names: &[&str]) -> HashRing {
        HashRing::new(&names.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn assignment_is_deterministic_and_total() {
        let spec = SweepSpec::default();
        let scenarios = spec.scenarios();
        let r = ring(&["a:1", "b:2", "c:3"]);
        let owned = r.assign(&scenarios);
        assert_eq!(owned.len(), 3);
        let total: usize = owned.iter().map(|v| v.len()).sum();
        assert_eq!(total, scenarios.len(), "every scenario has one owner");
        for indices in &owned {
            assert!(indices.windows(2).all(|w| w[0] < w[1]),
                    "per-replica indices are strictly increasing");
        }
        let again = ring(&["a:1", "b:2", "c:3"]).assign(&scenarios);
        assert_eq!(owned, again, "same ring + grid → same assignment");
    }

    #[test]
    fn memo_affine_scenarios_share_an_owner() {
        // Scenarios differing only in family/overlap/compression/zero
        // hash identically — they share memoised cost evaluations.
        let spec = SweepSpec {
            families: vec![crate::planner::sweep::StrategyFamily::DpOnly,
                           crate::planner::sweep::StrategyFamily::Hybrid],
            overlap: vec![1, 8],
            ..Default::default()
        };
        let scenarios = spec.scenarios();
        let r = ring(&["a:1", "b:2", "c:3", "d:4"]);
        for pair in scenarios.windows(2) {
            if shard_key(&pair[0]) == shard_key(&pair[1]) {
                assert_eq!(r.owner(&shard_key(&pair[0])),
                           r.owner(&shard_key(&pair[1])));
            }
        }
        // And the key really does collapse the non-memo axes.
        let keys: std::collections::HashSet<String> =
            scenarios.iter().map(shard_key).collect();
        assert!(keys.len() < scenarios.len(),
                "family/overlap spellings must share shard keys");
    }

    #[test]
    fn removing_a_replica_only_remaps_its_share() {
        let spec = SweepSpec {
            devices: vec![2, 4, 8, 16, 32, 64, 128, 256],
            ..Default::default()
        };
        let scenarios = spec.scenarios();
        let three = ring(&["a:1", "b:2", "c:3"]);
        let two = ring(&["a:1", "b:2"]);
        let mut moved = 0usize;
        let mut total = 0usize;
        for sc in &scenarios {
            let key = shard_key(sc);
            let before = three.owner(&key).unwrap();
            let after = two.owner(&key).unwrap();
            total += 1;
            if before != "c:3" && before != after {
                moved += 1;
            }
        }
        assert_eq!(moved, 0,
                   "keys not owned by the removed replica must not move \
                    ({moved}/{total} moved)");
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let r = HashRing::new(&[]);
        assert!(r.owner("anything").is_none());
        let spec = SweepSpec::default();
        let owned = r.assign(&spec.scenarios());
        assert!(owned.is_empty());
    }
}
