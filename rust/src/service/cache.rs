//! Single-flight LRU plan cache.
//!
//! The service's `POST /plan` amortisation layer: responses are keyed by
//! the canonicalised request (see
//! [`PlanRequest::canonical_json`](crate::planner::PlanRequest::canonical_json)),
//! so equivalent spellings share one entry, and each entry is an
//! [`OnceLock`] cell — concurrent requests for the same key **coalesce
//! onto one in-flight computation** instead of evaluating the planner
//! N times (the same trick the sweep engine's `MemoCost` uses, lifted
//! to whole responses).
//!
//! Recency is an intrusive doubly-linked LRU list threaded through a
//! slab (`Vec` of nodes addressed by index, plus a free list), so a
//! lookup, an insert and an eviction are all O(1) — no scan over the
//! resident set, which matters once the cache is sized for production
//! traffic rather than a smoke test.  The map lock is held only for
//! lookup/insert/evict, never across a computation.
//!
//! Two guarantees the eviction policy keeps:
//!
//! * **Single-flight survives capacity pressure.**  An entry whose cell
//!   is still being filled is never evicted — eviction walks from the
//!   LRU tail and skips in-flight cells, preferring the stalest
//!   *completed* entry.  If every resident entry is in-flight the cache
//!   runs transiently over capacity (bounded by the number of
//!   concurrent distinct evaluations) and shrinks back on the next
//!   call once fills land.  Evicting an in-flight cell would let the
//!   next identical request launch a second concurrent planner
//!   evaluation — breaking the coalescing guarantee exactly when the
//!   cache is hot.
//! * **Error-served waiters are not hits.**  A coalesced waiter whose
//!   winning computation returned `Err` got a 4xx/5xx body, not a plan;
//!   it is counted under [`error_hits`](PlanCache::error_hits) so the
//!   warm-vs-cold bench ratio and the `/metrics` hit series are not
//!   skewed by cached failures.
//!
//! Completed `Ok` entries can optionally be persisted as JSON lines and
//! reloaded on the next start ([`persist`](PlanCache::persist) /
//! [`load`](PlanCache::load)), so a restart keeps its warm set.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// A finished computation: the response document, or the (deterministic)
/// error text.  Errors are cached like successes — the planner is a pure
/// function of the canonical request, so "unknown model 'alexnet'" today
/// is "unknown model 'alexnet'" tomorrow.
pub type Cached = std::result::Result<Arc<String>, String>;

type Cell = Arc<OnceLock<Cached>>;

/// Slab-index sentinel for "no node".
const NIL: usize = usize::MAX;

/// One LRU node.  `prev`/`next` are slab indices threading the
/// intrusive recency list (head = most recent, tail = stalest).
struct Node {
    key: String,
    cell: Cell,
    prev: usize,
    next: usize,
}

struct State {
    /// Canonical key → slab index.
    map: HashMap<String, usize>,
    /// Node slab; freed slots are recycled via `free`.
    slab: Vec<Node>,
    free: Vec<usize>,
    /// Most-recently-used node, or NIL when empty.
    head: usize,
    /// Least-recently-used node, or NIL when empty.
    tail: usize,
}

impl State {
    /// Detach `idx` from the recency list (it stays in the slab/map).
    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slab[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slab[next].prev = prev;
        }
        self.slab[idx].prev = NIL;
        self.slab[idx].next = NIL;
    }

    /// Link `idx` at the head (most-recently-used) of the recency list.
    fn push_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Move an existing node to the front — the O(1) "touch".
    fn touch(&mut self, idx: usize) {
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
    }

    /// Insert a new entry at the front, returning its slab index.
    fn insert_front(&mut self, key: String, cell: Cell) -> usize {
        let node = Node { key: key.clone(), cell, prev: NIL, next: NIL };
        let idx = match self.free.pop() {
            Some(slot) => {
                self.slab[slot] = node;
                slot
            }
            None => {
                self.slab.push(node);
                self.slab.len() - 1
            }
        };
        self.map.insert(key, idx);
        self.push_front(idx);
        idx
    }

    /// Remove `idx` entirely: recency list, map, and slab slot.
    fn remove(&mut self, idx: usize) {
        self.unlink(idx);
        let key = std::mem::take(&mut self.slab[idx].key);
        self.map.remove(&key);
        // Drop the cell Arc (waiters keep it alive through their clone).
        self.slab[idx].cell = Arc::new(OnceLock::new());
        self.free.push(idx);
    }

    /// Evict completed entries from the tail until at or under
    /// `capacity`.  In-flight cells (empty `OnceLock`s) are skipped —
    /// see the module docs; if only in-flight entries remain the cache
    /// stays transiently over capacity.
    fn evict_over_capacity(&mut self, capacity: usize) {
        while self.map.len() > capacity {
            let mut idx = self.tail;
            while idx != NIL && self.slab[idx].cell.get().is_none() {
                idx = self.slab[idx].prev;
            }
            match idx {
                NIL => break, // every resident entry is in-flight
                done => self.remove(done),
            }
        }
    }
}

/// Single-flight LRU cache of serialised plan responses.
pub struct PlanCache {
    capacity: usize,
    state: Mutex<State>,
    hits: AtomicU64,
    error_hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// `capacity` is clamped to at least 1 (a zero-entry cache could
    /// not even coalesce concurrent identical requests).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            state: Mutex::new(State {
                map: HashMap::new(),
                slab: Vec::new(),
                free: Vec::new(),
                head: NIL,
                tail: NIL,
            }),
            hits: AtomicU64::new(0),
            error_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up `key`, computing (and caching) the value with `compute`
    /// on a miss.  Exactly one caller runs `compute` per cache fill —
    /// concurrent callers with the same key block on the winner's cell.
    /// Waiters served an `Ok` count as hits; waiters served a cached
    /// `Err` count as [`error_hits`](Self::error_hits).  Returns the
    /// cached result and whether this call was served from cache.
    pub fn get_or_compute<F>(&self, key: &str, compute: F) -> (Cached, bool)
    where
        F: FnOnce() -> Result<String>,
    {
        let cell = {
            let mut st = self.state.lock().unwrap();
            // Entries parked over capacity while in-flight (see
            // evict_over_capacity) shrink back here once fills land.
            st.evict_over_capacity(self.capacity);
            if let Some(&idx) = st.map.get(key) {
                st.touch(idx);
                st.slab[idx].cell.clone()
            } else {
                let cell: Cell = Arc::new(OnceLock::new());
                st.insert_front(key.to_string(), cell.clone());
                st.evict_over_capacity(self.capacity);
                cell
            }
        };
        let mut filled = false;
        let value = cell.get_or_init(|| {
            filled = true;
            match compute() {
                Ok(v) => Ok(Arc::new(v)),
                Err(e) => Err(format!("{e:#}")),
            }
        });
        if filled {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else if value.is_ok() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.error_hits.fetch_add(1, Ordering::Relaxed);
        }
        (value.clone(), !filled)
    }

    /// Requests served an `Ok` plan without a planner evaluation
    /// (including callers coalesced onto an in-flight computation).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Requests served a *cached error* without a planner evaluation —
    /// they got a 4xx/5xx body, so they are not plan hits.
    pub fn error_hits(&self) -> u64 {
        self.error_hits.load(Ordering::Relaxed)
    }

    /// Cache fills — actual planner evaluations.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Resident entries (in-flight included).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Write every completed `Ok` entry to `path` as JSON lines
    /// (`{"key":…,"value":…}`), stalest first so a subsequent
    /// [`load`](Self::load) reproduces the recency order.  In-flight
    /// and error entries are skipped (errors are cheap to recompute and
    /// may be environment-dependent in ways a plan never is).  The file
    /// is written via a temp-and-rename so a crash mid-persist cannot
    /// leave a truncated snapshot.  Returns the number of entries
    /// written.
    pub fn persist(&self, path: &Path) -> Result<usize> {
        let lines = {
            let st = self.state.lock().unwrap();
            let mut lines = Vec::new();
            let mut idx = st.tail;
            while idx != NIL {
                let node = &st.slab[idx];
                if let Some(Ok(value)) = node.cell.get() {
                    let mut obj = std::collections::BTreeMap::new();
                    obj.insert("key".to_string(),
                               Json::Str(node.key.clone()));
                    obj.insert("value".to_string(),
                               Json::Str(value.as_str().to_string()));
                    lines.push(Json::Obj(obj).to_string());
                }
                idx = st.slab[idx].prev;
            }
            lines
        };
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp).with_context(|| {
                format!("creating cache snapshot {}", tmp.display())
            })?;
            for line in &lines {
                writeln!(f, "{line}")?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path).with_context(|| {
            format!("renaming cache snapshot into {}", path.display())
        })?;
        Ok(lines.len())
    }

    /// Load a [`persist`](Self::persist) snapshot, inserting each entry
    /// as completed (front-inserted in file order, so the file's
    /// stale→recent order becomes the recency order).  Entries beyond
    /// capacity evict normally.  A missing file is not an error (zero
    /// entries loaded); a malformed line is.  Returns the number of
    /// entries loaded.
    pub fn load(&self, path: &Path) -> Result<usize> {
        let file = match std::fs::File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(0);
            }
            Err(e) => {
                return Err(anyhow!(e)).with_context(|| {
                    format!("opening cache snapshot {}", path.display())
                });
            }
        };
        let mut loaded = 0usize;
        for (n, line) in std::io::BufReader::new(file).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let doc = Json::parse(&line).with_context(|| {
                format!("cache snapshot line {}", n + 1)
            })?;
            let obj = doc.as_obj()?;
            let key = obj
                .get("key")
                .ok_or_else(|| anyhow!("snapshot line {} lacks 'key'", n + 1))?
                .as_str()?
                .to_string();
            let value = obj
                .get("value")
                .ok_or_else(|| {
                    anyhow!("snapshot line {} lacks 'value'", n + 1)
                })?
                .as_str()?
                .to_string();
            let cell: Cell = Arc::new(OnceLock::new());
            let _ = cell.set(Ok(Arc::new(value)));
            let mut st = self.state.lock().unwrap();
            if let Some(&idx) = st.map.get(&key) {
                // A live entry wins over the snapshot.
                st.touch(idx);
            } else {
                st.insert_front(key, cell);
                st.evict_over_capacity(self.capacity);
                loaded += 1;
            }
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok(cached: &Cached) -> &str {
        cached.as_ref().unwrap().as_str()
    }

    #[test]
    fn cold_then_hot() {
        let cache = PlanCache::new(8);
        let (v, hit) = cache.get_or_compute("k", || Ok("plan".into()));
        assert_eq!(ok(&v), "plan");
        assert!(!hit);
        let (v, hit) = cache.get_or_compute("k", || {
            panic!("hot path must not recompute")
        });
        assert_eq!(ok(&v), "plan");
        assert!(hit);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn errors_are_cached_but_not_hits() {
        let cache = PlanCache::new(8);
        let (v, _) =
            cache.get_or_compute("bad", || anyhow::bail!("unknown model"));
        assert!(v.unwrap_err().contains("unknown model"));
        let (v, served) = cache.get_or_compute("bad", || {
            panic!("deterministic errors must be served from cache")
        });
        assert!(v.is_err());
        assert!(served);
        // The error-served waiter is accounted separately from plan
        // hits — it got an error body, not a plan.
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.error_hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn lru_evicts_the_stalest_entry() {
        let cache = PlanCache::new(2);
        cache.get_or_compute("a", || Ok("A".into()));
        cache.get_or_compute("b", || Ok("B".into()));
        // Touch "a" so "b" is the stalest, then insert "c".
        cache.get_or_compute("a", || unreachable!());
        cache.get_or_compute("c", || Ok("C".into()));
        assert_eq!(cache.len(), 2);
        // "a" survived, "b" was evicted.
        let (_, hit) = cache.get_or_compute("a", || unreachable!());
        assert!(hit);
        let (_, hit) = cache.get_or_compute("b", || Ok("B2".into()));
        assert!(!hit, "evicted entry must recompute");
    }

    #[test]
    fn recency_order_survives_many_evictions() {
        // Churn far past capacity to exercise slab slot recycling.
        let cache = PlanCache::new(4);
        for i in 0..64 {
            cache.get_or_compute(&format!("k{i}"), || Ok(format!("v{i}")));
        }
        assert_eq!(cache.len(), 4);
        // Exactly the last four inserts are resident.
        for i in 60..64 {
            let (v, hit) =
                cache.get_or_compute(&format!("k{i}"), || unreachable!());
            assert!(hit);
            assert_eq!(ok(&v), &format!("v{i}"));
        }
        let (_, hit) = cache.get_or_compute("k0", || Ok("again".into()));
        assert!(!hit);
    }

    #[test]
    fn concurrent_identical_requests_fill_once() {
        let cache = PlanCache::new(8);
        let fills = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let (v, _) = cache.get_or_compute("k", || {
                        fills.fetch_add(1, Ordering::Relaxed);
                        // Widen the race window: the other threads must
                        // block on the cell, not start their own fill.
                        std::thread::sleep(
                            std::time::Duration::from_millis(20));
                        Ok("slow plan".into())
                    });
                    assert_eq!(ok(&v), "slow plan");
                });
            }
        });
        assert_eq!(fills.load(Ordering::Relaxed), 1, "single-flight");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn eviction_never_breaks_single_flight() {
        // Capacity 1 with two distinct keys racing slow computations:
        // the naive policy evicts whichever entry is stalest even while
        // its OnceLock is still being filled, so a latecomer on the
        // evicted key starts a SECOND evaluation.  The fix skips
        // in-flight cells, so each key fills exactly once.
        let cache = PlanCache::new(1);
        let fills_a = AtomicU64::new(0);
        let fills_b = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    let (v, _) = cache.get_or_compute("a", || {
                        fills_a.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(
                            std::time::Duration::from_millis(30));
                        Ok("A".into())
                    });
                    assert_eq!(ok(&v), "A");
                });
                scope.spawn(|| {
                    let (v, _) = cache.get_or_compute("b", || {
                        fills_b.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(
                            std::time::Duration::from_millis(30));
                        Ok("B".into())
                    });
                    assert_eq!(ok(&v), "B");
                });
            }
        });
        assert_eq!(fills_a.load(Ordering::Relaxed), 1,
                   "single-flight must survive capacity pressure");
        assert_eq!(fills_b.load(Ordering::Relaxed), 1,
                   "single-flight must survive capacity pressure");
        // Once both fills landed, the next call shrinks the cache back
        // to capacity.
        cache.get_or_compute("a", || Ok("A".into()));
        assert!(cache.len() <= 1 + 1,
                "over-capacity parking is transient");
    }

    #[test]
    fn eviction_prefers_completed_entries() {
        let cache = PlanCache::new(2);
        cache.get_or_compute("done", || Ok("D".into()));
        let started = std::sync::Barrier::new(2);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                cache.get_or_compute("slow", || {
                    started.wait();
                    std::thread::sleep(
                        std::time::Duration::from_millis(40));
                    Ok("S".into())
                });
            });
            started.wait();
            // "slow" is now in-flight and stalest-after-"done".  A new
            // insert must evict the completed "done", not "slow".
            cache.get_or_compute("new", || Ok("N".into()));
            let (v, served) =
                cache.get_or_compute("slow", || panic!("second fill"));
            assert!(served, "in-flight entry must survive eviction");
            assert_eq!(ok(&v), "S");
        });
        let (_, served) = cache.get_or_compute("done", || Ok("D2".into()));
        assert!(!served, "completed entry was the eviction victim");
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let cache = PlanCache::new(0);
        assert_eq!(cache.capacity(), 1);
        cache.get_or_compute("a", || Ok("A".into()));
        assert!(!cache.is_empty());
    }

    #[test]
    fn persist_and_reload_keep_values_and_recency() {
        let dir = std::env::temp_dir().join(format!(
            "hybridpar-cache-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snapshot.jsonl");

        let cache = PlanCache::new(8);
        cache.get_or_compute("stale", || Ok("old \"quoted\"\nplan".into()));
        cache.get_or_compute("fresh", || Ok("new plan".into()));
        cache.get_or_compute("bad", || anyhow::bail!("nope"));
        assert_eq!(cache.persist(&path).unwrap(), 2,
                   "errors are not persisted");

        let reborn = PlanCache::new(2);
        assert_eq!(reborn.load(&path).unwrap(), 2);
        let (v, served) =
            reborn.get_or_compute("stale", || panic!("reload missed"));
        assert!(served);
        assert_eq!(ok(&v), "old \"quoted\"\nplan");
        let (_, served) =
            reborn.get_or_compute("bad", || anyhow::bail!("nope"));
        assert!(!served, "errors must recompute after a restart");
        // "bad" filled a third entry, evicting the stalest completed
        // one — recency order carried across the restart means that is
        // "fresh"… unless "stale" was front-most; the load order is
        // stale→recent so "fresh" is the head and "stale"+"bad"'s
        // touch order decides.  Assert the invariant directly:
        assert_eq!(reborn.len(), 2);

        let missing = PlanCache::new(2);
        assert_eq!(missing.load(&dir.join("absent.jsonl")).unwrap(), 0,
                   "a missing snapshot is an empty snapshot");
        std::fs::remove_dir_all(&dir).ok();
    }
}
