//! Single-flight LRU plan cache.
//!
//! The service's `POST /plan` amortisation layer: responses are keyed by
//! the canonicalised request (see
//! [`PlanRequest::canonical_json`](crate::planner::PlanRequest::canonical_json)),
//! so equivalent spellings share one entry, and each entry is an
//! [`OnceLock`] cell — concurrent requests for the same key **coalesce
//! onto one in-flight computation** instead of evaluating the planner
//! N times (the same trick the sweep engine's `MemoCost` uses, lifted
//! to whole responses).
//!
//! Recency is a monotonic tick per entry; eviction scans for the
//! minimum (O(entries), which at service cache sizes — hundreds — is
//! noise next to a planner evaluation).  The map lock is held only for
//! lookup/insert/evict, never across a computation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

/// A finished computation: the response document, or the (deterministic)
/// error text.  Errors are cached like successes — the planner is a pure
/// function of the canonical request, so "unknown model 'alexnet'" today
/// is "unknown model 'alexnet'" tomorrow.
pub type Cached = std::result::Result<Arc<String>, String>;

type Cell = Arc<OnceLock<Cached>>;

struct Entry {
    cell: Cell,
    last_used: u64,
}

struct State {
    entries: HashMap<String, Entry>,
    tick: u64,
}

/// Single-flight LRU cache of serialised plan responses.
pub struct PlanCache {
    capacity: usize,
    state: Mutex<State>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// `capacity` is clamped to at least 1 (a zero-entry cache could
    /// not even coalesce concurrent identical requests).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            state: Mutex::new(State { entries: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up `key`, computing (and caching) the value with `compute`
    /// on a miss.  Exactly one caller runs `compute` per cache fill —
    /// concurrent callers with the same key block on the winner's cell
    /// and are counted as hits (they were served without a planner
    /// evaluation).  Returns the cached result and whether this call
    /// was a hit.
    pub fn get_or_compute<F>(&self, key: &str, compute: F) -> (Cached, bool)
    where
        F: FnOnce() -> Result<String>,
    {
        let cell = {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some(entry) = st.entries.get_mut(key) {
                entry.last_used = tick;
                entry.cell.clone()
            } else {
                let cell: Cell = Arc::new(OnceLock::new());
                st.entries.insert(key.to_string(), Entry {
                    cell: cell.clone(),
                    last_used: tick,
                });
                if st.entries.len() > self.capacity {
                    // Evict the stalest entry (never the one just
                    // inserted — it owns the newest tick).  An evicted
                    // in-flight cell stays alive for its waiters via
                    // the Arc; only future requests re-compute.
                    if let Some(stalest) = st
                        .entries
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone())
                    {
                        st.entries.remove(&stalest);
                    }
                }
                cell
            }
        };
        let mut filled = false;
        let value = cell.get_or_init(|| {
            filled = true;
            match compute() {
                Ok(v) => Ok(Arc::new(v)),
                Err(e) => Err(format!("{e:#}")),
            }
        });
        if filled {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        (value.clone(), !filled)
    }

    /// Requests served without a planner evaluation (including callers
    /// coalesced onto another request's in-flight computation).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache fills — actual planner evaluations.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Resident entries (in-flight included).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok(cached: &Cached) -> &str {
        cached.as_ref().unwrap().as_str()
    }

    #[test]
    fn cold_then_hot() {
        let cache = PlanCache::new(8);
        let (v, hit) = cache.get_or_compute("k", || Ok("plan".into()));
        assert_eq!(ok(&v), "plan");
        assert!(!hit);
        let (v, hit) = cache.get_or_compute("k", || {
            panic!("hot path must not recompute")
        });
        assert_eq!(ok(&v), "plan");
        assert!(hit);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn errors_are_cached_too() {
        let cache = PlanCache::new(8);
        let (v, _) =
            cache.get_or_compute("bad", || anyhow::bail!("unknown model"));
        assert!(v.unwrap_err().contains("unknown model"));
        let (v, hit) = cache.get_or_compute("bad", || {
            panic!("deterministic errors must be served from cache")
        });
        assert!(v.is_err());
        assert!(hit);
    }

    #[test]
    fn lru_evicts_the_stalest_entry() {
        let cache = PlanCache::new(2);
        cache.get_or_compute("a", || Ok("A".into()));
        cache.get_or_compute("b", || Ok("B".into()));
        // Touch "a" so "b" is the stalest, then insert "c".
        cache.get_or_compute("a", || unreachable!());
        cache.get_or_compute("c", || Ok("C".into()));
        assert_eq!(cache.len(), 2);
        // "a" survived, "b" was evicted.
        let (_, hit) = cache.get_or_compute("a", || unreachable!());
        assert!(hit);
        let (_, hit) = cache.get_or_compute("b", || Ok("B2".into()));
        assert!(!hit, "evicted entry must recompute");
    }

    #[test]
    fn concurrent_identical_requests_fill_once() {
        let cache = PlanCache::new(8);
        let fills = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let (v, _) = cache.get_or_compute("k", || {
                        fills.fetch_add(1, Ordering::Relaxed);
                        // Widen the race window: the other threads must
                        // block on the cell, not start their own fill.
                        std::thread::sleep(
                            std::time::Duration::from_millis(20));
                        Ok("slow plan".into())
                    });
                    assert_eq!(ok(&v), "slow plan");
                });
            }
        });
        assert_eq!(fills.load(Ordering::Relaxed), 1, "single-flight");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let cache = PlanCache::new(0);
        assert_eq!(cache.capacity(), 1);
        cache.get_or_compute("a", || Ok("A".into()));
        assert!(!cache.is_empty());
    }
}
