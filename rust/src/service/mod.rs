//! Planner-as-a-service: a dependency-free HTTP/1.1 daemon exposing the
//! unified planner, so repeated `PlanRequest → Plan` and
//! `SweepSpec → SweepResult` queries amortise across callers instead of
//! paying a fresh CLI invocation each (the deployment shape of Kahira et
//! al.'s training oracle).  Everything is `std` — `TcpListener` plus a
//! scoped worker-thread pool in the style of
//! [`parallel_map`](crate::planner::sweep::parallel_map).
//!
//! Endpoints:
//!
//! | route             | body                | response |
//! |-------------------|---------------------|----------|
//! | `POST /plan`      | `PlanRequest` JSON  | the plan document — byte-identical to the `plan` CLI's stdout |
//! | `POST /sweep`     | `SweepSpec` JSON    | the sweep document, chunk-streamed per scenario as the grid completes |
//! | `GET /models`     | —                   | model registry listing |
//! | `GET /topologies` | —                   | topology registry listing |
//! | `GET /healthz`    | —                   | `{"status":"ok"}` |
//! | `GET /metrics`    | —                   | Prometheus text: request counts, cache hits/misses, per-endpoint latency histograms |
//!
//! The heart is the **single-flight LRU plan cache** ([`cache`]):
//! requests are canonicalised
//! ([`PlanRequest::canonical_json`](crate::planner::PlanRequest::canonical_json))
//! so equivalent spellings — model
//! aliases, explicitly-spelled defaults, permuted degree lists — share
//! one entry, and concurrent identical requests coalesce onto a single
//! in-flight planner evaluation.  Cache *hits* are requests served
//! without an evaluation; *misses* are fills.  Worked examples and the
//! full canonicalisation rules live in `docs/service.md`.
//!
//! ```no_run
//! use hybridpar::service::{self, ServiceOptions};
//!
//! let bound = service::bind("127.0.0.1:0",
//!                           ServiceOptions::default()).unwrap();
//! println!("listening on {}", bound.local_addr());
//! bound.serve_forever().unwrap();   // or .spawn() for tests/benches
//! ```

pub mod cache;
pub mod http;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::{Counter, Histogram};
use crate::planner::sweep::{stream_sweep, SweepSpec};
use crate::planner::{cost_by_name, jobj, plan_request_from_json,
                     ModelRegistry, Planner, TopologyRegistry};
use crate::util::json::Json;

use self::cache::PlanCache;

const CONTENT_JSON: &str = "application/json";
const CONTENT_PROM: &str = "text/plain; version=0.0.4";

/// Metric name prefix for every exported series.
const METRIC_PREFIX: &str = "hybridpar_service";

/// The endpoint label set (fixed, so `/metrics` output is deterministic
/// and unbounded label cardinality is impossible — unknown paths all
/// land on "other").
const ENDPOINTS: [&str; 7] = ["plan", "sweep", "models", "topologies",
                              "healthz", "metrics", "other"];

/// Status codes the service can emit (fixed label set, like
/// [`ENDPOINTS`]).
const CODES: [u16; 5] = [200, 400, 404, 405, 500];

/// Cap on one `POST /sweep` grid.  A request describes its grid as a
/// cartesian product, so a small body can demand an enormous amount of
/// work; past this many scenarios the request is a 400, not a
/// daemon-sized job.
pub const MAX_SWEEP_SCENARIOS: usize = 4096;

// ==========================================================================
// Options
// ==========================================================================

/// Daemon knobs (`serve` CLI flags / the `[service]` config section).
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Request worker threads (0 = one per available core).
    pub threads: usize,
    /// Plan-cache capacity in entries (clamped to ≥ 1).
    pub cache_entries: usize,
    /// Cost model used when a request omits `"cost"`; the same default
    /// as the `plan` CLI, so minimal bodies stay byte-compatible.
    pub default_cost: String,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            threads: 0,
            cache_entries: 128,
            default_cost: "analytical".into(),
        }
    }
}

// ==========================================================================
// Per-endpoint metrics
// ==========================================================================

/// Request counters (by endpoint × status code) and per-endpoint latency
/// histograms, rendered as Prometheus text by
/// [`PlannerService::metrics_doc`].
struct ServiceMetrics {
    /// `[endpoint][code]` request counts.
    requests: Vec<Vec<Counter>>,
    /// `[endpoint]` request latency.
    latency: Vec<Histogram>,
}

impl ServiceMetrics {
    fn new() -> Self {
        ServiceMetrics {
            requests: ENDPOINTS
                .iter()
                .map(|_| CODES.iter().map(|_| Counter::new()).collect())
                .collect(),
            latency: ENDPOINTS.iter().map(|_| Histogram::latency()).collect(),
        }
    }

    fn endpoint_index(endpoint: &str) -> usize {
        ENDPOINTS
            .iter()
            .position(|&e| e == endpoint)
            .unwrap_or(ENDPOINTS.len() - 1)
    }

    fn record(&self, endpoint: &str, code: u16, seconds: f64) {
        let e = Self::endpoint_index(endpoint);
        let c = CODES.iter().position(|&x| x == code).unwrap_or(CODES.len() - 1);
        self.requests[e][c].inc();
        self.latency[e].observe(seconds);
    }

    fn render(&self, cache: &PlanCache) -> String {
        let p = METRIC_PREFIX;
        let mut s = String::new();
        s.push_str(&format!(
            "# HELP {p}_requests_total Requests served, by endpoint and \
             status code.\n# TYPE {p}_requests_total counter\n"));
        for (e, endpoint) in ENDPOINTS.iter().enumerate() {
            for (c, code) in CODES.iter().enumerate() {
                s.push_str(&self.requests[e][c].render(
                    &format!("{p}_requests_total"),
                    &format!("endpoint=\"{endpoint}\",code=\"{code}\"")));
            }
        }
        s.push_str(&format!(
            "# HELP {p}_plan_cache_hits_total Plan requests served \
             without a planner evaluation (coalesced waiters included).\n\
             # TYPE {p}_plan_cache_hits_total counter\n\
             {p}_plan_cache_hits_total {}\n", cache.hits()));
        s.push_str(&format!(
            "# HELP {p}_plan_cache_misses_total Plan-cache fills (actual \
             planner evaluations).\n\
             # TYPE {p}_plan_cache_misses_total counter\n\
             {p}_plan_cache_misses_total {}\n", cache.misses()));
        s.push_str(&format!(
            "# HELP {p}_plan_cache_entries Resident plan-cache entries.\n\
             # TYPE {p}_plan_cache_entries gauge\n\
             {p}_plan_cache_entries {}\n", cache.len()));
        s.push_str(&format!(
            "# HELP {p}_request_duration_seconds Request latency by \
             endpoint.\n\
             # TYPE {p}_request_duration_seconds histogram\n"));
        for (e, endpoint) in ENDPOINTS.iter().enumerate() {
            s.push_str(&self.latency[e].render(
                &format!("{p}_request_duration_seconds"),
                &format!("endpoint=\"{endpoint}\"")));
        }
        s
    }
}

// ==========================================================================
// The service
// ==========================================================================

/// JSON error document: `{"error":"…"}` plus newline.
fn error_body(msg: &str) -> Arc<String> {
    let mut s = jobj(vec![("error", Json::Str(msg.to_string()))]).to_string();
    s.push('\n');
    Arc::new(s)
}

/// Request-handling state shared by every worker thread: the registries,
/// the single-flight plan cache, and the metrics.
pub struct PlannerService {
    models: ModelRegistry,
    topologies: TopologyRegistry,
    cache: PlanCache,
    metrics: ServiceMetrics,
    default_cost: String,
}

impl PlannerService {
    /// Built-in registries.  Fails if `default_cost` does not resolve —
    /// better at startup than on the first request.
    pub fn new(opts: &ServiceOptions) -> Result<Self> {
        let default_cost = cost_by_name(&opts.default_cost)
            .context("service default cost model")?
            .name()
            .to_string();
        Ok(PlannerService {
            models: ModelRegistry::builtin(),
            topologies: TopologyRegistry::builtin(),
            cache: PlanCache::new(opts.cache_entries),
            metrics: ServiceMetrics::new(),
            default_cost,
        })
    }

    /// The plan cache (tests and benches read the hit/miss counters).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// `POST /plan`: parse → canonicalise → single-flight cache →
    /// respond.  The 200 body is [`Plan::to_json_string`]
    /// (byte-identical to the `plan` CLI); planner and parse errors are
    /// 400s with `{"error":…}` bodies — and deterministic planner
    /// errors are cached exactly like plans.
    ///
    /// [`Plan::to_json_string`]: crate::planner::Plan::to_json_string
    fn handle_plan(&self, body: &[u8]) -> (u16, Arc<String>) {
        let parsed = std::str::from_utf8(body)
            .map_err(anyhow::Error::from)
            .and_then(Json::parse)
            .and_then(|j| plan_request_from_json(&j));
        let (req, cost_name) = match parsed {
            Ok(p) => p,
            Err(e) => return (400, error_body(&format!("{e:#}"))),
        };
        let cost = match cost_by_name(
            cost_name.as_deref().unwrap_or(&self.default_cost)) {
            Ok(c) => c,
            Err(e) => return (400, error_body(&format!("{e:#}"))),
        };
        let key = req.canonical_json(&self.models, cost.name()).to_string();
        let (cached, _hit) = self.cache.get_or_compute(&key, || {
            let planner = Planner::with_parts(self.models.clone(),
                                              self.topologies.clone(), cost);
            Ok(planner.plan(&req)?.to_json_string())
        });
        match cached {
            Ok(doc) => (200, doc),
            Err(e) => (400, error_body(&e)),
        }
    }

    /// `POST /sweep`: parse + validate, then stream the sweep document
    /// as chunked transfer encoding — one chunk per completed scenario,
    /// in canonical order, concatenating to the `sweep` CLI's JSON
    /// byte-for-byte.  Validation failures are plain 400s; a failure
    /// *after* the 200 head is committed truncates the chunk stream
    /// (recorded as a 500 in the metrics).
    fn handle_sweep(&self, body: &[u8], stream: &mut TcpStream) -> u16 {
        let parsed = std::str::from_utf8(body)
            .map_err(anyhow::Error::from)
            .and_then(Json::parse)
            .and_then(|j| SweepSpec::from_json(&j))
            .and_then(|mut spec| {
                spec.validate()?;
                cost_by_name(&spec.cost_model)?;
                if spec.cardinality() > MAX_SWEEP_SCENARIOS {
                    bail!("sweep grid of {} scenarios exceeds the \
                           service cap of {MAX_SWEEP_SCENARIOS} — split \
                           the request", spec.cardinality());
                }
                // Worker threads are a server resource: clamp the
                // client's request to this host's cores (0 already
                // means one per core, which effective_threads resolves).
                if spec.threads != 0 {
                    let cores = std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1);
                    spec.threads = spec.threads.min(cores);
                }
                Ok(spec)
            });
        let spec = match parsed {
            Ok(s) => s,
            Err(e) => {
                let body = error_body(&format!("{e:#}"));
                let _ = http::write_response(stream, 400, CONTENT_JSON,
                                             body.as_bytes());
                return 400;
            }
        };
        let Ok(mut writer) =
            http::ChunkedWriter::start(stream, 200, CONTENT_JSON)
        else {
            return 500;
        };
        let mut first = true;
        let streamed = stream_sweep(&spec, |r| {
            let mut chunk = String::new();
            chunk.push_str(if first { "{\"scenarios\":[" } else { "," });
            first = false;
            chunk.push_str(&r.to_json().to_string());
            writer.chunk(chunk.as_bytes())
        });
        if streamed.is_err() {
            return 500;
        }
        let tail: &[u8] = if first { b"{\"scenarios\":[]}\n" } else { b"]}\n" };
        if writer.chunk(tail).is_err() || writer.finish().is_err() {
            return 500;
        }
        200
    }

    /// `GET /models` document.
    fn models_doc(&self) -> Arc<String> {
        let entries: Vec<Json> = self
            .models
            .entries()
            .iter()
            .map(|e| jobj(vec![
                ("name", Json::Str(e.name.into())),
                ("aliases",
                 Json::Arr(e.aliases
                     .iter()
                     .map(|&a| Json::Str(a.into()))
                     .collect())),
                ("default_batch", Json::Num(e.default_batch as f64)),
            ]))
            .collect();
        let mut s = jobj(vec![("models", Json::Arr(entries))]).to_string();
        s.push('\n');
        Arc::new(s)
    }

    /// `GET /topologies` document (`max_devices` is `null` for
    /// unbounded scale-out entries).
    fn topologies_doc(&self) -> Arc<String> {
        let entries: Vec<Json> = self
            .topologies
            .entries()
            .iter()
            .map(|e| jobj(vec![
                ("name", Json::Str(e.name.into())),
                ("aliases",
                 Json::Arr(e.aliases
                     .iter()
                     .map(|&a| Json::Str(a.into()))
                     .collect())),
                ("max_devices",
                 if e.max_devices == usize::MAX {
                     Json::Null
                 } else {
                     Json::Num(e.max_devices as f64)
                 }),
                ("multi_node", Json::Bool(e.build_pod.is_some())),
            ]))
            .collect();
        let mut s =
            jobj(vec![("topologies", Json::Arr(entries))]).to_string();
        s.push('\n');
        Arc::new(s)
    }

    /// `GET /metrics` document (Prometheus text exposition).
    pub fn metrics_doc(&self) -> String {
        self.metrics.render(&self.cache)
    }

    /// Serve one connection: read a request, dispatch, record metrics.
    /// One request per connection (every response is
    /// `Connection: close`).
    fn handle_conn(&self, mut stream: TcpStream) {
        let t0 = Instant::now();
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        // Per-write timeout: a client that stops reading its response
        // fills the kernel send buffer and would otherwise park this
        // worker in write_all forever — with a small fixed pool that is
        // a trivial denial of service.  (Sweep compute time between
        // chunks is unaffected; the clock only runs inside a write.)
        let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
        let _ = stream.set_nodelay(true);
        let (endpoint, code) = match http::read_request(&mut stream) {
            Err(e) => {
                let body = error_body(&format!("{e:#}"));
                let _ = http::write_response(&mut stream, 400, CONTENT_JSON,
                                             body.as_bytes());
                ("other", 400)
            }
            Ok(req) => self.dispatch(&req, &mut stream),
        };
        self.metrics.record(endpoint, code, t0.elapsed().as_secs_f64());
    }

    fn dispatch(&self, req: &http::Request, stream: &mut TcpStream)
                -> (&'static str, u16) {
        let endpoint = match req.path.as_str() {
            "/plan" => "plan",
            "/sweep" => "sweep",
            "/models" => "models",
            "/topologies" => "topologies",
            "/healthz" => "healthz",
            "/metrics" => "metrics",
            _ => "other",
        };
        let (code, content_type, body): (u16, &str, Arc<String>) =
            match (endpoint, req.method.as_str()) {
                ("plan", "POST") => {
                    let (code, body) = self.handle_plan(&req.body);
                    (code, CONTENT_JSON, body)
                }
                // /sweep writes its own (chunked) response.
                ("sweep", "POST") => {
                    return (endpoint, self.handle_sweep(&req.body, stream));
                }
                ("models", "GET") => (200, CONTENT_JSON, self.models_doc()),
                ("topologies", "GET") => {
                    (200, CONTENT_JSON, self.topologies_doc())
                }
                ("healthz", "GET") => (
                    200,
                    CONTENT_JSON,
                    Arc::new("{\"status\":\"ok\"}\n".to_string()),
                ),
                ("metrics", "GET") => {
                    (200, CONTENT_PROM, Arc::new(self.metrics_doc()))
                }
                ("other", _) => (
                    404,
                    CONTENT_JSON,
                    error_body(&format!(
                        "no endpoint '{}' (known: /plan, /sweep, /models, \
                         /topologies, /healthz, /metrics)", req.path)),
                ),
                (_, method) => (
                    405,
                    CONTENT_JSON,
                    error_body(&format!(
                        "{} does not support {method}", req.path)),
                ),
            };
        let _ = http::write_response(stream, code, content_type,
                                     body.as_bytes());
        (endpoint, code)
    }
}

// ==========================================================================
// The daemon
// ==========================================================================

/// A bound-but-not-yet-serving daemon: bind first so callers can learn
/// the ephemeral port (tests bind `127.0.0.1:0`) before the accept loop
/// starts.
pub struct BoundService {
    listener: TcpListener,
    service: Arc<PlannerService>,
    threads: usize,
}

/// Bind `addr` with the given options.
pub fn bind(addr: &str, opts: ServiceOptions) -> Result<BoundService> {
    let service = Arc::new(PlannerService::new(&opts)?);
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    Ok(BoundService { listener, service, threads: opts.threads })
}

/// Accept loop + worker pool, until `shutdown` flips (checked once per
/// accepted connection; [`ServiceHandle::stop`] flips it and then dials
/// the listener to unblock the acceptor).
fn serve_on(listener: &TcpListener, service: &PlannerService,
            threads: usize, shutdown: &AtomicBool) -> Result<()> {
    let n_workers = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
    .max(1);
    // parallel_map-style pool: scoped workers pull connections off one
    // shared channel; the calling thread is the acceptor.
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Mutex::new(rx);
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            let rx = &rx;
            scope.spawn(move || loop {
                // Hold the receiver lock only for the dequeue: requests
                // are handled concurrently across workers.
                let conn = rx.lock().unwrap().recv();
                match conn {
                    Ok(stream) => service.handle_conn(stream),
                    Err(_) => break, // acceptor hung up: drain complete
                }
            });
        }
        for conn in listener.incoming() {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    if tx.send(stream).is_err() {
                        break;
                    }
                }
                // A failed accept (client reset mid-handshake) is not a
                // daemon failure.
                Err(_) => continue,
            }
        }
        drop(tx);
    });
    Ok(())
}

impl BoundService {
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    pub fn service(&self) -> &PlannerService {
        &self.service
    }

    /// Serve on the calling thread until the process dies (the `serve`
    /// CLI path).
    pub fn serve_forever(self) -> Result<()> {
        let shutdown = AtomicBool::new(false);
        serve_on(&self.listener, &self.service, self.threads, &shutdown)
    }

    /// Serve on a background thread; the returned handle stops the
    /// daemon cleanly (tests and benches).
    pub fn spawn(self) -> ServiceHandle {
        let addr = self.local_addr();
        let shutdown = Arc::new(AtomicBool::new(false));
        let service = self.service.clone();
        let sd = shutdown.clone();
        let threads = self.threads;
        let listener = self.listener;
        let join = std::thread::spawn(move || {
            let _ = serve_on(&listener, &service, threads, &sd);
        });
        ServiceHandle { addr, service: self.service, shutdown, join }
    }
}

/// A running background daemon (from [`BoundService::spawn`]).
pub struct ServiceHandle {
    addr: SocketAddr,
    service: Arc<PlannerService>,
    shutdown: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl ServiceHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &PlannerService {
        &self.service
    }

    /// Flip the shutdown flag, unblock the acceptor with one last
    /// connection, and join the serving thread (which drains in-flight
    /// requests first).
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        let _ = self.join.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_index_is_total() {
        for e in ENDPOINTS {
            assert_eq!(ENDPOINTS[ServiceMetrics::endpoint_index(e)], e);
        }
        assert_eq!(ServiceMetrics::endpoint_index("bogus"),
                   ENDPOINTS.len() - 1);
    }

    #[test]
    fn metrics_doc_renders_every_series() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        svc.metrics.record("plan", 200, 0.001);
        svc.metrics.record("plan", 400, 0.002);
        let doc = svc.metrics_doc();
        assert!(doc.contains(
            "hybridpar_service_requests_total{endpoint=\"plan\",\
             code=\"200\"} 1"), "{doc}");
        assert!(doc.contains("hybridpar_service_plan_cache_hits_total 0"));
        assert!(doc.contains("hybridpar_service_plan_cache_misses_total 0"));
        assert!(doc.contains(
            "hybridpar_service_request_duration_seconds_bucket\
             {endpoint=\"plan\","), "{doc}");
        assert!(doc.contains(
            "hybridpar_service_request_duration_seconds_count\
             {endpoint=\"plan\"} 2"), "{doc}");
    }

    #[test]
    fn plan_handler_caches_and_matches_cli_document() {
        use crate::planner::{PlanRequest, Planner};
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let body = br#"{"model":"gnmt","devices":8}"#;
        let (code, doc) = svc.handle_plan(body);
        assert_eq!(code, 200);
        let want = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap()
            .to_json_string();
        assert_eq!(doc.as_str(), want,
                   "service body must be byte-identical to the CLI doc");
        // Alias + explicitly-spelled defaults share the entry.
        let (code, doc2) = svc.handle_plan(
            br#"{"model":"gnmt","topology":"dgx1","devices":8,
                 "cost":"analytical"}"#);
        assert_eq!(code, 200);
        assert_eq!(doc2, doc);
        assert_eq!((svc.cache().hits(), svc.cache().misses()), (1, 1));
    }

    #[test]
    fn plan_handler_rejects_bad_bodies() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let bad_bodies: [&[u8]; 3] = [b"not json", br#"{"devices":8}"#,
                                      br#"{"model":"gnmt","bogus_key":1}"#];
        for bad in bad_bodies {
            let (code, body) = svc.handle_plan(bad);
            assert_eq!(code, 400, "{body}");
            assert!(body.starts_with("{\"error\":"), "{body}");
        }
        // Unknown models are planner errors: 400, and cached.
        let (code, _) = svc.handle_plan(br#"{"model":"alexnet"}"#);
        assert_eq!(code, 400);
        let (code, _) = svc.handle_plan(br#"{"model":"alexnet"}"#);
        assert_eq!(code, 400);
        assert_eq!(svc.cache().hits(), 1,
                   "deterministic planner errors are cached");
    }

    #[test]
    fn registry_docs_list_the_catalogs() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let models = svc.models_doc();
        assert!(models.contains("\"inception-v3\""), "{models}");
        assert!(models.contains("\"default_batch\":128"), "{models}");
        let topos = svc.topologies_doc();
        assert!(topos.contains("\"dgx1-pod\""), "{topos}");
        assert!(topos.contains("\"max_devices\":null"), "{topos}");
        assert!(topos.contains("\"multi_node\":true"), "{topos}");
        // Both parse back as JSON.
        Json::parse(&models).unwrap();
        Json::parse(&topos).unwrap();
    }

    #[test]
    fn bad_default_cost_fails_at_startup() {
        let opts = ServiceOptions {
            default_cost: "crystal-ball".into(),
            ..Default::default()
        };
        assert!(PlannerService::new(&opts).is_err());
    }
}
