//! Planner-as-a-service: a dependency-free HTTP/1.1 daemon exposing the
//! unified planner, so repeated `PlanRequest → Plan` and
//! `SweepSpec → SweepResult` queries amortise across callers instead of
//! paying a fresh CLI invocation each (the deployment shape of Kahira et
//! al.'s training oracle).  Everything is `std`: a readiness-polled
//! event loop over non-blocking sockets ([`event_loop`]) owns every
//! connection, and a worker pool runs the planner evaluations — see the
//! event-loop module docs for the keep-alive, admission-control and
//! deadline policies.
//!
//! Endpoints:
//!
//! | route             | body                | response |
//! |-------------------|---------------------|----------|
//! | `POST /plan`      | `PlanRequest` JSON  | the plan document — byte-identical to the `plan` CLI's stdout |
//! | `POST /sweep`     | `SweepSpec` JSON    | the sweep document, chunk-streamed per scenario as the grid completes |
//! | `GET /models`     | —                   | model registry listing |
//! | `GET /topologies` | —                   | topology registry listing |
//! | `GET /healthz`    | —                   | `{"status":"ok"}` |
//! | `GET /metrics`    | —                   | Prometheus text: request counts, cache hits/misses, queue depth, per-endpoint latency and per-phase plan histograms |
//! | `GET /debug/trace`| —                   | the last `?n=` served requests with per-phase timings (in-memory ring) |
//!
//! Every response carries an `X-Request-Id` header — the client's own
//! id echoed back, or a generated one — and, when
//! [`ServiceOptions::access_log`] is set, each served request appends
//! one JSON line (id, endpoint, code, duration, plan phases) to the
//! log.  Schema and worked examples: `docs/observability.md`.
//!
//! The heart is the **single-flight LRU plan cache** ([`cache`]):
//! requests are canonicalised
//! ([`PlanRequest::canonical_json`](crate::planner::PlanRequest::canonical_json))
//! so equivalent spellings — model
//! aliases, explicitly-spelled defaults, permuted degree lists — share
//! one entry, and concurrent identical requests coalesce onto a single
//! in-flight planner evaluation.  Cache *hits* are requests served an
//! `Ok` plan without an evaluation; *misses* are fills; waiters served
//! a cached error count as *error hits*.  Eviction is O(1) and never
//! touches an in-flight cell; completed entries can persist across
//! restarts ([`ServiceOptions::persist_path`]).  Worked examples and
//! the full canonicalisation rules live in `docs/service.md`.
//!
//! When [`ServiceOptions::replicas`] names peer daemons, `POST /sweep`
//! becomes a **sharded fan-out**: the grid is partitioned by consistent
//! hashing on each scenario's memo-affinity key ([`shard`]), every
//! replica evaluates its share, and the coordinator splices the chunk
//! streams back in canonical order — the merged body stays
//! byte-identical to a single daemon's (and to `sweep` CLI stdout).
//!
//! ```no_run
//! use hybridpar::service::{self, ServiceOptions};
//!
//! let bound = service::bind("127.0.0.1:0",
//!                           ServiceOptions::default()).unwrap();
//! println!("listening on {}", bound.local_addr());
//! bound.serve_forever().unwrap();   // or .spawn() for tests/benches
//! ```

pub mod cache;
mod event_loop;
pub mod http;
pub mod shard;

use std::collections::{BTreeMap, VecDeque};
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::{Counter, Gauge, Histogram};
use crate::planner::sweep::{stream_sweep_indices, SweepSpec};
use crate::planner::{cost_by_name, jobj, plan_request_from_json,
                     ModelRegistry, Planner, TopologyRegistry};
use crate::util::json::Json;

use self::cache::PlanCache;

const CONTENT_JSON: &str = "application/json";
const CONTENT_PROM: &str = "text/plain; version=0.0.4";

/// Metric name prefix for every exported series.
const METRIC_PREFIX: &str = "hybridpar_service";

/// The endpoint label set (fixed, so `/metrics` output is deterministic
/// and unbounded label cardinality is impossible — unknown paths all
/// land on "other").
const ENDPOINTS: [&str; 8] = ["plan", "sweep", "models", "topologies",
                              "healthz", "metrics", "debug", "other"];

/// Label set for the `POST /plan` per-phase histograms, in handling
/// order: body parse, single-flight cache lookup, planner evaluation,
/// plan serialisation (the last two are zero on cache hits).
const PLAN_PHASES: [&str; 4] = ["parse", "cache_lookup", "plan",
                                "serialize"];

/// Entries retained by the `GET /debug/trace` request ring.
const DEBUG_RING_CAP: usize = 256;

/// Status codes the service can emit (fixed label set, like
/// [`ENDPOINTS`]).  408 = request-head deadline, 503 = load shed.
const CODES: [u16; 7] = [200, 400, 404, 405, 408, 500, 503];

/// Cap on one `POST /sweep` grid.  A request describes its grid as a
/// cartesian product, so a small body can demand an enormous amount of
/// work; past this many scenarios the request is a 400, not a
/// daemon-sized job.
pub const MAX_SWEEP_SCENARIOS: usize = 4096;

/// Socket timeout for one coordinator→replica read/write during a
/// sharded sweep (generous: chunks may be minutes apart on a grid of
/// slow cost models).
const REPLICA_IO_TIMEOUT: Duration = Duration::from_secs(300);

// ==========================================================================
// Options
// ==========================================================================

/// Daemon knobs (`serve` CLI flags / the `[service]` config section).
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Planner worker threads (0 = one per available core).  The event
    /// loop itself always runs on one dedicated thread.
    pub threads: usize,
    /// Plan-cache capacity in entries (clamped to ≥ 1).
    pub cache_entries: usize,
    /// Cost model used when a request omits `"cost"`; the same default
    /// as the `plan` CLI, so minimal bodies stay byte-compatible.
    pub default_cost: String,
    /// Admission-control bound: when this many planner jobs are
    /// outstanding, further `POST`s get 503 + `Retry-After` (clamped
    /// to ≥ 1).
    pub max_pending: usize,
    /// Connection cap; past it new connections are shed with a 503.
    pub max_connections: usize,
    /// A request head must complete within this deadline (slow-loris
    /// defence; expiry is a 408).
    pub head_timeout: Duration,
    /// Keep-alive connections idle *between* requests longer than this
    /// are closed silently.
    pub idle_timeout: Duration,
    /// Optional plan-cache snapshot file: loaded at bind, rewritten
    /// periodically and at shutdown, so a restart keeps its warm set.
    pub persist_path: Option<PathBuf>,
    /// Peer daemon addresses for sharded `POST /sweep` fan-out (empty =
    /// evaluate every sweep locally).  Listing this daemon's own
    /// address is allowed but requires `threads ≥ 2` (the coordinator
    /// occupies one worker while its own shard needs another).
    pub replicas: Vec<String>,
    /// Access-log destination: a file path (appended, JSON lines) or
    /// `"-"` for stderr.  `None` disables the log.
    pub access_log: Option<String>,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            threads: 0,
            cache_entries: 128,
            default_cost: "analytical".into(),
            max_pending: 128,
            max_connections: 10_240,
            head_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(60),
            persist_path: None,
            replicas: Vec::new(),
            access_log: None,
        }
    }
}

// ==========================================================================
// Per-endpoint metrics
// ==========================================================================

/// Request counters (by endpoint × status code) and per-endpoint latency
/// histograms, rendered as Prometheus text by
/// [`PlannerService::metrics_doc`].
struct ServiceMetrics {
    /// `[endpoint][code]` request counts.
    requests: Vec<Vec<Counter>>,
    /// `[endpoint]` request latency.
    latency: Vec<Histogram>,
    /// `[phase]` `POST /plan` handling-phase latency ([`PLAN_PHASES`]).
    plan_phase: Vec<Histogram>,
}

impl ServiceMetrics {
    fn new() -> Self {
        ServiceMetrics {
            requests: ENDPOINTS
                .iter()
                .map(|_| CODES.iter().map(|_| Counter::new()).collect())
                .collect(),
            latency: ENDPOINTS.iter().map(|_| Histogram::latency()).collect(),
            plan_phase: PLAN_PHASES
                .iter()
                .map(|_| Histogram::latency())
                .collect(),
        }
    }

    fn endpoint_index(endpoint: &str) -> usize {
        ENDPOINTS
            .iter()
            .position(|&e| e == endpoint)
            .unwrap_or(ENDPOINTS.len() - 1)
    }

    fn record(&self, endpoint: &str, code: u16, seconds: f64) {
        let e = Self::endpoint_index(endpoint);
        let c = CODES.iter().position(|&x| x == code).unwrap_or(CODES.len() - 1);
        self.requests[e][c].inc();
        self.latency[e].observe(seconds);
    }

    fn render(&self, cache: &PlanCache, stats: &LoopStats) -> String {
        let p = METRIC_PREFIX;
        let mut s = String::new();
        s.push_str(&format!(
            "# HELP {p}_requests_total Requests served, by endpoint and \
             status code.\n# TYPE {p}_requests_total counter\n"));
        for (e, endpoint) in ENDPOINTS.iter().enumerate() {
            for (c, code) in CODES.iter().enumerate() {
                s.push_str(&self.requests[e][c].render(
                    &format!("{p}_requests_total"),
                    &format!("endpoint=\"{endpoint}\",code=\"{code}\"")));
            }
        }
        s.push_str(&format!(
            "# HELP {p}_plan_cache_hits_total Plan requests served an Ok \
             plan without a planner evaluation (coalesced waiters \
             included).\n\
             # TYPE {p}_plan_cache_hits_total counter\n\
             {p}_plan_cache_hits_total {}\n", cache.hits()));
        s.push_str(&format!(
            "# HELP {p}_plan_cache_error_hits_total Plan requests served \
             a cached error body without a planner evaluation.\n\
             # TYPE {p}_plan_cache_error_hits_total counter\n\
             {p}_plan_cache_error_hits_total {}\n", cache.error_hits()));
        s.push_str(&format!(
            "# HELP {p}_plan_cache_misses_total Plan-cache fills (actual \
             planner evaluations).\n\
             # TYPE {p}_plan_cache_misses_total counter\n\
             {p}_plan_cache_misses_total {}\n", cache.misses()));
        s.push_str(&format!(
            "# HELP {p}_plan_cache_entries Resident plan-cache entries.\n\
             # TYPE {p}_plan_cache_entries gauge\n\
             {p}_plan_cache_entries {}\n", cache.len()));
        s.push_str(&format!(
            "# HELP {p}_connections_open Connections currently held by \
             the event loop.\n\
             # TYPE {p}_connections_open gauge\n"));
        s.push_str(&stats.connections.render(
            &format!("{p}_connections_open"), ""));
        s.push_str(&format!(
            "# HELP {p}_queue_depth Planner jobs outstanding (queued or \
             running); admission control refuses POSTs past the \
             max-pending bound.\n\
             # TYPE {p}_queue_depth gauge\n"));
        s.push_str(&stats.queue_depth.render(
            &format!("{p}_queue_depth"), ""));
        s.push_str(&format!(
            "# HELP {p}_rejected_total Requests shed with a 503 \
             (admission control or the connection cap).\n\
             # TYPE {p}_rejected_total counter\n"));
        s.push_str(&stats.rejected.render(
            &format!("{p}_rejected_total"), ""));
        s.push_str(&format!(
            "# HELP {p}_request_timeouts_total Request heads that missed \
             their deadline (408s).\n\
             # TYPE {p}_request_timeouts_total counter\n"));
        s.push_str(&stats.timeouts.render(
            &format!("{p}_request_timeouts_total"), ""));
        s.push_str(&format!(
            "# HELP {p}_keepalive_reuses_total Requests served on an \
             already-used connection.\n\
             # TYPE {p}_keepalive_reuses_total counter\n"));
        s.push_str(&stats.keepalive_reuses.render(
            &format!("{p}_keepalive_reuses_total"), ""));
        s.push_str(&format!(
            "# HELP {p}_request_duration_seconds Request latency by \
             endpoint.\n\
             # TYPE {p}_request_duration_seconds histogram\n"));
        for (e, endpoint) in ENDPOINTS.iter().enumerate() {
            s.push_str(&self.latency[e].render(
                &format!("{p}_request_duration_seconds"),
                &format!("endpoint=\"{endpoint}\"")));
        }
        s.push_str(&format!(
            "# HELP {p}_plan_phase_duration_seconds Time spent in each \
             POST /plan handling phase (plan and serialize are zero on \
             cache hits).\n\
             # TYPE {p}_plan_phase_duration_seconds histogram\n"));
        for (i, phase) in PLAN_PHASES.iter().enumerate() {
            s.push_str(&self.plan_phase[i].render(
                &format!("{p}_plan_phase_duration_seconds"),
                &format!("phase=\"{phase}\"")));
        }
        s
    }
}

/// Event-loop operational state, exported in `/metrics` alongside the
/// request counters (fields are touched by the [`event_loop`] module).
struct LoopStats {
    connections: Gauge,
    queue_depth: Gauge,
    rejected: Counter,
    timeouts: Counter,
    keepalive_reuses: Counter,
}

impl LoopStats {
    fn new() -> Self {
        LoopStats {
            connections: Gauge::new(),
            queue_depth: Gauge::new(),
            rejected: Counter::new(),
            timeouts: Counter::new(),
            keepalive_reuses: Counter::new(),
        }
    }
}

// ==========================================================================
// The service
// ==========================================================================

/// JSON error document: `{"error":"…"}` plus newline.
fn error_body(msg: &str) -> Arc<String> {
    let mut s = jobj(vec![("error", Json::Str(msg.to_string()))]).to_string();
    s.push('\n');
    Arc::new(s)
}

/// How a `POST /sweep` was answered: a plain fixed-length response
/// (validation failures), or a chunk stream already emitted through the
/// caller's sink (`code` 200 = complete with terminator due, 500 =
/// truncated mid-stream).
enum SweepOutcome {
    Plain { code: u16, body: Arc<String> },
    Streamed { code: u16 },
}

/// Wall-clock seconds spent in each `POST /plan` handling phase
/// ([`PLAN_PHASES`] order).  On a cache hit, `plan` and `serialize`
/// stay zero and `cache_lookup` absorbs the lookup (including any wait
/// on a coalesced in-flight evaluation).
#[derive(Clone, Copy, Debug, Default)]
pub(super) struct PlanPhases {
    parse_s: f64,
    cache_s: f64,
    plan_s: f64,
    serialize_s: f64,
}

impl PlanPhases {
    fn to_json(self) -> Json {
        jobj(vec![
            ("parse_s", Json::Num(self.parse_s)),
            ("cache_lookup_s", Json::Num(self.cache_s)),
            ("plan_s", Json::Num(self.plan_s)),
            ("serialize_s", Json::Num(self.serialize_s)),
        ])
    }
}

/// The access-log destination, resolved once at startup.
enum LogSink {
    Stderr,
    File(std::fs::File),
}

/// Request-handling state shared by every worker thread: the registries,
/// the single-flight plan cache, the metrics, the request-id counter,
/// the debug ring, and the sweep-shard replica set.
pub struct PlannerService {
    models: ModelRegistry,
    topologies: TopologyRegistry,
    cache: PlanCache,
    metrics: ServiceMetrics,
    stats: LoopStats,
    default_cost: String,
    replicas: Vec<String>,
    /// Source of generated `X-Request-Id`s (requests carrying their own
    /// id keep it; everything else gets the next counter value).
    request_counter: AtomicU64,
    /// Last [`DEBUG_RING_CAP`] served requests, for `GET /debug/trace`.
    debug_ring: Mutex<VecDeque<Json>>,
    access_log: Option<Mutex<LogSink>>,
}

impl PlannerService {
    /// Built-in registries.  Fails if `default_cost` does not resolve —
    /// better at startup than on the first request.
    pub fn new(opts: &ServiceOptions) -> Result<Self> {
        let default_cost = cost_by_name(&opts.default_cost)
            .context("service default cost model")?
            .name()
            .to_string();
        let access_log = match opts.access_log.as_deref() {
            None => None,
            Some("-") => Some(Mutex::new(LogSink::Stderr)),
            Some(path) => Some(Mutex::new(LogSink::File(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)
                    .with_context(|| format!("open access log {path}"))?))),
        };
        Ok(PlannerService {
            models: ModelRegistry::builtin(),
            topologies: TopologyRegistry::builtin(),
            cache: PlanCache::new(opts.cache_entries),
            metrics: ServiceMetrics::new(),
            stats: LoopStats::new(),
            default_cost,
            replicas: opts.replicas.clone(),
            request_counter: AtomicU64::new(0),
            debug_ring: Mutex::new(VecDeque::with_capacity(DEBUG_RING_CAP)),
            access_log,
        })
    }

    /// The plan cache (tests and benches read the hit/miss counters).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    fn stats(&self) -> &LoopStats {
        &self.stats
    }

    /// Record one served request in the metrics (the event loop calls
    /// this when it queues the response bytes).
    fn record_request(&self, endpoint: &str, code: u16, seconds: f64) {
        self.metrics.record(endpoint, code, seconds);
    }

    /// The next generated request id (zero-padded hex, monotonic).
    fn next_request_id(&self) -> String {
        format!("{:016x}",
                self.request_counter.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Record one completed request in the debug ring and, if
    /// configured, the access log (one compact JSON line).  Called by
    /// the event loop when it queues the response bytes, so `seconds`
    /// is the full request wall time.
    fn log_request(&self, id: &str, endpoint: &str, code: u16,
                   seconds: f64, phases: Option<PlanPhases>) {
        let mut pairs = vec![
            ("code", Json::Num(code as f64)),
            ("duration_s", Json::Num(seconds)),
            ("endpoint", Json::Str(endpoint.to_string())),
            ("id", Json::Str(id.to_string())),
        ];
        if let Some(p) = phases {
            pairs.push(("phases", p.to_json()));
        }
        let entry = jobj(pairs);
        {
            let mut ring = self.debug_ring.lock().unwrap();
            if ring.len() >= DEBUG_RING_CAP {
                ring.pop_front();
            }
            ring.push_back(entry.clone());
        }
        if let Some(sink) = &self.access_log {
            // The log line adds a wall-clock stamp; the ring stays
            // stamp-free so /debug/trace bodies are reproducible.
            let ts = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0);
            let mut line = match entry {
                Json::Obj(mut o) => {
                    o.insert("ts".into(), Json::Num(ts));
                    Json::Obj(o).to_string()
                }
                other => other.to_string(),
            };
            line.push('\n');
            let mut sink = sink.lock().unwrap();
            let res = match &mut *sink {
                LogSink::Stderr => std::io::stderr()
                    .write_all(line.as_bytes()),
                LogSink::File(f) => f.write_all(line.as_bytes()),
            };
            if let Err(e) = res {
                eprintln!("warning: access log write failed: {e}");
            }
        }
    }

    /// `GET /debug/trace?n=` document: the most recent `n` ring entries
    /// (default 32), oldest first, as `{"requests":[…]}`.
    fn debug_trace_doc(&self, n: usize) -> Arc<String> {
        let ring = self.debug_ring.lock().unwrap();
        let take = n.min(ring.len());
        let items: Vec<Json> =
            ring.iter().skip(ring.len() - take).cloned().collect();
        let mut s = jobj(vec![("requests", Json::Arr(items))]).to_string();
        s.push('\n');
        Arc::new(s)
    }

    /// `POST /plan`: parse → canonicalise → single-flight cache →
    /// respond.  The 200 body is [`Plan::to_json_string`]
    /// (byte-identical to the `plan` CLI); planner and parse errors are
    /// 400s with `{"error":…}` bodies — and deterministic planner
    /// errors are cached exactly like plans.
    ///
    /// [`Plan::to_json_string`]: crate::planner::Plan::to_json_string
    fn handle_plan(&self, body: &[u8]) -> (u16, Arc<String>) {
        let (code, doc, _) = self.handle_plan_timed(body);
        (code, doc)
    }

    /// [`Self::handle_plan`] with per-phase wall times.  Phase
    /// histograms are observed here (every call, hit or miss); the
    /// caller threads the [`PlanPhases`] into the access log and the
    /// debug ring.
    fn handle_plan_timed(&self, body: &[u8])
                         -> (u16, Arc<String>, PlanPhases) {
        let mut phases = PlanPhases::default();
        let observe = |m: &ServiceMetrics, p: &PlanPhases| {
            m.plan_phase[0].observe(p.parse_s);
            m.plan_phase[1].observe(p.cache_s);
            m.plan_phase[2].observe(p.plan_s);
            m.plan_phase[3].observe(p.serialize_s);
        };
        let t0 = Instant::now();
        let parsed = std::str::from_utf8(body)
            .map_err(anyhow::Error::from)
            .and_then(Json::parse)
            .and_then(|j| plan_request_from_json(&j));
        let resolved = parsed.and_then(|(req, cost_name)| {
            let cost = cost_by_name(
                cost_name.as_deref().unwrap_or(&self.default_cost))?;
            Ok((req, cost))
        });
        phases.parse_s = t0.elapsed().as_secs_f64();
        let (req, cost) = match resolved {
            Ok(p) => p,
            Err(e) => {
                observe(&self.metrics, &phases);
                return (400, error_body(&format!("{e:#}")), phases);
            }
        };
        let key = req.canonical_json(&self.models, cost.name()).to_string();
        let t1 = Instant::now();
        let mut plan_s = 0.0;
        let mut serialize_s = 0.0;
        let (cached, _hit) = self.cache.get_or_compute(&key, || {
            let planner = Planner::with_parts(self.models.clone(),
                                              self.topologies.clone(), cost);
            let tp = Instant::now();
            let plan = planner.plan(&req)?;
            plan_s = tp.elapsed().as_secs_f64();
            let ts = Instant::now();
            let doc = plan.to_json_string();
            serialize_s = ts.elapsed().as_secs_f64();
            Ok(doc)
        });
        phases.plan_s = plan_s;
        phases.serialize_s = serialize_s;
        // The lookup phase is everything around the evaluation itself:
        // key probe, single-flight coordination, LRU bookkeeping.
        phases.cache_s = (t1.elapsed().as_secs_f64() - plan_s - serialize_s)
            .max(0.0);
        observe(&self.metrics, &phases);
        match cached {
            Ok(doc) => (200, doc, phases),
            Err(e) => (400, error_body(&e), phases),
        }
    }

    /// `POST /sweep`: parse + validate, then stream the sweep document
    /// through `emit` — one call per chunk payload, concatenating to
    /// the `sweep` CLI's JSON byte-for-byte.  `emit` only runs after
    /// validation succeeds (so the caller may commit a 200 head on the
    /// first call); validation failures return
    /// [`SweepOutcome::Plain`] 400s.  With a replica set configured,
    /// markerless requests fan out ([`Self::coordinate_sweep`]); a
    /// request carrying a `"shard"` marker always evaluates locally,
    /// so fan-out cannot recurse.
    fn respond_sweep(&self, body: &[u8],
                     emit: &mut dyn FnMut(&[u8]) -> Result<()>)
                     -> SweepOutcome {
        let doc = match std::str::from_utf8(body)
            .map_err(anyhow::Error::from)
            .and_then(Json::parse)
        {
            Ok(d) => d,
            Err(e) => {
                return SweepOutcome::Plain {
                    code: 400, body: error_body(&format!("{e:#}")) };
            }
        };
        let mut obj = match doc.as_obj() {
            Ok(o) => o.clone(),
            Err(e) => {
                return SweepOutcome::Plain {
                    code: 400, body: error_body(&format!("{e:#}")) };
            }
        };
        let marker = obj.remove("shard");
        let spec_obj = obj;
        let validated = SweepSpec::from_json(&Json::Obj(spec_obj.clone()))
            .and_then(|mut spec| {
                spec.validate()?;
                cost_by_name(&spec.cost_model)?;
                if spec.cardinality() > MAX_SWEEP_SCENARIOS {
                    bail!("sweep grid of {} scenarios exceeds the \
                           service cap of {MAX_SWEEP_SCENARIOS} — split \
                           the request", spec.cardinality());
                }
                // Worker threads are a server resource: clamp the
                // client's request to this host's cores (0 already
                // means one per core, which effective_threads resolves).
                if spec.threads != 0 {
                    let cores = std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1);
                    spec.threads = spec.threads.min(cores);
                }
                Ok(spec)
            });
        let spec = match validated {
            Ok(s) => s,
            Err(e) => {
                return SweepOutcome::Plain {
                    code: 400, body: error_body(&format!("{e:#}")) };
            }
        };
        let indices = match &marker {
            None => None,
            Some(j) => match parse_shard_marker(j, spec.cardinality()) {
                Ok(v) => Some(v),
                Err(e) => {
                    return SweepOutcome::Plain {
                        code: 400, body: error_body(&format!("{e:#}")) };
                }
            },
        };
        if indices.is_none() && !self.replicas.is_empty() {
            return self.coordinate_sweep(&spec, &spec_obj, emit);
        }
        let mut first = true;
        let streamed =
            stream_sweep_indices(&spec, indices.as_deref(), |r| {
                let mut chunk = String::new();
                chunk.push_str(if first { "{\"scenarios\":[" } else { "," });
                first = false;
                chunk.push_str(&r.to_json().to_string());
                emit(chunk.as_bytes())
            });
        if streamed.is_err() {
            return SweepOutcome::Streamed { code: 500 };
        }
        let tail: &[u8] =
            if first { b"{\"scenarios\":[]}\n" } else { b"]}\n" };
        if emit(tail).is_err() {
            return SweepOutcome::Streamed { code: 500 };
        }
        SweepOutcome::Streamed { code: 200 }
    }

    /// Fan a validated sweep out across [`ServiceOptions::replicas`]:
    /// consistent-hash the canonical scenario list, POST each replica
    /// its share (pinned by an explicit `"shard":{"indices":…}` marker
    /// so both sides agree exactly), and splice the returned chunk
    /// payloads back into canonical order through the same reorder
    /// buffer the local sweep engine uses.  Because every replica
    /// serialises scenarios with the one shared writer, the merged
    /// stream is byte-identical to a single-daemon response.  A replica
    /// failure truncates the stream (or, before anything was emitted,
    /// returns a clean 500 document).
    fn coordinate_sweep(&self, spec: &SweepSpec,
                        client_obj: &BTreeMap<String, Json>,
                        emit: &mut dyn FnMut(&[u8]) -> Result<()>)
                        -> SweepOutcome {
        let scenarios = spec.scenarios();
        if scenarios.is_empty() {
            return match emit(b"{\"scenarios\":[]}\n") {
                Ok(()) => SweepOutcome::Streamed { code: 200 },
                Err(_) => SweepOutcome::Streamed { code: 500 },
            };
        }
        let ring = shard::HashRing::new(&self.replicas);
        let owned = ring.assign(&scenarios);
        type Delivery = std::result::Result<(usize, Vec<u8>), String>;
        let (tx, rx) = mpsc::channel::<Delivery>();
        let mut emitted_any = false;
        let mut failed: Option<String> = None;
        std::thread::scope(|scope| {
            for (r, indices) in owned.iter().enumerate() {
                if indices.is_empty() {
                    continue;
                }
                let tx = tx.clone();
                let addr = self.replicas[r].clone();
                let mut body_obj = client_obj.clone();
                body_obj.insert("shard".into(), jobj(vec![(
                    "indices",
                    Json::Arr(indices.iter()
                        .map(|&i| Json::Num(i as f64))
                        .collect()),
                )]));
                let body = Json::Obj(body_obj).to_string();
                scope.spawn(move || {
                    replica_reader(&addr, body.as_bytes(), indices, &tx);
                });
            }
            drop(tx);
            let mut slots: Vec<Option<Vec<u8>>> = Vec::new();
            slots.resize_with(scenarios.len(), || None);
            let mut flushed = 0usize;
            'recv: for msg in rx.iter() {
                match msg {
                    Err(e) => {
                        failed = Some(e);
                        break 'recv;
                    }
                    Ok((i, payload)) => {
                        slots[i] = Some(payload);
                        while flushed < slots.len()
                            && slots[flushed].is_some()
                        {
                            let payload = slots[flushed].take().unwrap();
                            let mut chunk: Vec<u8> = if flushed == 0 {
                                b"{\"scenarios\":[".to_vec()
                            } else {
                                vec![b',']
                            };
                            chunk.extend_from_slice(&payload);
                            flushed += 1;
                            if emit(&chunk).is_err() {
                                failed = Some("client went away".into());
                                break 'recv;
                            }
                            emitted_any = true;
                        }
                    }
                }
            }
            // Dropping the receiver aborts any replica stream still in
            // flight (its next delivery fails, cancelling the read).
            drop(rx);
        });
        match failed {
            None => {
                if emit(b"]}\n").is_ok() {
                    SweepOutcome::Streamed { code: 200 }
                } else {
                    SweepOutcome::Streamed { code: 500 }
                }
            }
            Some(e) if emitted_any => {
                eprintln!("warning: sharded sweep truncated: {e}");
                SweepOutcome::Streamed { code: 500 }
            }
            Some(e) => SweepOutcome::Plain {
                code: 500,
                body: error_body(&format!("sharded sweep failed: {e}")),
            },
        }
    }

    /// `GET /models` document.
    fn models_doc(&self) -> Arc<String> {
        let entries: Vec<Json> = self
            .models
            .entries()
            .iter()
            .map(|e| jobj(vec![
                ("name", Json::Str(e.name.into())),
                ("aliases",
                 Json::Arr(e.aliases
                     .iter()
                     .map(|&a| Json::Str(a.into()))
                     .collect())),
                ("default_batch", Json::Num(e.default_batch as f64)),
            ]))
            .collect();
        let mut s = jobj(vec![("models", Json::Arr(entries))]).to_string();
        s.push('\n');
        Arc::new(s)
    }

    /// `GET /topologies` document (`max_devices` is `null` for
    /// unbounded scale-out entries).
    fn topologies_doc(&self) -> Arc<String> {
        let entries: Vec<Json> = self
            .topologies
            .entries()
            .iter()
            .map(|e| jobj(vec![
                ("name", Json::Str(e.name.into())),
                ("aliases",
                 Json::Arr(e.aliases
                     .iter()
                     .map(|&a| Json::Str(a.into()))
                     .collect())),
                ("max_devices",
                 if e.max_devices == usize::MAX {
                     Json::Null
                 } else {
                     Json::Num(e.max_devices as f64)
                 }),
                ("multi_node", Json::Bool(e.build_pod.is_some())),
            ]))
            .collect();
        let mut s =
            jobj(vec![("topologies", Json::Arr(entries))]).to_string();
        s.push('\n');
        Arc::new(s)
    }

    /// `GET /metrics` document (Prometheus text exposition).
    pub fn metrics_doc(&self) -> String {
        self.metrics.render(&self.cache, &self.stats)
    }
}

/// Parse and validate a `"shard"` marker: `{"indices": [i, …]}` with
/// strictly increasing indices inside the grid.
fn parse_shard_marker(j: &Json, cardinality: usize) -> Result<Vec<usize>> {
    let obj = j.as_obj().context("'shard' must be an object")?;
    if let Some(k) = obj.keys().find(|k| k.as_str() != "indices") {
        bail!("unknown shard key '{k}' (expected 'indices')");
    }
    let arr = obj
        .get("indices")
        .ok_or_else(|| anyhow!("'shard' lacks 'indices'"))?
        .as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        out.push(v.as_usize()?);
    }
    if out.windows(2).any(|w| w[0] >= w[1]) {
        bail!("shard indices must be strictly increasing");
    }
    if let Some(&last) = out.last() {
        if last >= cardinality {
            bail!("shard index {last} is outside the \
                   {cardinality}-scenario grid");
        }
    }
    Ok(out)
}

/// Un-frame one replica chunk payload: strip the document prefix
/// (first chunk) or the separator (later chunks) and return the bare
/// scenario JSON; `None` for the document terminator.
fn shard_payload(chunk: &[u8], k: usize) -> Result<Option<Vec<u8>>> {
    if chunk == b"]}\n" || chunk == b"{\"scenarios\":[]}\n" {
        return Ok(None);
    }
    let payload = if k == 0 {
        chunk
            .strip_prefix(b"{\"scenarios\":[" as &[u8])
            .ok_or_else(|| anyhow!("first chunk lacks the document prefix"))?
    } else {
        chunk
            .strip_prefix(b"," as &[u8])
            .ok_or_else(|| anyhow!("chunk lacks the ',' separator"))?
    };
    Ok(Some(payload.to_vec()))
}

/// One coordinator→replica stream: POST the shard, map the replica's
/// k-th scenario payload to its k-th owned global index, deliver in
/// order.  Every failure mode becomes one `Err` delivery.
fn replica_reader(addr: &str, body: &[u8], indices: &[usize],
                  tx: &mpsc::Sender<std::result::Result<(usize, Vec<u8>),
                                                        String>>) {
    let mut k = 0usize;
    let mut on_chunk = |payload: &[u8]| -> Result<()> {
        let Some(json) = shard_payload(payload, k)? else {
            return Ok(());
        };
        let &i = indices.get(k).ok_or_else(|| {
            anyhow!("more scenarios than the {} assigned", indices.len())
        })?;
        k += 1;
        tx.send(Ok((i, json)))
            .map_err(|_| anyhow!("merge aborted"))
    };
    match http::post_and_stream_chunks(addr, "/sweep", body,
                                       REPLICA_IO_TIMEOUT, &mut on_chunk) {
        Ok(200) => {
            if k != indices.len() {
                let _ = tx.send(Err(format!(
                    "replica {addr} streamed {k}/{} assigned scenarios",
                    indices.len())));
            }
        }
        Ok(code) => {
            let _ = tx.send(Err(format!(
                "replica {addr} answered HTTP {code}")));
        }
        Err(e) => {
            let _ = tx.send(Err(format!("replica {addr}: {e:#}")));
        }
    }
}

// ==========================================================================
// The daemon
// ==========================================================================

/// A bound-but-not-yet-serving daemon: bind first so callers can learn
/// the ephemeral port (tests bind `127.0.0.1:0`) before the event loop
/// starts.
pub struct BoundService {
    listener: TcpListener,
    service: Arc<PlannerService>,
    opts: ServiceOptions,
}

/// Bind `addr` with the given options.  If a cache snapshot is
/// configured and present, the warm set loads here (corrupt or missing
/// snapshots never stop a daemon from starting).
pub fn bind(addr: &str, opts: ServiceOptions) -> Result<BoundService> {
    let service = Arc::new(PlannerService::new(&opts)?);
    if let Some(path) = &opts.persist_path {
        match service.cache().load(path) {
            Ok(0) => {}
            Ok(n) => eprintln!("plan cache: reloaded {n} entries from {}",
                               path.display()),
            Err(e) => eprintln!("warning: cache snapshot ignored: {e:#}"),
        }
    }
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    Ok(BoundService { listener, service, opts })
}

impl BoundService {
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    pub fn service(&self) -> &PlannerService {
        &self.service
    }

    /// Serve on the calling thread until the process dies (the `serve`
    /// CLI path).
    pub fn serve_forever(self) -> Result<()> {
        let shutdown = AtomicBool::new(false);
        event_loop::serve_event_loop(&self.listener, &self.service,
                                     &self.opts, &shutdown)
    }

    /// Serve on a background thread; the returned handle stops the
    /// daemon cleanly (tests and benches).
    pub fn spawn(self) -> ServiceHandle {
        let addr = self.local_addr();
        let shutdown = Arc::new(AtomicBool::new(false));
        let service = self.service.clone();
        let sd = shutdown.clone();
        let opts = self.opts.clone();
        let listener = self.listener;
        let join = std::thread::spawn(move || {
            let _ = event_loop::serve_event_loop(&listener, &service,
                                                 &opts, &sd);
        });
        ServiceHandle { addr, service: self.service, shutdown, join }
    }
}

/// A running background daemon (from [`BoundService::spawn`]).
pub struct ServiceHandle {
    addr: SocketAddr,
    service: Arc<PlannerService>,
    shutdown: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl ServiceHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &PlannerService {
        &self.service
    }

    /// Flip the shutdown flag and join the loop (which cancels
    /// in-flight streams, drains the workers, and snapshots the cache
    /// if persistence is configured).  The polling loop notices within
    /// one idle tick — no wake-up connection needed.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = self.join.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::sweep::run_sweep;

    #[test]
    fn endpoint_index_is_total() {
        for e in ENDPOINTS {
            assert_eq!(ENDPOINTS[ServiceMetrics::endpoint_index(e)], e);
        }
        assert_eq!(ServiceMetrics::endpoint_index("bogus"),
                   ENDPOINTS.len() - 1);
    }

    #[test]
    fn metrics_doc_renders_every_series() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        svc.metrics.record("plan", 200, 0.001);
        svc.metrics.record("plan", 503, 0.002);
        svc.stats.rejected.inc();
        svc.stats.queue_depth.set(3);
        let doc = svc.metrics_doc();
        assert!(doc.contains(
            "hybridpar_service_requests_total{endpoint=\"plan\",\
             code=\"200\"} 1"), "{doc}");
        assert!(doc.contains(
            "hybridpar_service_requests_total{endpoint=\"plan\",\
             code=\"503\"} 1"), "{doc}");
        assert!(doc.contains("hybridpar_service_plan_cache_hits_total 0"));
        assert!(doc.contains(
            "hybridpar_service_plan_cache_error_hits_total 0"));
        assert!(doc.contains("hybridpar_service_plan_cache_misses_total 0"));
        assert!(doc.contains("hybridpar_service_connections_open 0"));
        assert!(doc.contains("hybridpar_service_queue_depth 3"));
        assert!(doc.contains("hybridpar_service_rejected_total 1"));
        assert!(doc.contains("hybridpar_service_request_timeouts_total 0"));
        assert!(doc.contains("hybridpar_service_keepalive_reuses_total 0"));
        assert!(doc.contains(
            "hybridpar_service_request_duration_seconds_bucket\
             {endpoint=\"plan\","), "{doc}");
        assert!(doc.contains(
            "hybridpar_service_request_duration_seconds_count\
             {endpoint=\"plan\"} 2"), "{doc}");
        // The per-phase plan histograms render for every phase label,
        // the debug endpoint has its own request series.
        for phase in PLAN_PHASES {
            assert!(doc.contains(&format!(
                "hybridpar_service_plan_phase_duration_seconds_bucket\
                 {{phase=\"{phase}\",")), "{phase}: {doc}");
        }
        assert!(doc.contains(
            "hybridpar_service_requests_total{endpoint=\"debug\",\
             code=\"200\"} 0"), "{doc}");
    }

    #[test]
    fn plan_phases_are_observed_and_sum_close_to_the_handler_time() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let (code, _, phases) =
            svc.handle_plan_timed(br#"{"model":"gnmt","devices":8}"#);
        assert_eq!(code, 200);
        assert!(phases.parse_s >= 0.0 && phases.plan_s > 0.0,
                "a cache miss runs the planner: {phases:?}");
        // Every phase histogram saw exactly one observation.
        for (i, phase) in PLAN_PHASES.iter().enumerate() {
            assert_eq!(svc.metrics.plan_phase[i].count(), 1, "{phase}");
        }
        // A repeat is a cache hit: plan and serialize stay zero.
        let (_, _, hit) =
            svc.handle_plan_timed(br#"{"model":"gnmt","devices":8}"#);
        assert_eq!((hit.plan_s, hit.serialize_s), (0.0, 0.0));
        assert_eq!(svc.metrics.plan_phase[2].count(), 2);
        // Parse failures still observe (as near-zero plan/serialize).
        let (code, _, _) = svc.handle_plan_timed(b"not json");
        assert_eq!(code, 400);
        assert_eq!(svc.metrics.plan_phase[0].count(), 3);
    }

    #[test]
    fn debug_ring_keeps_the_last_entries_in_order() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        for i in 0..(DEBUG_RING_CAP + 10) {
            svc.log_request(&format!("{i:x}"), "healthz", 200,
                            1e-4, None);
        }
        let all = svc.debug_trace_doc(usize::MAX);
        let doc = Json::parse(&all).unwrap();
        let rows = doc.as_obj().unwrap()["requests"].as_arr().unwrap();
        assert_eq!(rows.len(), DEBUG_RING_CAP, "ring is bounded");
        let first = rows[0].as_obj().unwrap()["id"].as_str().unwrap();
        assert_eq!(first, format!("{:x}", 10), "oldest survivors first");
        // ?n= trims to the most recent n, still oldest-first.
        let tail = svc.debug_trace_doc(2);
        let doc = Json::parse(&tail).unwrap();
        let rows = doc.as_obj().unwrap()["requests"].as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let last = rows[1].as_obj().unwrap()["id"].as_str().unwrap();
        assert_eq!(last, format!("{:x}", DEBUG_RING_CAP + 9));
    }

    #[test]
    fn plan_phases_land_in_the_ring_and_ids_are_unique() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let (_, _, phases) =
            svc.handle_plan_timed(br#"{"model":"gnmt","devices":8}"#);
        let (a, b) = (svc.next_request_id(), svc.next_request_id());
        assert_ne!(a, b, "generated request ids must be unique");
        svc.log_request(&a, "plan", 200, 0.01, Some(phases));
        let doc = svc.debug_trace_doc(1);
        assert!(doc.contains("\"phases\":{"), "{doc}");
        assert!(doc.contains("\"plan_s\":"), "{doc}");
        assert!(doc.contains(&format!("\"id\":\"{a}\"")), "{doc}");
        Json::parse(&doc).unwrap();
    }

    #[test]
    fn plan_handler_caches_and_matches_cli_document() {
        use crate::planner::{PlanRequest, Planner};
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let body = br#"{"model":"gnmt","devices":8}"#;
        let (code, doc) = svc.handle_plan(body);
        assert_eq!(code, 200);
        let want = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap()
            .to_json_string();
        assert_eq!(doc.as_str(), want,
                   "service body must be byte-identical to the CLI doc");
        // Alias + explicitly-spelled defaults share the entry.
        let (code, doc2) = svc.handle_plan(
            br#"{"model":"gnmt","topology":"dgx1","devices":8,
                 "cost":"analytical"}"#);
        assert_eq!(code, 200);
        assert_eq!(doc2, doc);
        assert_eq!((svc.cache().hits(), svc.cache().misses()), (1, 1));
    }

    #[test]
    fn plan_handler_rejects_bad_bodies() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let bad_bodies: [&[u8]; 3] = [b"not json", br#"{"devices":8}"#,
                                      br#"{"model":"gnmt","bogus_key":1}"#];
        for bad in bad_bodies {
            let (code, body) = svc.handle_plan(bad);
            assert_eq!(code, 400, "{body}");
            assert!(body.starts_with("{\"error\":"), "{body}");
        }
        // Unknown models are planner errors: 400, and cached — but the
        // repeat is an *error hit*, not a plan hit (it was served a 400
        // body).
        let (code, _) = svc.handle_plan(br#"{"model":"alexnet"}"#);
        assert_eq!(code, 400);
        let (code, _) = svc.handle_plan(br#"{"model":"alexnet"}"#);
        assert_eq!(code, 400);
        assert_eq!(svc.cache().error_hits(), 1,
                   "deterministic planner errors are cached");
        assert_eq!(svc.cache().hits(), 0,
                   "an error-served waiter must not count as a plan hit");
    }

    fn collect_sweep(svc: &PlannerService, body: &[u8])
                     -> (Option<u16>, u16, Vec<Vec<u8>>) {
        let mut chunks: Vec<Vec<u8>> = Vec::new();
        let outcome = svc.respond_sweep(body, &mut |c: &[u8]| {
            chunks.push(c.to_vec());
            Ok(())
        });
        match outcome {
            SweepOutcome::Plain { code, .. } => (Some(code), 0, chunks),
            SweepOutcome::Streamed { code } => (None, code, chunks),
        }
    }

    #[test]
    fn respond_sweep_concatenates_to_the_cli_document() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let body = br#"{"models":["gnmt"],"devices":[4,8],
                        "families":["dp"],"threads":1}"#;
        let (plain, code, chunks) = collect_sweep(&svc, body);
        assert_eq!(plain, None);
        assert_eq!(code, 200);
        let merged: Vec<u8> = chunks.concat();
        let spec = SweepSpec {
            models: vec!["gnmt".into()],
            devices: vec![4, 8],
            families: vec![crate::planner::sweep::StrategyFamily::DpOnly],
            threads: 1,
            ..Default::default()
        };
        let want = run_sweep(&spec).unwrap().to_json_string();
        assert_eq!(String::from_utf8(merged).unwrap(), want,
                   "chunk concatenation must be byte-identical to the CLI");
    }

    #[test]
    fn respond_sweep_shard_marker_selects_a_subset() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let body = br#"{"models":["gnmt"],"devices":[4,8],
                        "families":["dp"],"threads":1,
                        "shard":{"indices":[1]}}"#;
        let (plain, code, chunks) = collect_sweep(&svc, body);
        assert_eq!(plain, None);
        assert_eq!(code, 200);
        let merged = String::from_utf8(chunks.concat()).unwrap();
        let doc = Json::parse(&merged).unwrap();
        let rows = doc.as_obj().unwrap()["scenarios"].as_arr().unwrap();
        assert_eq!(rows.len(), 1, "{merged}");
        assert_eq!(rows[0].as_obj().unwrap()["devices"].as_usize().unwrap(),
                   8, "index 1 of the devices axis");
    }

    #[test]
    fn respond_sweep_rejects_bad_shard_markers() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        for marker in [r#"{"indices":[1,0]}"#,   // not increasing
                       r#"{"indices":[99]}"#,    // outside the grid
                       r#"{"bogus":[]}"#,        // unknown key
                       r#"[]"#] {                // not an object
            let body = format!(
                r#"{{"models":["gnmt"],"devices":[4,8],
                     "families":["dp"],"shard":{marker}}}"#);
            let (plain, _, chunks) = collect_sweep(&svc, body.as_bytes());
            assert_eq!(plain, Some(400), "marker {marker}");
            assert!(chunks.is_empty(),
                    "validation failures must not emit chunks");
        }
    }

    #[test]
    fn shard_payload_unframes_replica_chunks() {
        assert_eq!(
            shard_payload(b"{\"scenarios\":[{\"a\":1}", 0).unwrap(),
            Some(b"{\"a\":1}".to_vec()));
        assert_eq!(shard_payload(b",{\"b\":2}", 1).unwrap(),
                   Some(b"{\"b\":2}".to_vec()));
        assert_eq!(shard_payload(b"]}\n", 2).unwrap(), None);
        assert_eq!(shard_payload(b"{\"scenarios\":[]}\n", 0).unwrap(), None);
        assert!(shard_payload(b"{\"a\":1}", 1).is_err(),
                "a later chunk without the separator is malformed");
    }

    #[test]
    fn registry_docs_list_the_catalogs() {
        let svc =
            PlannerService::new(&ServiceOptions::default()).unwrap();
        let models = svc.models_doc();
        assert!(models.contains("\"inception-v3\""), "{models}");
        assert!(models.contains("\"default_batch\":128"), "{models}");
        let topos = svc.topologies_doc();
        assert!(topos.contains("\"dgx1-pod\""), "{topos}");
        assert!(topos.contains("\"max_devices\":null"), "{topos}");
        assert!(topos.contains("\"multi_node\":true"), "{topos}");
        // Both parse back as JSON.
        Json::parse(&models).unwrap();
        Json::parse(&topos).unwrap();
    }

    #[test]
    fn bad_default_cost_fails_at_startup() {
        let opts = ServiceOptions {
            default_cost: "crystal-ball".into(),
            ..Default::default()
        };
        assert!(PlannerService::new(&opts).is_err());
    }
}
