//! Minimal HTTP/1.1 framing for the planner service (hyper unavailable
//! offline; see DESIGN.md substitutions).
//!
//! Covers exactly what the event-loop service needs:
//!
//! * an **incremental request parser** ([`try_parse_request`]) that
//!   works over an accumulating byte buffer — it reports "need more
//!   bytes" instead of blocking, which is what lets one thread poll
//!   thousands of keep-alive connections;
//! * response **encoders** ([`encode_response`], [`encode_chunked_head`],
//!   [`encode_chunk`], [`CHUNK_END`]) that produce complete wire bytes
//!   for the loop to write as the socket drains;
//! * a small **blocking client** ([`post_and_stream_chunks`]) used by
//!   the sweep-shard coordinator to fan a grid out to replica daemons
//!   and read their chunk streams frame-by-frame.
//!
//! Responses carry `Connection: keep-alive` or `Connection: close`
//! explicitly; the service keeps connections open across requests
//! unless the client asked to close, the response has no length
//! framing, or the server is shedding load.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed request line + headers + body.
#[derive(Clone, Debug)]
pub struct Request {
    /// Uppercase method ("GET", "POST", …).
    pub method: String,
    /// Path with any `?query` suffix stripped.
    pub path: String,
    /// The raw query string after `?` (empty when absent) —
    /// `GET /debug/trace?n=16` reads its `n` from here.
    pub query: String,
    /// Lowercased header names, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a (lowercase) header name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client asked for the connection to stay open after
    /// this request (HTTP/1.1 default yes, overridden by
    /// `Connection: close`).
    pub fn wants_keep_alive(&self) -> bool {
        !matches!(self.header("connection"),
                  Some(v) if v.eq_ignore_ascii_case("close"))
    }
}

/// Cap on the request line + headers (pre-body) section.  Public so the
/// event loop can reject a head that grew past the cap *before* a
/// terminator arrives — a slow-loris trickling header bytes must not
/// hold buffer space until some larger limit trips.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on the request body (a `SweepSpec` is well under this).
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// Outcome of one incremental parse attempt over the connection's
/// accumulated read buffer.
pub enum ParseStatus {
    /// No complete request yet — keep the buffer, read more bytes.
    NeedMore,
    /// One complete request; `consumed` bytes of the buffer belong to
    /// it (the remainder is pipelined input for the next request).
    Complete { req: Request, consumed: usize },
}

/// Find the end of the head section (the byte *after* the blank line),
/// accepting both CRLF and bare-LF line endings.
fn head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
            if buf.get(i + 1) == Some(&b'\r')
                && buf.get(i + 2) == Some(&b'\n')
            {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// Try to parse one request from the front of `buf`.  Returns
/// [`ParseStatus::NeedMore`] while the head or declared body is still
/// incomplete; fails loudly on malformed framing or oversized
/// heads/bodies (the caller maps a failure to a 400 and closes — the
/// byte stream is unrecoverable after a framing error).
pub fn try_parse_request(buf: &[u8]) -> Result<ParseStatus> {
    let Some(head_len) = head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        return Ok(ParseStatus::NeedMore);
    };
    if head_len > MAX_HEAD_BYTES {
        bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
    }
    let head = std::str::from_utf8(&buf[..head_len])
        .context("request head is not UTF-8")?;
    let mut lines = head.lines();
    let request_line = lines
        .next()
        .filter(|l| !l.is_empty())
        .ok_or_else(|| anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("missing method"))?
        .to_ascii_uppercase();
    let raw_path = parts
        .next()
        .ok_or_else(|| anyhow!("missing request path"))?;
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("missing HTTP version"))?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol '{version}'");
    }
    let (path, query) = match raw_path.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (raw_path.to_string(), String::new()),
    };
    let mut headers = Vec::new();
    for l in lines {
        if l.is_empty() {
            break;
        }
        let (k, v) = l
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line '{l}'"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length = match headers
        .iter()
        .find(|(k, _)| k == "content-length")
    {
        None => 0usize,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|e| anyhow!("bad content-length '{v}': {e}"))?,
    };
    if content_length > MAX_BODY_BYTES {
        bail!("request body of {content_length} bytes exceeds the \
               {MAX_BODY_BYTES}-byte cap");
    }
    if buf.len() < head_len + content_length {
        return Ok(ParseStatus::NeedMore);
    }
    let body = buf[head_len..head_len + content_length].to_vec();
    Ok(ParseStatus::Complete {
        req: Request { method, path, query, headers, body },
        consumed: head_len + content_length,
    })
}

/// Status-line reason phrases for every code the service can emit.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Encode a complete fixed-length response (`Content-Length` framing).
/// `extra_headers` lets load-shedding responses carry `Retry-After`.
pub fn encode_response(status: u16, content_type: &str, body: &[u8],
                       keep_alive: bool,
                       extra_headers: &[(&str, &str)]) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: {}\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" });
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Encode the head of a chunked-transfer response.  Chunked responses
/// always close the connection: the stream may legitimately end
/// truncated (a sweep failing after the 200 head is committed), and a
/// truncated chunk stream on a kept-alive connection would desync the
/// client's framing.  `extra_headers` carries `X-Request-Id`.
pub fn encode_chunked_head(status: u16, content_type: &str,
                           extra_headers: &[(&str, &str)]) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\n\
         Content-Type: {content_type}\r\n\
         Transfer-Encoding: chunked\r\n\
         Connection: close\r\n",
        reason(status));
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    head.into_bytes()
}

/// Encode one chunk frame (empty input encodes nothing — a zero-length
/// chunk would terminate the stream).
pub fn encode_chunk(data: &[u8]) -> Vec<u8> {
    if data.is_empty() {
        return Vec::new();
    }
    let mut out = format!("{:x}\r\n", data.len()).into_bytes();
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
    out
}

/// The chunk-stream terminator.  *Not* writing it leaves the client
/// with a truncated stream — exactly right when a sweep fails
/// mid-flight, since the committed 200 head cannot be taken back.
pub const CHUNK_END: &[u8] = b"0\r\n\r\n";

// ==========================================================================
// Blocking client (sweep-shard coordinator side)
// ==========================================================================

/// Read from `stream` until `buf` satisfies `done`, in `step`-byte
/// reads.  Fails on EOF before `done`.
fn read_until<F>(stream: &mut TcpStream, buf: &mut Vec<u8>, step: usize,
                 mut done: F) -> Result<()>
where
    F: FnMut(&[u8]) -> bool,
{
    let mut tmp = vec![0u8; step];
    while !done(buf) {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            bail!("peer closed mid-response");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    Ok(())
}

/// POST `body` to `http://{addr}{path}` and stream the chunked response
/// back one frame at a time: `on_chunk` sees exactly the payloads the
/// replica's writer emitted, in order, which is what lets the shard
/// coordinator splice replica streams without re-framing.  Returns the
/// response status.  `on_chunk` only runs for 200 responses — an error
/// document is consumed and discarded, leaving the status to speak.
pub fn post_and_stream_chunks<F>(addr: &str, path: &str, body: &[u8],
                                 timeout: Duration, on_chunk: &mut F)
                                 -> Result<u16>
where
    F: FnMut(&[u8]) -> Result<()>,
{
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting replica {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;
    let head = format!(
        "POST {path} HTTP/1.1\r\n\
         Host: {addr}\r\n\
         Content-Type: application/json\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n",
        body.len());
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut buf = Vec::new();
    read_until(&mut stream, &mut buf, 4096, |b| head_end(b).is_some())?;
    let head_len = head_end(&buf).expect("read_until guaranteed a head");
    let head_text = std::str::from_utf8(&buf[..head_len])
        .context("replica response head is not UTF-8")?;
    let mut lines = head_text.lines();
    let status_line = lines
        .next()
        .ok_or_else(|| anyhow!("empty replica response"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed status line '{status_line}'"))?
        .parse()
        .with_context(|| format!("status in '{status_line}'"))?;
    let mut chunked = false;
    let mut content_length = 0usize;
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            let (k, v) = (k.trim().to_ascii_lowercase(),
                          v.trim().to_ascii_lowercase());
            if k == "transfer-encoding" && v.contains("chunked") {
                chunked = true;
            } else if k == "content-length" {
                content_length = v.parse().with_context(|| {
                    format!("replica content-length '{v}'")
                })?;
            }
        }
    }
    buf.drain(..head_len);

    if !chunked {
        read_until(&mut stream, &mut buf, 4096,
                   |b| b.len() >= content_length)?;
        if status == 200 {
            on_chunk(&buf[..content_length])?;
        }
        return Ok(status);
    }
    loop {
        // Chunk-size line, then payload + CRLF.
        read_until(&mut stream, &mut buf, 4096, |b| {
            b.windows(2).any(|w| w == b"\r\n")
        })?;
        let nl = buf
            .windows(2)
            .position(|w| w == b"\r\n")
            .expect("read_until guaranteed a CRLF");
        let size_text = std::str::from_utf8(&buf[..nl])
            .context("chunk size is not UTF-8")?;
        let size = usize::from_str_radix(size_text.trim(), 16)
            .with_context(|| format!("chunk size '{size_text}'"))?;
        buf.drain(..nl + 2);
        if size == 0 {
            return Ok(status);
        }
        read_until(&mut stream, &mut buf, 4096, |b| b.len() >= size + 2)?;
        if status == 200 {
            on_chunk(&buf[..size])?;
        }
        buf.drain(..size + 2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &[u8]) -> Result<Request> {
        match try_parse_request(raw)? {
            ParseStatus::Complete { req, .. } => Ok(req),
            ParseStatus::NeedMore => bail!("incomplete"),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            b"POST /plan?x=1 HTTP/1.1\r\n\
              Host: localhost\r\n\
              Content-Type: application/json\r\n\
              Content-Length: 16\r\n\
              \r\n\
              {\"model\":\"gnmt\"}").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/plan", "query string must be stripped");
        assert_eq!(req.query, "x=1", "query string must be kept aside");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"{\"model\":\"gnmt\"}");
        assert!(req.wants_keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_bodyless_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.query.is_empty());
        assert!(req.body.is_empty());
    }

    #[test]
    fn connection_close_is_honoured() {
        let req = parse(
            b"GET /healthz HTTP/1.1\r\nConnection: Close\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive());
    }

    #[test]
    fn incremental_parse_reports_need_more_then_pipelined_leftover() {
        let full = b"POST /plan HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET ";
        // Every strict prefix of the complete request is NeedMore.
        for cut in 0..full.len() - 5 {
            assert!(matches!(try_parse_request(&full[..cut]).unwrap(),
                             ParseStatus::NeedMore),
                    "cut at {cut}");
        }
        // The full buffer parses one request and reports the consumed
        // length, leaving the pipelined "GET " for the next round.
        match try_parse_request(full).unwrap() {
            ParseStatus::Complete { req, consumed } => {
                assert_eq!(req.body, b"hi");
                assert_eq!(&full[consumed..], b"GET ");
            }
            ParseStatus::NeedMore => panic!("complete request not parsed"),
        }
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse(b"\r\n\r\n").is_err());
        assert!(parse(b"GET /x\r\n\r\n").is_err(), "missing version");
        assert!(parse(b"GET /x SMTP/1.0\r\n\r\n").is_err());
        assert!(parse(b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: oops\r\n\r\n")
                    .is_err());
    }

    #[test]
    fn oversized_head_is_rejected_even_without_a_terminator() {
        // A slow-loris head: no blank line, just bytes past the cap.
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        while raw.len() <= MAX_HEAD_BYTES {
            raw.extend_from_slice(b"X-Padding: aaaaaaaaaaaaaaaa\r\n");
        }
        assert!(try_parse_request(&raw).is_err());
    }

    #[test]
    fn rejects_oversized_bodies() {
        let head = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                           MAX_BODY_BYTES + 1);
        assert!(try_parse_request(head.as_bytes()).is_err());
    }

    #[test]
    fn response_encoding_carries_connection_and_extras() {
        let ok = encode_response(200, "application/json", b"{}\n", true, &[]);
        let text = String::from_utf8(ok).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}\n"), "{text}");

        let shed = encode_response(503, "application/json", b"{}\n", false,
                                   &[("Retry-After", "1")]);
        let text = String::from_utf8(shed).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"),
                "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");

        assert_eq!(reason(408), "Request Timeout");
    }

    #[test]
    fn chunk_frames_round_trip() {
        assert!(encode_chunk(b"").is_empty(),
                "empty chunk must not terminate the stream");
        let frame = encode_chunk(b"hello");
        assert_eq!(frame, b"5\r\nhello\r\n");
        let head = String::from_utf8(encode_chunked_head(
            200, "application/json", &[("X-Request-Id", "2a")])).unwrap();
        assert!(head.contains("Transfer-Encoding: chunked\r\n"), "{head}");
        assert!(head.contains("Connection: close\r\n"), "{head}");
        assert!(head.contains("X-Request-Id: 2a\r\n"), "{head}");
        assert!(head.ends_with("\r\n\r\n"), "{head}");
        assert_eq!(CHUNK_END, b"0\r\n\r\n");
    }
}
