//! Minimal HTTP/1.1 framing for the planner service (hyper unavailable
//! offline; see DESIGN.md substitutions).
//!
//! Covers exactly what the service needs: request-line + header parsing
//! with size caps, `Content-Length` bodies, fixed-length responses, and
//! a chunked-transfer writer for the streamed `POST /sweep` endpoint.
//! Every response carries `Connection: close` — the service is
//! one-request-per-connection by design (the expensive path is the
//! planner evaluation, not the TCP handshake, and closing keeps the
//! worker pool's accounting trivial).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

/// Parsed request line + headers + body.
#[derive(Clone, Debug)]
pub struct Request {
    /// Uppercase method ("GET", "POST", …).
    pub method: String,
    /// Path with any `?query` suffix stripped (the service's endpoints
    /// take no query parameters).
    pub path: String,
    /// Lowercased header names, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a (lowercase) header name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Cap on the request line + headers (pre-body) section.
const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on the request body (a `SweepSpec` is well under this).
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// Read one request off the stream.  Fails loudly on malformed framing,
/// oversized heads/bodies, or EOF mid-request; the caller maps parse
/// failures to a 400 where a response is still possible.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut head = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        head.push_str(&line);
        if head.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        if line == "\r\n" || line == "\n" {
            break;
        }
    }
    let mut lines = head.lines();
    let request_line = lines
        .next()
        .ok_or_else(|| anyhow!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow!("missing method"))?
        .to_ascii_uppercase();
    let raw_path = parts
        .next()
        .ok_or_else(|| anyhow!("missing request path"))?;
    let version = parts
        .next()
        .ok_or_else(|| anyhow!("missing HTTP version"))?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol '{version}'");
    }
    let path = raw_path
        .split_once('?')
        .map(|(p, _)| p)
        .unwrap_or(raw_path)
        .to_string();
    let mut headers = Vec::new();
    for l in lines {
        if l.is_empty() {
            break;
        }
        let (k, v) = l
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line '{l}'"))?;
        headers.push((k.trim().to_ascii_lowercase(),
                      v.trim().to_string()));
    }
    let content_length = match headers
        .iter()
        .find(|(k, _)| k == "content-length")
    {
        None => 0usize,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|e| anyhow!("bad content-length '{v}': {e}"))?,
    };
    if content_length > MAX_BODY_BYTES {
        bail!("request body of {content_length} bytes exceeds the \
               {MAX_BODY_BYTES}-byte cap");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (`Content-Length` framing,
/// `Connection: close`).
pub fn write_response(stream: &mut TcpStream, status: u16,
                      content_type: &str, body: &[u8]) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n",
        reason(status),
        body.len());
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Chunked-transfer response writer for the streamed `POST /sweep`
/// endpoint: the head commits the status before the sweep runs, then
/// each completed scenario goes out as its own chunk.  Concatenating
/// the chunks reproduces the `sweep` CLI's JSON document byte-for-byte.
pub struct ChunkedWriter<'a> {
    stream: &'a mut TcpStream,
}

impl<'a> ChunkedWriter<'a> {
    /// Write the response head and return the chunk writer.
    pub fn start(stream: &'a mut TcpStream, status: u16,
                 content_type: &str) -> Result<Self> {
        let head = format!(
            "HTTP/1.1 {status} {}\r\n\
             Content-Type: {content_type}\r\n\
             Transfer-Encoding: chunked\r\n\
             Connection: close\r\n\
             \r\n",
            reason(status));
        stream.write_all(head.as_bytes())?;
        Ok(ChunkedWriter { stream })
    }

    /// Write one chunk (empty input writes nothing — a zero-length
    /// chunk would terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.stream, "{:x}\r\n", data.len())?;
        self.stream.write_all(data)?;
        self.stream.write_all(b"\r\n")?;
        self.stream.flush()?;
        Ok(())
    }

    /// Terminate the chunk stream.  Dropping the writer *without*
    /// calling this leaves the client with a truncated chunk stream —
    /// exactly right when a sweep fails mid-flight, since the committed
    /// 200 head cannot be taken back.
    pub fn finish(self) -> Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Round-trip helper: write `raw` into a socket, parse it off the
    /// other end.
    fn parse(raw: &[u8]) -> Result<Request> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = read_request(&mut conn);
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            b"POST /plan?x=1 HTTP/1.1\r\n\
              Host: localhost\r\n\
              Content-Type: application/json\r\n\
              Content-Length: 16\r\n\
              \r\n\
              {\"model\":\"gnmt\"}").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/plan", "query string must be stripped");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"{\"model\":\"gnmt\"}");
    }

    #[test]
    fn parses_bodyless_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse(b"\r\n\r\n").is_err());
        assert!(parse(b"GET /x\r\n\r\n").is_err(), "missing version");
        assert!(parse(b"GET /x SMTP/1.0\r\n\r\n").is_err());
        assert!(parse(b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: oops\r\n\r\n")
                    .is_err());
        // Declared body longer than what arrives.
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nhi")
                    .is_err());
    }

    #[test]
    fn rejects_oversized_bodies() {
        let head = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                           MAX_BODY_BYTES + 1);
        assert!(parse(head.as_bytes()).is_err());
    }
}
