//! Dense two-phase primal simplex for LP relaxations.
//!
//! Solves `min c^T x  s.t.  A x {<=,>=,=} b,  lo <= x <= hi` by conversion
//! to standard form (slack/surplus/artificial columns, lower-bound shift,
//! upper bounds as rows).  Bland's anti-cycling rule kicks in after a
//! degenerate-pivot streak.  Problem sizes here are DLPlacer-scale
//! (hundreds of rows/columns), where a dense tableau is both simple and
//! fast.

use anyhow::{bail, Result};

use super::{Cmp, Problem};

/// LP outcome.
#[derive(Clone, Debug)]
pub enum LpOutcome {
    /// Optimal with objective value and a value per original variable.
    Optimal { obj: f64, x: Vec<f64> },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

struct Tableau {
    /// rows x cols coefficient matrix (last col = rhs).
    a: Vec<Vec<f64>>,
    basis: Vec<usize>,
    rows: usize,
    cols: usize, // number of structural columns (excl rhs)
}

impl Tableau {
    fn rhs(&self, r: usize) -> f64 {
        self.a[r][self.cols]
    }

    fn pivot(&mut self, pr: usize, pc: usize) {
        let piv = self.a[pr][pc];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for j in 0..=self.cols {
            self.a[pr][j] *= inv;
        }
        for r in 0..self.rows {
            if r != pr {
                let f = self.a[r][pc];
                if f.abs() > EPS {
                    for j in 0..=self.cols {
                        self.a[r][j] -= f * self.a[pr][j];
                    }
                }
            }
        }
        self.basis[pr] = pc;
    }

    /// Reduced costs under current basis for cost vector `c`.
    fn reduced_costs(&self, c: &[f64]) -> Vec<f64> {
        // y = c_B B^-1 applied implicitly: since tableau rows are already
        // B^-1 A, reduced cost_j = c_j - sum_r c_basis[r] * a[r][j].
        let mut rc = c.to_vec();
        for r in 0..self.rows {
            let cb = c[self.basis[r]];
            if cb != 0.0 {
                for j in 0..self.cols {
                    rc[j] -= cb * self.a[r][j];
                }
            }
        }
        rc
    }

    fn objective(&self, c: &[f64]) -> f64 {
        (0..self.rows).map(|r| c[self.basis[r]] * self.rhs(r)).sum()
    }

    /// Run simplex iterations on cost vector c. Returns false if unbounded.
    fn optimize(&mut self, c: &[f64], max_iters: usize) -> Result<bool> {
        let mut degenerate_streak = 0usize;
        for _ in 0..max_iters {
            let rc = self.reduced_costs(c);
            // Entering column: most negative reduced cost (Dantzig), or
            // Bland (lowest index with rc<0) when cycling is suspected.
            let bland = degenerate_streak > 20;
            let mut pc = usize::MAX;
            let mut best = -1e-7;
            for j in 0..self.cols {
                if rc[j] < best {
                    if bland {
                        pc = j;
                        break;
                    }
                    best = rc[j];
                    pc = j;
                }
            }
            if pc == usize::MAX {
                return Ok(true); // optimal
            }
            // Ratio test.
            let mut pr = usize::MAX;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.rows {
                let a = self.a[r][pc];
                if a > EPS {
                    let ratio = self.rhs(r) / a;
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && pr != usize::MAX
                            && self.basis[r] < self.basis[pr])
                    {
                        best_ratio = ratio;
                        pr = r;
                    }
                }
            }
            if pr == usize::MAX {
                return Ok(false); // unbounded
            }
            if best_ratio < EPS {
                degenerate_streak += 1;
            } else {
                degenerate_streak = 0;
            }
            self.pivot(pr, pc);
        }
        bail!("simplex iteration limit reached");
    }
}

/// Solve the LP relaxation of `p` (integrality ignored).
pub fn solve_lp(p: &Problem) -> Result<LpOutcome> {
    let n = p.vars.len();
    // --- normalise: shift lower bounds to zero; collect rows -------------
    // x = lo + x', x' in [0, hi-lo].
    let lo: Vec<f64> = p.vars.iter().map(|v| v.lo).collect();
    for (i, v) in p.vars.iter().enumerate() {
        if !v.lo.is_finite() {
            bail!("var {} has -inf lower bound (unsupported)", i);
        }
        if v.hi < v.lo - EPS {
            return Ok(LpOutcome::Infeasible);
        }
    }

    struct Row {
        coeffs: Vec<f64>,
        cmp: Cmp,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for c in &p.constraints {
        let mut coeffs = vec![0.0; n];
        let mut shift = 0.0;
        for &(j, a) in &c.coeffs {
            coeffs[j] += a;
            shift += a * lo[j];
        }
        rows.push(Row { coeffs, cmp: c.cmp, rhs: c.rhs - shift });
    }
    // Upper bounds as rows.
    for (j, v) in p.vars.iter().enumerate() {
        if v.hi.is_finite() {
            let ub = v.hi - v.lo;
            let mut coeffs = vec![0.0; n];
            coeffs[j] = 1.0;
            rows.push(Row { coeffs, cmp: Cmp::Le, rhs: ub });
        }
    }

    let m = rows.len();
    // Column layout: [x' (n)] [slack/surplus (m, 0 where Eq)] [artificial].
    // Make rhs nonnegative by row negation (flips cmp).
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for a in r.coeffs.iter_mut() {
                *a = -*a;
            }
            r.rhs = -r.rhs;
            r.cmp = match r.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }

    // Count slack columns after the flips settle the row senses.
    let n_slack = rows.iter().filter(|r| r.cmp != Cmp::Eq).count();
    // Artificials needed for Ge and Eq rows.
    let n_art = rows.iter().filter(|r| r.cmp != Cmp::Le).count();
    let cols = n + n_slack + n_art;

    let mut a = vec![vec![0.0; cols + 1]; m];
    let mut basis = vec![usize::MAX; m];
    let mut slack_idx = n;
    let mut art_idx = n + n_slack;
    for (ri, r) in rows.iter().enumerate() {
        a[ri][..n].copy_from_slice(&r.coeffs);
        a[ri][cols] = r.rhs;
        match r.cmp {
            Cmp::Le => {
                a[ri][slack_idx] = 1.0;
                basis[ri] = slack_idx;
                slack_idx += 1;
            }
            Cmp::Ge => {
                a[ri][slack_idx] = -1.0;
                slack_idx += 1;
                a[ri][art_idx] = 1.0;
                basis[ri] = art_idx;
                art_idx += 1;
            }
            Cmp::Eq => {
                a[ri][art_idx] = 1.0;
                basis[ri] = art_idx;
                art_idx += 1;
            }
        }
    }

    let mut t = Tableau { a, basis, rows: m, cols };

    let max_iters = 2000 * (m + cols).max(100);

    // --- phase 1 ----------------------------------------------------------
    if n_art > 0 {
        let mut c1 = vec![0.0; cols];
        for j in (n + n_slack)..cols {
            c1[j] = 1.0;
        }
        if !t.optimize(&c1, max_iters)? {
            bail!("phase-1 unbounded (impossible)");
        }
        if t.objective(&c1) > 1e-6 {
            return Ok(LpOutcome::Infeasible);
        }
        // Drive any artificial still in the basis out (degenerate).
        for r in 0..t.rows {
            if t.basis[r] >= n + n_slack {
                // Find a non-artificial column with nonzero coeff.
                let mut done = false;
                for j in 0..(n + n_slack) {
                    if t.a[r][j].abs() > 1e-7 {
                        t.pivot(r, j);
                        done = true;
                        break;
                    }
                }
                if !done {
                    // Row is redundant; zero it (keep artificial at 0).
                }
            }
        }
    }

    // --- phase 2 ----------------------------------------------------------
    let sign = if p.maximize { -1.0 } else { 1.0 };
    let mut c2 = vec![0.0; cols];
    for (j, v) in p.vars.iter().enumerate() {
        c2[j] = sign * v.obj;
    }
    // Forbid artificials from re-entering.
    for j in (n + n_slack)..cols {
        c2[j] = 1e12;
    }
    if !t.optimize(&c2, max_iters)? {
        return Ok(LpOutcome::Unbounded);
    }

    let mut x = lo.clone();
    for r in 0..t.rows {
        if t.basis[r] < n {
            x[t.basis[r]] = lo[t.basis[r]] + t.rhs(r);
        }
    }
    let obj: f64 = p
        .vars
        .iter()
        .enumerate()
        .map(|(j, v)| v.obj * x[j])
        .sum();
    Ok(LpOutcome::Optimal { obj, x })
}

#[cfg(test)]
mod tests {
    use super::super::{Problem, Cmp};
    use super::*;

    fn assert_opt(out: &LpOutcome, want_obj: f64) -> Vec<f64> {
        match out {
            LpOutcome::Optimal { obj, x } => {
                assert!((obj - want_obj).abs() < 1e-6,
                        "obj {obj} want {want_obj}");
                x.clone()
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_max() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18  => (2, 6), obj 36.
        let mut p = Problem::maximize();
        let x = p.add_var("x", 0.0, f64::INFINITY, 3.0);
        let y = p.add_var("y", 0.0, f64::INFINITY, 5.0);
        p.add_le(&[(x, 1.0)], 4.0);
        p.add_le(&[(y, 2.0)], 12.0);
        p.add_le(&[(x, 3.0), (y, 2.0)], 18.0);
        let sol = assert_opt(&solve_lp(&p).unwrap(), 36.0);
        assert!((sol[x] - 2.0).abs() < 1e-6);
        assert!((sol[y] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn min_with_ge() {
        // min 2x + 3y s.t. x + y >= 10, x >= 2 => (8,2)? obj: prefer x
        // (cheaper): x=10-y; 2(10-y)+3y = 20+y -> y=0, x=10. obj 20.
        let mut p = Problem::minimize();
        let x = p.add_var("x", 0.0, f64::INFINITY, 2.0);
        let y = p.add_var("y", 0.0, f64::INFINITY, 3.0);
        p.add_ge(&[(x, 1.0), (y, 1.0)], 10.0);
        let sol = assert_opt(&solve_lp(&p).unwrap(), 20.0);
        assert!((sol[x] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn equality_constraint() {
        // min x + y s.t. x + 2y = 4, x,y>=0 => y=2, x=0, obj 2.
        let mut p = Problem::minimize();
        let x = p.add_var("x", 0.0, f64::INFINITY, 1.0);
        let y = p.add_var("y", 0.0, f64::INFINITY, 1.0);
        p.add_eq(&[(x, 1.0), (y, 2.0)], 4.0);
        assert_opt(&solve_lp(&p).unwrap(), 2.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = Problem::minimize();
        let x = p.add_var("x", 0.0, 1.0, 1.0);
        p.add_ge(&[(x, 1.0)], 5.0);
        assert!(matches!(solve_lp(&p).unwrap(), LpOutcome::Infeasible));
    }

    #[test]
    fn unbounded_detected() {
        let mut p = Problem::maximize();
        let x = p.add_var("x", 0.0, f64::INFINITY, 1.0);
        p.add_ge(&[(x, 1.0)], 1.0);
        assert!(matches!(solve_lp(&p).unwrap(), LpOutcome::Unbounded));
    }

    #[test]
    fn bounds_respected() {
        // max x + y, x in [1,3], y in [2,2.5] => 5.5.
        let mut p = Problem::maximize();
        let x = p.add_var("x", 1.0, 3.0, 1.0);
        let y = p.add_var("y", 2.0, 2.5, 1.0);
        let sol = assert_opt(&solve_lp(&p).unwrap(), 5.5);
        assert!((sol[x] - 3.0).abs() < 1e-6);
        assert!((sol[y] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn negative_rhs_normalised() {
        // min x s.t. -x <= -3  (i.e. x >= 3).
        let mut p = Problem::minimize();
        let x = p.add_var("x", 0.0, f64::INFINITY, 1.0);
        p.add_le(&[(x, -1.0)], -3.0);
        assert_opt(&solve_lp(&p).unwrap(), 3.0);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Classic degenerate polytope; must not cycle.
        let mut p = Problem::maximize();
        let x1 = p.add_var("x1", 0.0, f64::INFINITY, 10.0);
        let x2 = p.add_var("x2", 0.0, f64::INFINITY, -57.0);
        let x3 = p.add_var("x3", 0.0, f64::INFINITY, -9.0);
        let x4 = p.add_var("x4", 0.0, f64::INFINITY, -24.0);
        p.add_le(&[(x1, 0.5), (x2, -5.5), (x3, -2.5), (x4, 9.0)], 0.0);
        p.add_le(&[(x1, 0.5), (x2, -1.5), (x3, -0.5), (x4, 1.0)], 0.0);
        p.add_le(&[(x1, 1.0)], 1.0);
        let out = solve_lp(&p).unwrap();
        assert_opt(&out, 1.0);
    }

    #[test]
    fn shifted_lower_bounds_in_constraints() {
        // min x + y, x>=5, y>=5, x + y >= 12 => 12.
        let mut p = Problem::minimize();
        let x = p.add_var("x", 5.0, f64::INFINITY, 1.0);
        let y = p.add_var("y", 5.0, f64::INFINITY, 1.0);
        p.add_ge(&[(x, 1.0), (y, 1.0)], 12.0);
        assert_opt(&solve_lp(&p).unwrap(), 12.0);
    }
}
