//! Mixed-integer linear programming: problem model + branch & bound.
//!
//! DLPlacer (paper §6) needs an exact ILP solver; none is available
//! offline, so this module implements one from scratch: LP relaxations via
//! the dense two-phase simplex in [`simplex`], integrality via best-first
//! branch & bound with most-fractional branching and incumbent pruning.
//! Scale target is DLPlacer-sized models (≲ a few hundred binaries).

pub mod simplex;

use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use anyhow::Result;

pub use simplex::{solve_lp, LpOutcome};

/// Constraint comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// Decision variable.
#[derive(Clone, Debug)]
pub struct Var {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub obj: f64,
    pub integer: bool,
}

/// Linear constraint `sum coeffs {<=,>=,=} rhs`.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A MILP/LP problem.
#[derive(Clone, Debug)]
pub struct Problem {
    pub vars: Vec<Var>,
    pub constraints: Vec<Constraint>,
    pub maximize: bool,
}

impl Problem {
    pub fn minimize() -> Self {
        Problem { vars: Vec::new(), constraints: Vec::new(), maximize: false }
    }

    pub fn maximize() -> Self {
        Problem { vars: Vec::new(), constraints: Vec::new(), maximize: true }
    }

    /// Continuous variable; returns its index.
    pub fn add_var(&mut self, name: &str, lo: f64, hi: f64, obj: f64)
                   -> usize {
        self.vars.push(Var {
            name: name.to_string(),
            lo,
            hi,
            obj,
            integer: false,
        });
        self.vars.len() - 1
    }

    /// Binary 0/1 variable.
    pub fn add_binary(&mut self, name: &str, obj: f64) -> usize {
        self.vars.push(Var {
            name: name.to_string(),
            lo: 0.0,
            hi: 1.0,
            obj,
            integer: true,
        });
        self.vars.len() - 1
    }

    /// General integer variable.
    pub fn add_integer(&mut self, name: &str, lo: f64, hi: f64, obj: f64)
                       -> usize {
        self.vars.push(Var {
            name: name.to_string(),
            lo,
            hi,
            obj,
            integer: true,
        });
        self.vars.len() - 1
    }

    pub fn add_le(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        self.constraints.push(Constraint {
            coeffs: coeffs.to_vec(),
            cmp: Cmp::Le,
            rhs,
        });
    }

    pub fn add_ge(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        self.constraints.push(Constraint {
            coeffs: coeffs.to_vec(),
            cmp: Cmp::Ge,
            rhs,
        });
    }

    pub fn add_eq(&mut self, coeffs: &[(usize, f64)], rhs: f64) {
        self.constraints.push(Constraint {
            coeffs: coeffs.to_vec(),
            cmp: Cmp::Eq,
            rhs,
        });
    }

    /// Check a candidate point against all constraints and bounds.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        for (j, v) in self.vars.iter().enumerate() {
            if x[j] < v.lo - tol || x[j] > v.hi + tol {
                return false;
            }
            if v.integer && (x[j] - x[j].round()).abs() > tol {
                return false;
            }
        }
        for c in &self.constraints {
            let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
            let ok = match c.cmp {
                Cmp::Le => lhs <= c.rhs + tol,
                Cmp::Ge => lhs >= c.rhs - tol,
                Cmp::Eq => (lhs - c.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

/// Branch & bound configuration.
#[derive(Clone, Copy, Debug)]
pub struct BnbConfig {
    pub max_nodes: usize,
    pub time_limit: Duration,
    /// Relative optimality gap at which to stop (0 = prove optimality).
    pub gap: f64,
    pub int_tol: f64,
}

impl Default for BnbConfig {
    fn default() -> Self {
        BnbConfig {
            max_nodes: 200_000,
            time_limit: Duration::from_secs(120),
            gap: 1e-6,
            int_tol: 1e-6,
        }
    }
}

/// MILP outcome.
#[derive(Clone, Debug)]
pub enum MilpOutcome {
    Optimal { obj: f64, x: Vec<f64> },
    /// Feasible incumbent found but optimality not proven in budget.
    Feasible { obj: f64, x: Vec<f64>, bound: f64 },
    Infeasible,
    Unbounded,
    /// Budget exhausted without any incumbent.
    Unknown,
}

impl MilpOutcome {
    pub fn solution(&self) -> Option<(f64, &[f64])> {
        match self {
            MilpOutcome::Optimal { obj, x }
            | MilpOutcome::Feasible { obj, x, .. } => Some((*obj, x)),
            _ => None,
        }
    }
}

struct Node {
    bound: f64,
    overrides: Vec<(usize, f64, f64)>, // (var, lo, hi)
    sign: f64,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Best-first: for minimisation pop the smallest bound.
        (other.bound * self.sign)
            .partial_cmp(&(self.bound * self.sign))
            .unwrap_or(std::cmp::Ordering::Equal)
    }
}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Solve a MILP by best-first branch & bound.
///
/// Optionally seed with a known-feasible `incumbent` (e.g. from a heuristic)
/// to tighten pruning from the start — DLPlacer warm-starts with its
/// list-scheduling solution.
pub fn solve_milp(p: &Problem, cfg: BnbConfig,
                  incumbent: Option<(f64, Vec<f64>)>) -> Result<MilpOutcome> {
    let start = Instant::now();
    let sign = if p.maximize { -1.0 } else { 1.0 };
    // Incumbent tracked in minimisation sense.
    let mut best: Option<(f64, Vec<f64>)> = match incumbent {
        Some((o, x)) => {
            if p.is_feasible(&x, 1e-5) {
                Some((o * sign, x))
            } else {
                if std::env::var("HYBRIDPAR_MILP_DEBUG").is_ok() {
                    eprintln!("milp: warm-start incumbent rejected as \
infeasible (obj {o})");
                    for (j, v) in p.vars.iter().enumerate() {
                        if x[j] < v.lo - 1e-5 || x[j] > v.hi + 1e-5 {
                            eprintln!("  var {} = {} outside [{}, {}]",
                                      v.name, x[j], v.lo, v.hi);
                        }
                    }
                    for c in &p.constraints {
                        let lhs: f64 = c.coeffs.iter()
                            .map(|&(j, a)| a * x[j]).sum();
                        let ok = match c.cmp {
                            Cmp::Le => lhs <= c.rhs + 1e-5,
                            Cmp::Ge => lhs >= c.rhs - 1e-5,
                            Cmp::Eq => (lhs - c.rhs).abs() <= 1e-5,
                        };
                        if !ok {
                            eprintln!("  violated {:?} lhs={} rhs={} \
coeffs={:?}", c.cmp, lhs, c.rhs,
                                c.coeffs.iter().map(|&(j, a)|
                                    (p.vars[j].name.clone(), a, x[j]))
                                    .collect::<Vec<_>>());
                        }
                    }
                }
                None
            }
        }
        None => None,
    };

    let root = match solve_lp(p)? {
        LpOutcome::Optimal { obj, x } => (obj * sign, x),
        LpOutcome::Infeasible => return Ok(MilpOutcome::Infeasible),
        LpOutcome::Unbounded => return Ok(MilpOutcome::Unbounded),
    };

    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: root.0, overrides: Vec::new(), sign });
    let mut nodes = 0usize;
    #[allow(unused_assignments)]
    let mut best_bound = root.0;

    while let Some(node) = heap.pop() {
        nodes += 1;
        best_bound = node.bound;
        if nodes > cfg.max_nodes || start.elapsed() > cfg.time_limit {
            return Ok(match best {
                Some((obj, x)) => MilpOutcome::Feasible {
                    obj: obj * sign,
                    x,
                    bound: best_bound * sign,
                },
                None => MilpOutcome::Unknown,
            });
        }
        if let Some((inc, _)) = &best {
            // Prune: bound can't beat incumbent (within gap).
            if node.bound >= inc - cfg.gap * inc.abs().max(1.0) {
                continue;
            }
        }
        // Re-solve LP with this node's bound overrides.
        let mut sub = p.clone();
        for &(j, lo, hi) in &node.overrides {
            sub.vars[j].lo = sub.vars[j].lo.max(lo);
            sub.vars[j].hi = sub.vars[j].hi.min(hi);
        }
        let (obj_min, x) = match solve_lp(&sub)? {
            LpOutcome::Optimal { obj, x } => (obj * sign, x),
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => return Ok(MilpOutcome::Unbounded),
        };
        if let Some((inc, _)) = &best {
            if obj_min >= inc - cfg.gap * inc.abs().max(1.0) {
                continue;
            }
        }
        // Most-fractional integer variable.
        let mut branch_var = usize::MAX;
        let mut best_frac = cfg.int_tol;
        for (j, v) in p.vars.iter().enumerate() {
            if v.integer {
                let f = (x[j] - x[j].round()).abs();
                if f > best_frac {
                    best_frac = f;
                    branch_var = j;
                }
            }
        }
        if branch_var == usize::MAX {
            // Integral: candidate incumbent.
            let rounded: Vec<f64> = p
                .vars
                .iter()
                .enumerate()
                .map(|(j, v)| if v.integer { x[j].round() } else { x[j] })
                .collect();
            if best.as_ref().map_or(true, |(inc, _)| obj_min < *inc) {
                best = Some((obj_min, rounded));
            }
            continue;
        }
        let xv = x[branch_var];
        let mut lo_overrides = node.overrides.clone();
        lo_overrides.push((branch_var, f64::NEG_INFINITY, xv.floor()));
        let mut hi_overrides = node.overrides;
        hi_overrides.push((branch_var, xv.ceil(), f64::INFINITY));
        heap.push(Node { bound: obj_min, overrides: lo_overrides, sign });
        heap.push(Node { bound: obj_min, overrides: hi_overrides, sign });
    }

    Ok(match best {
        Some((obj, x)) => MilpOutcome::Optimal { obj: obj * sign, x },
        None => MilpOutcome::Infeasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal(out: MilpOutcome) -> (f64, Vec<f64>) {
        match out {
            MilpOutcome::Optimal { obj, x } => (obj, x),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binaries.
        // best: a + c = 17 w 5 <= 6? a(3)+c(2)=5 ok obj 17;
        // b + c = 20 w 6 ok obj 20 <- optimal.
        let mut p = Problem::maximize();
        let a = p.add_binary("a", 10.0);
        let b = p.add_binary("b", 13.0);
        let c = p.add_binary("c", 7.0);
        p.add_le(&[(a, 3.0), (b, 4.0), (c, 2.0)], 6.0);
        let (obj, x) = optimal(solve_milp(&p, BnbConfig::default(),
                                          None).unwrap());
        assert!((obj - 20.0).abs() < 1e-6);
        assert_eq!(x[a].round() as i64, 0);
        assert_eq!(x[b].round() as i64, 1);
        assert_eq!(x[c].round() as i64, 1);
    }

    #[test]
    fn integer_rounding_matters() {
        // max x, 2x <= 7, x integer => 3 (LP gives 3.5).
        let mut p = Problem::maximize();
        let x = p.add_integer("x", 0.0, 100.0, 1.0);
        p.add_le(&[(x, 2.0)], 7.0);
        let (obj, _) = optimal(solve_milp(&p, BnbConfig::default(),
                                          None).unwrap());
        assert!((obj - 3.0).abs() < 1e-6);
    }

    #[test]
    fn assignment_problem() {
        // 3 tasks x 3 machines, minimise cost; classic assignment.
        let cost = [[4.0, 2.0, 8.0], [4.0, 3.0, 7.0], [3.0, 1.0, 6.0]];
        let mut p = Problem::minimize();
        let mut v = [[0usize; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                v[i][j] = p.add_binary(&format!("x{i}{j}"), cost[i][j]);
            }
        }
        for i in 0..3 {
            let row: Vec<(usize, f64)> =
                (0..3).map(|j| (v[i][j], 1.0)).collect();
            p.add_eq(&row, 1.0);
            let col: Vec<(usize, f64)> =
                (0..3).map(|j| (v[j][i], 1.0)).collect();
            p.add_eq(&col, 1.0);
        }
        let (obj, x) = optimal(solve_milp(&p, BnbConfig::default(),
                                          None).unwrap());
        // optimal: t0->m1(2)? then t2->m0(3), t1->m2(7) = 12;
        // alt: t0->m0(4), t2->m1(1), t1->m2(7) = 12.
        assert!((obj - 12.0).abs() < 1e-6, "obj={obj}");
        assert!(p.is_feasible(&x, 1e-6));
    }

    #[test]
    fn infeasible_milp() {
        let mut p = Problem::minimize();
        let a = p.add_binary("a", 1.0);
        let b = p.add_binary("b", 1.0);
        p.add_ge(&[(a, 1.0), (b, 1.0)], 3.0);
        assert!(matches!(solve_milp(&p, BnbConfig::default(), None).unwrap(),
                         MilpOutcome::Infeasible));
    }

    #[test]
    fn incumbent_respected() {
        let mut p = Problem::maximize();
        let a = p.add_binary("a", 5.0);
        p.add_le(&[(a, 1.0)], 1.0);
        // Wrong incumbent (infeasible point) must be ignored.
        let out = solve_milp(&p, BnbConfig::default(),
                             Some((99.0, vec![3.0]))).unwrap();
        let (obj, _) = optimal(out);
        assert!((obj - 5.0).abs() < 1e-6);
    }

    #[test]
    fn mixed_integer_continuous() {
        // max 3x + 2y, x integer, y continuous <= 1.7,
        // x + y <= 3.2 => x=3, y=0.2 -> 9.4 (LP relaxation x=3.2 -> 9.6).
        let mut p = Problem::maximize();
        let x = p.add_integer("x", 0.0, 10.0, 3.0);
        let y = p.add_var("y", 0.0, 1.7, 2.0);
        p.add_le(&[(x, 1.0), (y, 1.0)], 3.2);
        let (obj, sol) = optimal(solve_milp(&p, BnbConfig::default(),
                                            None).unwrap());
        assert!((sol[x] - 3.0).abs() < 1e-6);
        assert!((sol[y] - 0.2).abs() < 1e-6);
        assert!((obj - 9.4).abs() < 1e-6);
    }

    #[test]
    fn budget_returns_feasible_or_unknown() {
        // Tiny node budget on a problem needing branching.
        let mut p = Problem::maximize();
        let vars: Vec<usize> =
            (0..12).map(|i| p.add_binary(&format!("v{i}"), (i % 5) as f64 + 1.0)).collect();
        let coeffs: Vec<(usize, f64)> =
            vars.iter().enumerate().map(|(i, &v)| (v, (i % 3) as f64 + 1.0)).collect();
        p.add_le(&coeffs, 7.0);
        let cfg = BnbConfig { max_nodes: 2, ..Default::default() };
        match solve_milp(&p, cfg, None).unwrap() {
            MilpOutcome::Optimal { .. }
            | MilpOutcome::Feasible { .. }
            | MilpOutcome::Unknown => {}
            other => panic!("unexpected {other:?}"),
        }
    }
}
