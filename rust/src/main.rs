//! `hybridpar` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   plan       query the unified planner for the best strategy
//!   sweep      evaluate a scenario grid in parallel (JSON/CSV out)
//!   serve      run the planner as a cached HTTP daemon
//!   train      train the transformer LM under a parallelization strategy
//!   place      run DLPlacer on an analytic model DFG
//!   analyze    print the Eq. 1-6 strategy projection for a network
//!   allreduce  micro-benchmark the collective implementations
//!   info       show loaded artifact signatures
//!
//! Run `hybridpar <cmd> --help` semantics are informal: every option has a
//! default, so bare invocations work.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

use hybridpar::cluster;
use hybridpar::collective;
use hybridpar::config::{MemoryConfig, RunConfig, SweepConfig, Toml};
use hybridpar::coordinator::{Coordinator, Strategy};
use hybridpar::data::Corpus;
use hybridpar::memory::{MemoryModel, Optimizer, ZeroMode};
use hybridpar::parallel::{NetworkModel, ScalingEfficiency};
use hybridpar::placer;
use hybridpar::planner::sweep::{self, effective_threads, parse_mem_gb,
                                run_sweep_observed, BatchSpec,
                                StrategyFamily, SweepSpec};
use hybridpar::planner::timeline::plan_timeline;
use hybridpar::planner::{cost_by_name, AnalyticalCost, CostModel,
                         ModelRegistry, Objective, PlanMechanism,
                         PlanRequest, Planner};
use hybridpar::runtime::Meta;
use hybridpar::service::{self, ServiceOptions};
use hybridpar::util::cli::Args;
use hybridpar::util::fmt_secs;

const USAGE: &str = "\
hybridpar — hybrid DP+MP training framework (Pal et al. 2019 reproduction)

USAGE: hybridpar <COMMAND> [OPTIONS]

COMMANDS:
  plan       --model NAME
             --topo dgx1|dgx2|dgx-a100|multinode|dgx1-pod|cloud-25gbe
             --devices N [--nodes K]
             [--collective auto|ring|tree|hierarchical]
             [--batch B] [--objective time-to-converge|step-time]
             [--cost analytical|alpha-beta|simulator] [--mp-degrees 2,4]
             [--mechanism auto|layerwise|tensor] [--tensor-degrees 8,2]
             [--pipeline-only] [--max-curve N]
             [--device-mem-gb G] [--optimizer sgd|momentum|adam]
             [--recompute] [--act-factor F] [--reserved-gb G]
             [--zero off|optimizer|gradients|weights]
             [--overlap-buckets K] [--compression F]
             [--explain] [--trace-out timeline.json]
             [--config cfg.toml] [--out-json path]
             (emits the typed Plan as JSON on stdout; memory-infeasible
              candidates appear in the scorecard as infeasible rows, and
              the collective pricing each exchange is recorded per row;
              --explain prints the per-candidate cost waterfall on stderr
              and embeds it in the Plan JSON; --trace-out writes a Chrome
              trace-event / Perfetto timeline of the chosen plan)
  sweep      --models a,b --topos dgx1,dgx1-pod --devices 8,64,256
             [--nodes 1,2,4] [--collective auto|ring|tree|hierarchical]
             [--device-mem-gb default|G,...]
             [--batches default|paper|N,...]
             [--families dp,hybrid,pipelined,layerwise,tensor]
             [--mp-degrees 2,4] [--threads N] [--objective ...] [--cost ...]
             [--optimizer ...] [--recompute] [--max-curve N]
             [--overlap 1,8,...] [--compression 1.0,0.25,...]
             [--zero off,weights,...]
             [--progress] [--trace-dir DIR]
             [--config cfg.toml] [--out-json p] [--out-csv p]
             (parallel grid evaluation; JSON on stdout, deterministic
              ordering — --threads N output is byte-identical to --threads 1;
              --progress prints a done/total heartbeat to stderr,
              --trace-dir writes one Perfetto timeline per planned scenario)
  serve      [--addr 127.0.0.1:8080] [--threads N] [--cache-entries N]
             [--cost analytical|alpha-beta|simulator] [--config cfg.toml]
             [--max-pending N] [--max-connections N]
             [--head-timeout-ms MS] [--idle-timeout-ms MS]
             [--cache-persist path] [--replicas host:port,...]
             [--access-log path|-]
             (planner-as-a-service HTTP daemon: keep-alive event loop,
              POST /plan and /sweep, GET /models /topologies /healthz
              /metrics /debug/trace; /plan responses are byte-identical
              to the plan subcommand and cached in a single-flight LRU;
              --replicas shards POST /sweep across peer daemons;
              --access-log appends one JSON line per request ("-" =
              stderr) — docs/service.md, docs/observability.md)
  train      --config cfg.toml |
             --strategy single|dp|hybrid|pipelined|async|local-sgd
             --workers N --steps N --lr F --dp-workers N --microbatches N
             [--stages K --replicas N] [--delayed-factor K] [--staleness K]
             [--sync-every K] [--target-loss F] [--out-csv path]
  place      --model inception|gnmt|biglstm|transformer --devices N
             [--heuristic] [--dot out.dot]
  analyze    --model inception|gnmt|biglstm [--max-devices N] [--real-se]
  allreduce  [--mbytes M] [--workers N] [--topology dgx1|multinode]
             (benches ring, tree, hierarchical and parameter-server)
  info       [--artifacts dir]
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    let args = Args::from_env(2, &["heuristic", "real-se", "verbose",
                                   "pipeline-only", "recompute", "explain",
                                   "progress"]);
    match cmd.as_str() {
        "plan" => cmd_plan(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "place" => cmd_place(&args),
        "analyze" => cmd_analyze(&args),
        "allreduce" => cmd_allreduce(&args),
        "info" => cmd_info(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

// --------------------------------------------------------------------------

/// Resolve a collective pin from a CLI/config spelling: "auto" (or
/// empty) means let the cost model pick per exchange.
fn parse_collective(s: &str) -> Result<Option<collective::Algorithm>> {
    match s {
        "" | "auto" => Ok(None),
        other => Ok(Some(collective::Algorithm::parse(other)?)),
    }
}

/// Resolve the footprint-accounting model from the `[memory]` config
/// section plus CLI overrides (`--optimizer`, `--recompute`,
/// `--act-factor`, `--reserved-gb`), shared by `plan` and `sweep`.
fn memory_model_from(args: &Args, base: &MemoryConfig)
                     -> Result<MemoryModel> {
    let act_factor = args.get_f64("act-factor", base.act_factor)?;
    if !act_factor.is_finite() || act_factor <= 0.0 {
        bail!("--act-factor must be a positive finite number, got \
               {act_factor}");
    }
    let reserved_gb = args.get_f64("reserved-gb", base.reserved_gb)?;
    if !reserved_gb.is_finite() || reserved_gb < 0.0 {
        bail!("--reserved-gb must be a non-negative finite number, got \
               {reserved_gb}");
    }
    Ok(MemoryModel {
        optimizer: Optimizer::parse(
            &args.get_or("optimizer", &base.optimizer))?,
        recompute: args.has_flag("recompute") || base.recompute,
        act_factor,
        reserved_bytes: reserved_gb * 1e9,
        // `--zero` is handled per-subcommand (plan: a mode; sweep: an
        // axis), so only the `[memory]` section lands here.
        zero: ZeroMode::parse(&base.zero)?,
        ..MemoryModel::default()
    })
}

/// `plan`: one typed query against the unified planner.  Prints the JSON
/// [`hybridpar::planner::Plan`] on stdout (human summary on stderr).
fn cmd_plan(args: &Args) -> Result<()> {
    // Defaults come from the optional `[planner]` / `[memory]` /
    // `[overlap]` config sections.
    let cfg = match args.get("config") {
        Some(path) => {
            RunConfig::from_toml(&Toml::load(&PathBuf::from(path))?)?
        }
        None => RunConfig::default(),
    };
    let base = cfg.planner.unwrap_or_default();
    let mem_base = cfg.memory.unwrap_or_default();
    // --overlap-buckets / --compression: CLI > [overlap] > off.  Range
    // validation happens inside the planner (OverlapModel::validate).
    let ov_base = cfg.overlap.unwrap_or_default();
    let overlap_buckets =
        args.get_usize("overlap-buckets", ov_base.buckets)?;
    let compression = args.get_f64("compression", ov_base.compression)?;
    let model = args.get_or("model", &base.model);
    let topo_default = args.get_or("topology", &base.topology);
    let topo = args.get_or("topo", &topo_default);
    let devices = args.get_usize("devices", base.devices)?;
    let batch = match args.get("batch") {
        Some(b) => Some(b.parse::<usize>()?),
        None => base.batch,
    };
    let objective =
        Objective::parse(&args.get_or("objective", &base.objective))?;
    let cost = cost_by_name(&args.get_or("cost", &base.cost_model))?;
    let mut mem_model = memory_model_from(args, &mem_base)?;
    if let Some(z) = args.get("zero") {
        mem_model.zero = ZeroMode::parse(z)?;
    }
    let device_mem_gb = match args.get("device-mem-gb") {
        Some(s) => parse_mem_gb(s)?,
        None => mem_base.device_mem_gb,
    };
    // --nodes: CLI > [planner] nodes; --collective: CLI > [planner] >
    // [cluster].
    let nodes = match args.get("nodes") {
        Some(s) => Some(s.parse::<usize>()?),
        None => base.nodes,
    };
    let collective_spec = args.get_or(
        "collective",
        base.collective.as_deref().unwrap_or(&cfg.collective));
    let collective = parse_collective(&collective_spec)?;

    let mechanism = PlanMechanism::parse(
        &args.get_or("mechanism", &base.mechanism))?;

    let mut req = PlanRequest::new(&model, &topo)
        .devices(devices)
        .objective(objective)
        .pipeline_only(args.has_flag("pipeline-only"))
        .explain(args.has_flag("explain"))
        .mechanism(mechanism)
        .memory(mem_model)
        .overlap_buckets(overlap_buckets)
        .compression(compression)
        .curve_to(args.get_usize("max-curve", 256)?);
    if let Some(n) = nodes {
        req = req.nodes(n);
    }
    if let Some(a) = collective {
        req = req.collective(a);
    }
    if let Some(gb) = device_mem_gb {
        req = req.device_mem_gb(gb);
    }
    if let Some(b) = batch {
        req = req.batch(b);
    }
    if let Some(ms) = args.get("mp-degrees") {
        let degrees: Vec<usize> = ms
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()?;
        req = req.mp_degrees(&degrees);
    }
    // --tensor-degrees: CLI > [planner] tensor_degrees > off (empty).
    let tensor_degrees: Vec<usize> = match args.get("tensor-degrees") {
        Some(ts) => ts
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()?,
        None => base.tensor_degrees.clone(),
    };
    if !tensor_degrees.is_empty() {
        req = req.tensor_degrees(&tensor_degrees);
    }

    let planner = Planner::with_cost(cost);
    let plan = planner.plan(&req)?;
    eprint!("{}", plan.summary());
    if args.has_flag("explain") {
        eprint!("{}", plan.explain_text());
    }
    if let Some(path) = args.get("trace-out") {
        // The timeline is a pure function of the request (virtual-clock
        // timestamps come from the simulator, never the wall clock), so
        // the same plan always writes byte-identical JSON.
        std::fs::write(path, plan_timeline(&planner, &req, &plan)?)?;
        eprintln!("wrote {path}");
    }
    // One shared writer with the service's POST /plan (and the golden
    // fixtures): stdout, --out-json and the HTTP body are byte-identical.
    let doc = plan.to_json_string();
    print!("{doc}");
    if let Some(path) = args.get("out-json") {
        std::fs::write(path, &doc)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

// --------------------------------------------------------------------------

/// `serve`: run the planner as a long-lived HTTP daemon (see
/// `docs/service.md`).  Defaults come from the optional `[service]`
/// config section; CLI flags override.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => {
            RunConfig::from_toml(&Toml::load(&PathBuf::from(path))?)?
        }
        None => RunConfig::default(),
    };
    let base = cfg.service.unwrap_or_default();
    let addr = args.get_or("addr", &base.addr);
    let persist_path = args
        .get("cache-persist")
        .map(|s| s.to_string())
        .or(base.persist)
        .map(PathBuf::from);
    let replicas: Vec<String> = match args.get("replicas") {
        Some(list) => list
            .split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect(),
        None => base.replicas,
    };
    let opts = ServiceOptions {
        threads: args.get_usize("threads", base.threads)?,
        cache_entries: args.get_usize("cache-entries", base.cache_entries)?,
        default_cost: args.get_or("cost", &base.cost_model),
        max_pending: args.get_usize("max-pending", base.max_pending)?,
        max_connections: args.get_usize("max-connections",
                                        base.max_connections)?,
        head_timeout: Duration::from_millis(args.get_usize(
            "head-timeout-ms", base.head_timeout_ms as usize)? as u64),
        idle_timeout: Duration::from_millis(args.get_usize(
            "idle-timeout-ms", base.idle_timeout_ms as usize)? as u64),
        persist_path,
        replicas,
        access_log: args
            .get("access-log")
            .map(|s| s.to_string())
            .or(base.access_log),
    };
    let bound = service::bind(&addr, opts)?;
    eprintln!("serving planner on http://{} \
               (POST /plan /sweep, GET /models /topologies /healthz \
               /metrics /debug/trace; ctrl-c to stop)",
              bound.local_addr());
    bound.serve_forever()
}

// --------------------------------------------------------------------------

/// `sweep`: evaluate a `(model × topology × devices × batch × family)`
/// grid through the work-sharing parallel sweep engine.  Emits the full
/// [`hybridpar::planner::sweep::SweepResult`] as JSON on stdout (summary
/// on stderr); `--out-json` / `--out-csv` also write files.  Output
/// ordering is canonical, so `--threads N` is byte-identical to
/// `--threads 1` — only faster.
fn cmd_sweep(args: &Args) -> Result<()> {
    // Defaults come from the optional `[sweep]` / `[memory]` /
    // `[overlap]` config sections.
    let cfg = match args.get("config") {
        Some(path) => {
            RunConfig::from_toml(&Toml::load(&PathBuf::from(path))?)?
        }
        None => RunConfig::default(),
    };
    let base: SweepConfig = cfg.sweep.unwrap_or_default();
    let mem_base = cfg.memory.unwrap_or_default();
    let csv_list = |s: &str| -> Vec<String> {
        s.split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect()
    };
    let usize_list = |s: &str| -> Result<Vec<usize>> {
        csv_list(s)
            .iter()
            .map(|x| x.parse::<usize>().map_err(|e| anyhow::anyhow!("{e}")))
            .collect()
    };
    let f64_list = |s: &str| -> Result<Vec<f64>> {
        csv_list(s)
            .iter()
            .map(|x| x.parse::<f64>().map_err(|e| anyhow::anyhow!("{e}")))
            .collect()
    };
    let models = args.get("models").map(csv_list).unwrap_or(base.models);
    let topos = args
        .get("topos")
        .or_else(|| args.get("topologies"))
        .map(csv_list)
        .unwrap_or(base.topologies);
    let devices = match args.get("devices") {
        Some(s) => usize_list(s)?,
        None => base.devices,
    };
    let nodes = match args.get("nodes") {
        Some(s) => usize_list(s)?,
        None => base.nodes,
    };
    let batches = args.get("batches").map(csv_list).unwrap_or(base.batches);
    let families =
        args.get("families").map(csv_list).unwrap_or(base.families);
    let mp_degrees = match args.get("mp-degrees") {
        Some(s) => usize_list(s)?,
        None => base.mp_degrees,
    };
    let mem_axis = args
        .get("device-mem-gb")
        .map(csv_list)
        .unwrap_or(base.device_mem_gb);
    // Overlap axes: CLI > non-default [sweep] axes > the [overlap]
    // section's singleton > off.  Range validation happens in
    // SweepSpec::validate (shared with the wire surface).
    let ov = cfg.overlap.clone().unwrap_or_default();
    let overlap = match args.get("overlap") {
        Some(s) => usize_list(s)?,
        None if base.overlap != vec![1] => base.overlap,
        None => vec![ov.buckets],
    };
    let compression = match args.get("compression") {
        Some(s) => f64_list(s)?,
        None if base.compression != vec![1.0] => base.compression,
        None => vec![ov.compression],
    };
    // ZeRO axis: CLI > [sweep] zero.  "off" entries keep the `[memory]`
    // section's mode (already resolved into spec.memory), so the default
    // singleton composes with a config-level `memory.zero`.
    let zero: Vec<ZeroMode> = match args.get("zero") {
        Some(s) => csv_list(s)
            .iter()
            .map(|x| ZeroMode::parse(x))
            .collect::<Result<_>>()?,
        None => base
            .zero
            .iter()
            .map(|x| ZeroMode::parse(x))
            .collect::<Result<_>>()?,
    };

    // --collective: CLI > [sweep] > [cluster].
    let collective_spec = args.get_or(
        "collective",
        base.collective.as_deref().unwrap_or(&cfg.collective));

    let spec = SweepSpec {
        models,
        topologies: topos,
        devices,
        nodes,
        device_mem_gb: mem_axis
            .iter()
            .map(|s| parse_mem_gb(s))
            .collect::<Result<_>>()?,
        batches: batches
            .iter()
            .map(|s| BatchSpec::parse(s))
            .collect::<Result<_>>()?,
        families: families
            .iter()
            .map(|s| StrategyFamily::parse(s))
            .collect::<Result<_>>()?,
        overlap,
        compression,
        zero,
        mp_degrees,
        objective: Objective::parse(
            &args.get_or("objective", &base.objective))?,
        cost_model: args.get_or("cost", &base.cost_model),
        memory: memory_model_from(args, &mem_base)?,
        collective: parse_collective(&collective_spec)?,
        curve_max_devices: args
            .get_usize("max-curve", base.curve_max_devices)?,
        threads: args.get_usize("threads", base.threads)?,
    };

    let n = spec.scenarios().len();
    let workers = effective_threads(spec.threads, n);
    let t0 = std::time::Instant::now();
    // --progress: heartbeat on stderr every ~5% of the grid (at least
    // every completion on small grids).  stdout is untouched, so the
    // byte-identical --threads contract holds with or without the flag.
    let progress = args.has_flag("progress");
    let stride = (n / 20).max(1);
    let result = run_sweep_observed(&spec, |done, total| {
        if progress && (done % stride == 0 || done == total) {
            eprintln!("sweep progress: {done}/{total} scenarios \
                       ({} elapsed)",
                      fmt_secs(t0.elapsed().as_secs_f64()));
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let ok = result.results.iter().filter(|r| r.plan.is_some()).count();
    eprintln!("sweep: {n} scenarios on {workers} threads in {} \
               ({ok} planned, {} errored)",
              fmt_secs(wall), n - ok);
    for r in &result.results {
        let sc = &r.scenario;
        let mem = hybridpar::planner::sweep::mem_gb_label(sc.device_mem_gb);
        match (&r.plan, &r.error) {
            (Some(p), _) => eprintln!(
                "  {:<14} {:<9} {:>4} dev x{:<2} mem {:<7} batch {:<7} \
                 {:<9} -> M={} {} [{}] ({:.2}x, {} devices used)",
                sc.model, sc.topology, sc.devices, sc.nodes, mem,
                sc.batch.label(), sc.family.as_str(), p.mp_degree,
                p.mechanism, p.collective, p.predicted_speedup,
                p.devices_used),
            (None, err) => eprintln!(
                "  {:<14} {:<9} {:>4} dev x{:<2} mem {:<7} batch {:<7} \
                 {:<9} -> error: {}",
                sc.model, sc.topology, sc.devices, sc.nodes, mem,
                sc.batch.label(), sc.family.as_str(),
                err.as_deref().unwrap_or("unknown")),
        }
    }
    // --trace-dir: serial post-pass rebuilding each planned scenario's
    // request and rendering its Perfetto timeline.  Runs after the sweep
    // (timelines re-simulate pipelines, so they stay off the hot path)
    // and writes one file per scenario in canonical grid order.
    if let Some(dir) = args.get("trace-dir") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        let tracer = Planner::with_cost(cost_by_name(&spec.cost_model)?);
        let mut written = 0usize;
        for (i, r) in result.results.iter().enumerate() {
            let Some(plan) = &r.plan else { continue };
            let sc = &r.scenario;
            let req = sweep::plan_request(&tracer, &spec, sc);
            let name = format!("{i:04}_{}_{}_{}dev_{}.json", sc.model,
                               sc.topology, sc.devices,
                               sc.family.as_str());
            std::fs::write(dir.join(&name),
                           plan_timeline(&tracer, &req, plan)?)?;
            written += 1;
        }
        eprintln!("wrote {written} timelines to {}", dir.display());
    }
    // One shared writer with the service's POST /sweep chunk stream.
    let doc = result.to_json_string();
    print!("{doc}");
    if let Some(path) = args.get("out-json") {
        std::fs::write(path, &doc)?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("out-csv") {
        std::fs::write(path, result.to_csv())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

// --------------------------------------------------------------------------

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_toml(&Toml::load(&PathBuf::from(path))?)?,
        None => RunConfig::default(),
    };
    // CLI overrides.
    if let Some(s) = args.get("strategy") {
        cfg.train.strategy = match s {
            "single" => Strategy::Single,
            "dp" => Strategy::DataParallel {
                workers: args.get_usize("workers", 2)?,
                delayed_factor: args.get_usize("delayed-factor", 1)?,
            },
            "hybrid" => Strategy::Hybrid {
                dp_workers: args.get_usize("dp-workers", 2)?,
                microbatches: args.get_usize("microbatches", 2)?,
            },
            "pipelined" => Strategy::PipelinedHybrid {
                stages: args.get_usize("stages", 2)?,
                microbatches: args.get_usize("microbatches", 2)?,
                replicas: args.get_usize("replicas", 2)?,
            },
            "async" => Strategy::AsyncPs {
                workers: args.get_usize("workers", 2)?,
                staleness: args.get_usize("staleness", 2)?,
            },
            "local-sgd" => Strategy::LocalSgd {
                workers: args.get_usize("workers", 2)?,
                sync_every: args.get_usize("sync-every", 4)?,
            },
            other => bail!("unknown strategy {other}"),
        };
    }
    cfg.train.steps = args.get_usize("steps", cfg.train.steps)?;
    cfg.train.lr = args.get_f64("lr", cfg.train.lr as f64)? as f32;
    if let Some(t) = args.get("target-loss") {
        cfg.train.target_loss = Some(t.parse()?);
    }
    if let Some(p) = args.get("out-csv") {
        cfg.out_csv = Some(p.to_string());
    }
    let artifacts = PathBuf::from(
        args.get_or("artifacts", &cfg.artifacts_dir));

    let hw = cfg.build_cluster()?;
    eprintln!("cluster: {} ({} devices); strategy: {:?}", hw.name,
              hw.n_devices(), cfg.train.strategy);
    let coord = Coordinator::new(&artifacts, hw)?;
    let mut corpus = Corpus::new(cfg.corpus_vocab, cfg.epoch_tokens,
                                 cfg.train.seed);
    // All strategies — §7.3 alternatives included — dispatch uniformly.
    let report = coord.train(&mut corpus, &cfg.train)?;
    println!(
        "steps={} final_loss={:.4} epochs_used={:.3} \
         step_wall={} step_sim={} reached_target={}",
        report.steps_run, report.final_loss, report.epochs_used,
        fmt_secs(report.mean_step_wall_s), fmt_secs(report.mean_step_sim_s),
        report.reached_target
    );
    if let Some(path) = &cfg.out_csv {
        report.curve.write_csv(&PathBuf::from(path))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

// --------------------------------------------------------------------------

fn cmd_place(args: &Args) -> Result<()> {
    let registry = ModelRegistry::builtin();
    let prof = registry.build(&args.get_or("model", "inception"), None)?;
    let nd = args.get_usize("devices", 2)?;
    let hw = cluster::dgx1_mem(nd.max(1).min(8), cluster::V100_32G_MEM);
    let times = prof.dfg.op_times(7e12, 15e-6);
    let serial: f64 = times.iter().sum();
    let placement = if args.has_flag("heuristic") {
        placer::place_heuristic(&prof.dfg, &hw, &times, nd)?
    } else {
        placer::place(&prof.dfg, &hw, &times,
                      &placer::PlacerOptions {
                          max_devices: nd,
                          ..Default::default()
                      })?
    };
    placer::validate_placement(&prof.dfg, &hw, &placement.assignment)?;
    println!("model={} devices={} serial={} predicted={} speedup={:.3} \
              optimal={}",
             prof.name, nd, fmt_secs(serial),
             fmt_secs(placement.predicted_time),
             serial / placement.predicted_time, placement.optimal);
    // Per-device op listing (Fig. 7 textual form).
    for d in hw.devices() {
        let ops: Vec<&str> = placement
            .assignment
            .iter()
            .enumerate()
            .filter(|&(_, &a)| a == d)
            .map(|(i, _)| prof.dfg.ops[i].name.as_str())
            .collect();
        if !ops.is_empty() {
            println!("  device {}: {} ops: {}", d, ops.len(),
                     ops.join(", "));
        }
    }
    if let Some(dot) = args.get("dot") {
        std::fs::write(dot, prof.dfg.to_dot(Some(&placement.assignment)))?;
        eprintln!("wrote {dot}");
    }
    Ok(())
}

// --------------------------------------------------------------------------

fn cmd_analyze(args: &Args) -> Result<()> {
    let name = args.get_or("model", "inception");
    let prof = ModelRegistry::builtin().build(&name, None)?;
    let max_dev = args.get_usize("max-devices", 256)?;
    let cost = AnalyticalCost::default();
    let times = prof.dfg.op_times(cost.flops_per_sec,
                                  cost.launch_overhead_s);
    let step_compute: f64 = times.iter().sum();

    // MP speedup source: DLPlacer for branchy graphs, pipeline for chains
    // — the structural choice lives in the planner's analytical cost model.
    let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
    let su2 = step_compute / cost.mp_step_time(&prof, &hw, 2)?.step_time_s;

    let se = if args.has_flag("real-se") {
        ScalingEfficiency::RingAllReduce {
            step_compute_s: step_compute,
            grad_bytes: prof.grad_bytes,
            alpha: 5e-6,
            beta_bw: 12e9,
        }
    } else {
        ScalingEfficiency::Perfect
    };
    let net = NetworkModel {
        name: prof.name.clone(),
        epochs: prof.epochs.clone(),
        mini_batch: prof.mini_batch,
        se,
        mp_speedups: vec![(2, su2)],
    };
    println!("network={} SU^2={:.3} mini_batch={}", net.name, su2,
             net.mini_batch);
    println!("{:>8} {:>12} {:>14} {:>10}", "devices", "DP-only",
             "hybrid(M=2)", "best");
    let mut n = 1usize;
    while n <= max_dev {
        let dp = net.su_dp(n);
        let hy = net.su_hybrid(n, 2);
        let best = net.best_strategy(n);
        println!(
            "{:>8} {:>12} {:>14} {:>10}",
            n,
            dp.map(|v| format!("{v:.2}")).unwrap_or("diverged".into()),
            hy.map(|v| format!("{v:.2}")).unwrap_or("-".into()),
            best.map(|(m, v)| format!("M={m} ({v:.2})"))
                .unwrap_or("-".into())
        );
        n *= 2;
    }
    if let Some(x) = net.crossover_point(2, max_dev) {
        println!("crossover: hybrid (M=2) overtakes DP-only at {x} devices");
    } else {
        println!("no crossover up to {max_dev} devices");
    }
    Ok(())
}

// --------------------------------------------------------------------------

fn cmd_allreduce(args: &Args) -> Result<()> {
    let mbytes = args.get_f64("mbytes", 16.0)?;
    let workers = args.get_usize("workers", 4)?;
    let topo = args.get_or("topology", "dgx1");
    let hw = match topo.as_str() {
        "dgx1" => cluster::dgx1(workers.min(8)),
        "multinode" => cluster::multi_node(workers.div_ceil(4), 4),
        other => bail!("unknown topology {other}"),
    };
    let devs: Vec<usize> =
        hw.devices().into_iter().cycle().take(workers).collect();
    let len = (mbytes * 1e6 / 4.0) as usize;
    let mut rng = hybridpar::util::rng::Rng::new(1);
    let make = |rng: &mut hybridpar::util::rng::Rng| -> Vec<Vec<f32>> {
        (0..workers)
            .map(|_| (0..len).map(|_| rng.f32()).collect())
            .collect()
    };
    for (name, f) in [
        ("ring", collective::ring_allreduce
            as fn(&mut [Vec<f32>], &cluster::HwGraph, &[usize])
                  -> Result<collective::CollectiveResult>),
        ("tree", collective::tree_allreduce),
        ("hierarchical", collective::hierarchical_allreduce),
        ("param-server", collective::parameter_server),
    ] {
        let mut bufs = make(&mut rng);
        let t0 = std::time::Instant::now();
        // A worker layout can be infeasible for one algorithm (e.g.
        // hierarchical needs equal ranks per node) without invalidating
        // the others — report and move on.
        match f(&mut bufs, &hw, &devs) {
            Ok(r) => println!(
                "{name:>14}: sim_time={} wire={:.1} MB host_wall={}",
                fmt_secs(r.sim_time),
                r.bytes_on_wire / 1e6,
                fmt_secs(t0.elapsed().as_secs_f64())
            ),
            Err(e) => println!("{name:>14}: skipped ({e})"),
        }
    }
    Ok(())
}

// --------------------------------------------------------------------------

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let meta = Meta::load(&dir)?;
    println!("artifacts in {dir:?}:");
    for (name, a) in &meta.artifacts {
        println!("  {:<18} {} in / {} out  ({})", name, a.inputs.len(),
                 a.outputs.len(), a.file);
    }
    let t = &meta.transformer;
    println!("transformer: {} params ({} tensors), batch {}, microbatch {}, \
              seq {}, vocab {}",
             t.n_params_total, t.param_specs.len(), t.batch, t.microbatch,
             t.seq_len, t.vocab);
    if let Some(l) = &meta.lstm {
        println!("lstm: {} params, batch {}, seq {}", l.n_params_total,
                 l.batch, l.seq_len);
    }
    Ok(())
}
