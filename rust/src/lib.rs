//! # hybrid-parallel
//!
//! Production-grade reproduction of *"Optimizing Multi-GPU Parallelization
//! Strategies for Deep Learning Training"* (Pal, Ebrahimi, Zulfiqar, Fu,
//! Zhang, Migacz, Nellans, Gupta — 2019, DOI 10.1109/MM.2019.2935967).
//!
//! The paper's two contributions, plus every substrate they depend on, are
//! implemented here as a three-layer rust + JAX + Pallas stack:
//!
//! 1. **The hybrid DP+MP analytical framework** ([`parallel`]) — decomposes
//!    time-to-converge `C = T × S × E` (paper Eq. 1), quantifies N-way
//!    data-parallel speedup `SU_N = SE_N × N × E1/EN` (Eq. 3), and finds the
//!    crossover (Eq. 6) past which a hybrid strategy (N-way DP of M-way-MP
//!    workers) beats (M·N)-way DP.
//! 2. **DLPlacer** ([`placer`]) — ILP-based operation-to-device placement
//!    (paper Eq. 7–13) over an in-repo MILP solver ([`milp`]), validated
//!    against a discrete-event cluster simulator ([`sim`]) standing in for
//!    the paper's "silicon" runs.
//!
//! The training side is real: the L3 [`coordinator`] drives AOT-compiled
//! JAX/Pallas artifacts through the PJRT C API ([`runtime`]), exchanging
//! gradients with an actual chunked ring all-reduce ([`collective`]) across
//! simulated devices — python never runs on the training path.
//!
//! ## Planner API
//!
//! The decision procedure itself — "given this network and this device
//! budget, which strategy minimises end-to-end training time?" — is exposed
//! as one typed entry point, [`planner`]:
//!
//! ```no_run
//! use hybridpar::planner::{PlanRequest, Planner};
//!
//! let planner = Planner::new(); // built-in registries, Eq. 1–6 costs
//! let plan = planner
//!     .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
//!     .unwrap();
//! println!("run {:?} — {:.2}x projected over 1 GPU",
//!          plan.strategy, plan.predicted_speedup);
//! println!("{}", plan.to_json()); // full scorecard + speedup curve
//! ```
//!
//! * Models and topologies resolve by name through
//!   [`planner::ModelRegistry`] / [`planner::TopologyRegistry`] (the
//!   paper's three networks plus the transformer LM; DGX-1, a 16-GPU
//!   NVSwitch DGX-2, and IB multi-node).
//! * Predictions are pluggable via [`planner::CostModel`]: the analytical
//!   Eq. 1–6 model, the topology-aware α-β collective model (DP gradient
//!   exchange priced as the best feasible ring / tree / hierarchical
//!   all-reduce for the candidate's device set,
//!   [`collective::best_allreduce`]), or the discrete-event simulator —
//!   swap one for another to cross-check a plan.  Every model scores both
//!   MP mechanisms per degree: the Table 1 structural default *and* an
//!   explicit GPipe pipeline, so
//!   [`coordinator::Strategy::PipelinedHybrid`] candidates (the pipelined
//!   ConvNet hybrids of PaSE / the Oracle paper) compete in every search.
//! * Beyond the fixed candidate family, a PaSE-style *layer-wise* search
//!   ([`layerwise`]) composes per-op configurations (replicate /
//!   batch-split / feature-split / stage placement) into a mixed
//!   whole-model strategy by dynamic programming over the DFG, with an
//!   optional MILP cross-check; it appears as `mechanism = "layerwise"`
//!   rows in every scorecard and takes over plan selection under
//!   `PlanRequest::mechanism("layerwise")` / `plan --mechanism layerwise`.
//! * The returned [`planner::Plan`] carries the chosen
//!   [`coordinator::Strategy`], predicted step time, epochs-to-converge,
//!   the end-to-end speedup curve, the placement / pipeline partition, and
//!   a per-candidate scorecard, all JSON-serialisable via [`util::json`].
//! * Every candidate is checked against a per-device footprint model
//!   ([`memory`]): weights + gradients + optimizer state + activations
//!   (GPipe micro-batch stashing included).  Candidates that estimate
//!   but overflow `Mem(n)` are marked
//!   [`memory::Feasibility::Infeasible`] in the scorecard instead of
//!   being scored — the strategy class the paper could not express:
//!   hybrids chosen because DP *cannot fit*, not just because they are
//!   faster.  (A degree whose *estimation* fails outright — deeper than
//!   the topology, or no stage split under the raw Eq. 13 cap — drops
//!   out of the search entirely, as topology-infeasible degrees always
//!   have.)  `PlanRequest::device_mem_gb` overrides the topology's
//!   capacity ("what if these were 16 GB parts?"), and
//!   gradient-checkpointing recompute trades footprint for step time.
//!
//! ## Scenario sweeps
//!
//! Grid evaluation — every `(model × topology × device budget ×
//! global batch × strategy family)` combination — goes through the
//! work-sharing parallel engine in [`planner::sweep`] (CLI: the `sweep`
//! subcommand; see `docs/sweep.md`).  Scheduling is dynamic but output
//! ordering is canonical: `threads = N` produces byte-identical JSON/CSV
//! to `threads = 1`.
//!
//! ```
//! use hybridpar::planner::sweep::{run_sweep, StrategyFamily, SweepSpec};
//!
//! let result = run_sweep(&SweepSpec {
//!     models: vec!["gnmt".into(), "biglstm".into()],
//!     devices: vec![8],
//!     families: vec![StrategyFamily::DpOnly],
//!     curve_max_devices: 8,
//!     threads: 2,
//!     ..Default::default()
//! })
//! .unwrap();
//! assert_eq!(result.len(), 2); // canonical (model-major) order
//! ```
//!
//! ## Planner-as-a-service
//!
//! The `serve` subcommand runs the planner as a long-lived std-only HTTP
//! daemon ([`service`]): `POST /plan` answers are byte-identical to the
//! `plan` CLI and amortise across callers through a single-flight LRU
//! cache (equivalent request spellings share one entry, concurrent
//! identical requests coalesce onto one evaluation), `POST /sweep`
//! streams grid results as they complete, and `GET /metrics` exports
//! Prometheus counters and latency histograms.  See `docs/service.md`.
//!
//! ## Observability
//!
//! Every layer above can *show its work* through [`trace`] — a std-only
//! span recorder with a Chrome trace-event / Perfetto writer.  `plan
//! --trace-out timeline.json` exports the chosen candidate's simulated
//! schedule (one track per device, one per network resource), `plan
//! --explain` renders the cost waterfall behind the verdict (also
//! embedded as `Plan.explain` JSON), and the service tags every request
//! with an `X-Request-Id`, logs per-phase durations as JSON lines, and
//! keeps a `GET /debug/trace` ring buffer of recent request span trees.
//! See `docs/observability.md`.

pub mod util;
pub mod trace;
pub mod dfg;
pub mod cluster;
pub mod sim;
pub mod milp;
pub mod collective;
pub mod statistical;
pub mod models;
pub mod memory;
pub mod placer;
pub mod pipeline;
pub mod layerwise;
pub mod parallel;
pub mod data;
pub mod config;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod planner;
pub mod service;
pub mod bench;
pub mod prop;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
