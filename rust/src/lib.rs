//! # hybrid-parallel
//!
//! Production-grade reproduction of *"Optimizing Multi-GPU Parallelization
//! Strategies for Deep Learning Training"* (Pal, Ebrahimi, Zulfiqar, Fu,
//! Zhang, Migacz, Nellans, Gupta — 2019, DOI 10.1109/MM.2019.2935967).
//!
//! The paper's two contributions, plus every substrate they depend on, are
//! implemented here as a three-layer rust + JAX + Pallas stack:
//!
//! 1. **The hybrid DP+MP analytical framework** ([`parallel`]) — decomposes
//!    time-to-converge `C = T × S × E` (paper Eq. 1), quantifies N-way
//!    data-parallel speedup `SU_N = SE_N × N × E1/EN` (Eq. 3), and finds the
//!    crossover (Eq. 6) past which a hybrid strategy (N-way DP of M-way-MP
//!    workers) beats (M·N)-way DP.
//! 2. **DLPlacer** ([`placer`]) — ILP-based operation-to-device placement
//!    (paper Eq. 7–13) over an in-repo MILP solver ([`milp`]), validated
//!    against a discrete-event cluster simulator ([`sim`]) standing in for
//!    the paper's "silicon" runs.
//!
//! The training side is real: the L3 [`coordinator`] drives AOT-compiled
//! JAX/Pallas artifacts through the PJRT C API ([`runtime`]), exchanging
//! gradients with an actual chunked ring all-reduce ([`collective`]) across
//! simulated devices — python never runs on the training path.

pub mod util;
pub mod dfg;
pub mod cluster;
pub mod sim;
pub mod milp;
pub mod collective;
pub mod statistical;
pub mod models;
pub mod placer;
pub mod pipeline;
pub mod parallel;
pub mod data;
pub mod config;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod prop;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
