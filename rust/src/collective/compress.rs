//! Gradient-compressed ring all-reduce (paper §7.3 ablation).
//!
//! The paper notes framework developers keep shrinking DP's communication
//! overhead (its conservative SE_N = 1 assumption exists because of this).
//! One standard lever is half-precision gradient exchange: this module
//! implements a **bf16-on-the-wire** ring all-reduce — gradients are
//! rounded to bfloat16 before each hop while accumulation stays f32 — and
//! an α-β model for it.  The allreduce bench quantifies the SE_N gain and
//! the rounding error it buys.

use anyhow::Result;

use crate::cluster::HwGraph;

use super::{ring_allreduce, ring_cost, CollectiveResult};

/// Round an f32 to bfloat16 precision (truncate mantissa, round to
/// nearest even) and back.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // Round-to-nearest-even on the dropped 16 bits.
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// α-β cost of the compressed ring: halves the bandwidth term.
pub fn ring_cost_bf16(n: usize, f32_bytes: f64, alpha: f64, beta_bw: f64)
                      -> f64 {
    ring_cost(n, f32_bytes / 2.0, alpha, beta_bw)
}

/// bf16-on-the-wire ring all-reduce.
///
/// Every value is rounded to bf16 before it leaves a rank (simulating the
/// wire format); the receiving rank accumulates in f32.  Simulated time is
/// the plain ring's with half the payload.
pub fn ring_allreduce_bf16(bufs: &mut [Vec<f32>], hw: &HwGraph,
                           ring: &[usize]) -> Result<CollectiveResult> {
    // Wire-format rounding of each rank's contribution.
    for b in bufs.iter_mut() {
        for x in b.iter_mut() {
            *x = bf16_round(*x);
        }
    }
    let r = ring_allreduce(bufs, hw, ring)?;
    Ok(CollectiveResult {
        sim_time: r.sim_time / 2.0, // half the bytes over the same links
        bytes_on_wire: r.bytes_on_wire / 2.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dgx1;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_round_trip_properties() {
        assert_eq!(bf16_round(0.0), 0.0);
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-2.5), -2.5);
        // Relative error bounded by 2^-8.
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            let x = (rng.normal() * 100.0) as f32;
            let y = bf16_round(x);
            if x != 0.0 {
                assert!(((y - x) / x).abs() < 0.5f32 / 128.0 + 1e-7,
                        "{x} -> {y}");
            }
        }
    }

    #[test]
    fn compressed_ring_close_to_exact() {
        let hw = dgx1(4);
        let devs = hw.devices();
        let mut rng = Rng::new(7);
        let len = 4096;
        let exact: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut a = exact.clone();
        ring_allreduce(&mut a, &hw, &devs).unwrap();
        let mut b = exact.clone();
        let r = ring_allreduce_bf16(&mut b, &hw, &devs).unwrap();
        // Half the wire traffic...
        assert!(r.bytes_on_wire < 0.51 * (2.0 * 3.0 * (len * 4) as f64));
        // ...and bounded rounding error.
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert!((x - y).abs() < 0.05 * x.abs().max(1.0),
                    "exact {x} vs bf16 {y}");
        }
    }

    #[test]
    fn cost_model_halves_bandwidth_term() {
        let full = ring_cost(8, 100e6, 0.0, 25e9);
        let half = ring_cost_bf16(8, 100e6, 0.0, 25e9);
        assert!((half - full / 2.0).abs() < 1e-12);
    }
}
