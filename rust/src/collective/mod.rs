//! Collective communication: the gradient-exchange substrate (paper §3.1).
//!
//! The paper uses NCCL ring all-reduce for sync-SGD gradient sharing and
//! cites Thakur'05 / Patarasuk-Yuan'09 for its cost.  This module provides
//!
//! * [`ring_allreduce`] — a **real data-moving** chunked ring all-reduce:
//!   N worker buffers are reduced exactly as NCCL does it (N−1 reduce-
//!   scatter steps + N−1 all-gather steps over per-rank chunks), producing
//!   bit-identical sums on every rank while accounting simulated wall time
//!   over the hardware graph's links;
//! * [`tree_allreduce`] and a [`parameter_server`] baseline (the paper's
//!   "performs poorly at scale" comparison point);
//! * [`hierarchical_allreduce`] — the two-level multi-node scheme
//!   (Sridharan et al., "On Scale-out Deep Learning Training for Cloud
//!   and HPC"): intra-node reduce-scatter at NVLink speed, inter-node
//!   rings over one rank per node, intra-node allgather;
//! * α-β analytical cost models used by the scaling-efficiency
//!   projections, plus the topology-aware selection layer
//!   ([`Algorithm`], [`TopoProfile`], [`best_allreduce`]) the planner
//!   uses to price DP gradient exchange per candidate instead of
//!   assuming a flat ring.

pub mod compress;

use anyhow::{bail, Result};

use crate::cluster::HwGraph;

/// Result of a collective: per-rank reduced buffers + simulated time.
#[derive(Clone, Debug)]
pub struct CollectiveResult {
    pub sim_time: f64,
    pub bytes_on_wire: f64,
}

/// α-β cost of ring all-reduce over n ranks for `bytes` per rank:
/// `2(n−1) α + 2 (n−1)/n · bytes / β` (Patarasuk & Yuan 2009).
pub fn ring_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let n_f = n as f64;
    2.0 * (n_f - 1.0) * alpha + 2.0 * (n_f - 1.0) / n_f * bytes / beta_bw
}

/// α-β cost of a binary-tree all-reduce (reduce + broadcast):
/// `2 log2(n) (α + bytes/β)`.
pub fn tree_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let lg = (n as f64).log2().ceil();
    2.0 * lg * (alpha + bytes / beta_bw)
}

/// α-β cost of parameter-server all-reduce: every worker sends to + receives
/// from one server over its link: `2 α + 2 n bytes / β` serialised at the
/// server's NIC — the incast bottleneck that makes PS scale poorly.
pub fn ps_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    2.0 * alpha + 2.0 * (n as f64) * bytes / beta_bw
}

/// α-β cost of the two-level hierarchical all-reduce over `nodes` chassis
/// of `gpus_per_node` ranks each:
///
/// * intra-node reduce-scatter + allgather at `intra_bw`:
///   `2 (g−1) (α + (bytes/g) / β_intra)`;
/// * inter-node ring all-reduce over one rank per node and chunk, the
///   per-step shard sends of a chassis bundled through its NIC:
///   `2 (n−1) (α + (bytes/n) / β_inter)`.
///
/// Against the flat ring at the inter-node bottleneck
/// (`2(ng−1)α + 2(ng−1)/(ng)·bytes/β_inter`) this wins whenever
/// `β_intra ≥ n · β_inter` — which holds on every registry multi-node
/// graph, where store-and-forward NIC paths make the effective
/// inter-node bandwidth a small fraction of NVLink.
pub fn hierarchical_cost(nodes: usize, gpus_per_node: usize, bytes: f64,
                         alpha: f64, intra_bw: f64, inter_bw: f64) -> f64 {
    let (n, g) = (nodes.max(1), gpus_per_node.max(1));
    let mut t = 0.0;
    if g > 1 {
        t += 2.0 * (g as f64 - 1.0)
            * (alpha + (bytes / g as f64) / intra_bw);
    }
    if n > 1 {
        t += 2.0 * (n as f64 - 1.0)
            * (alpha + (bytes / n as f64) / inter_bw);
    }
    t
}

// ==========================================================================
// Topology-aware algorithm selection
// ==========================================================================

/// An all-reduce algorithm the selection layer can price and (for
/// [`Algorithm::Ring`] / [`Algorithm::Tree`] / [`Algorithm::Hierarchical`])
/// execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Bandwidth-optimal chunked ring (NCCL's default).
    Ring,
    /// Binary reduce + broadcast tree: `O(log n)` latency terms, wins the
    /// latency-dominated small-buffer regime.
    Tree,
    /// Two-level intra/inter scheme — the multi-node scale-out choice.
    Hierarchical,
}

impl Algorithm {
    /// Fixed pricing order (ties prefer the earlier, simpler algorithm).
    pub const ALL: [Algorithm; 3] =
        [Algorithm::Ring, Algorithm::Tree, Algorithm::Hierarchical];

    pub fn as_str(&self) -> &'static str {
        match self {
            Algorithm::Ring => "ring",
            Algorithm::Tree => "tree",
            Algorithm::Hierarchical => "hierarchical",
        }
    }

    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "ring" => Algorithm::Ring,
            "tree" => Algorithm::Tree,
            "hierarchical" | "hier" | "2level" => Algorithm::Hierarchical,
            other => bail!("unknown collective algorithm '{other}' \
                            (known: ring, tree, hierarchical)"),
        })
    }
}

/// Effective inter-node path of a *projected* spill: a single-box graph
/// extended across nodes crosses PCIe + IB + IB + PCIe store-and-forward
/// (the `multi_node` NIC path), ≈ 3 GB/s at 9 µs.
const SPILL_INTER_BW: f64 = 3e9;
const SPILL_INTER_LAT: f64 = 9e-6;

/// Collective-pricing summary of a hardware graph: chassis shape plus the
/// effective intra-/inter-node α-β path profiles (store-and-forward, so
/// they reproduce [`HwGraph::transfer_time`] — see
/// [`HwGraph::path_profile`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TopoProfile {
    /// Ranks per chassis an n-worker exchange groups by.  `usize::MAX`
    /// marks an in-box budget on a single-box graph: the exchange never
    /// spills, so every worker count prices intra-node.
    pub gpus_per_node: usize,
    /// Compute devices physically present.
    pub physical_devices: usize,
    /// Effective bandwidth / wire latency between two co-chassis devices.
    pub intra_bw: f64,
    pub intra_lat: f64,
    /// Effective bandwidth / wire latency across a chassis boundary (the
    /// spill constants when the graph itself is a single box).
    pub inter_bw: f64,
    pub inter_lat: f64,
}

impl TopoProfile {
    /// Profile of the physical graph (an in-box exchange; use
    /// [`TopoProfile::for_budget`] when the worker count may exceed it).
    pub fn of(hw: &HwGraph) -> TopoProfile {
        TopoProfile::for_budget(hw, hw.n_devices())
    }

    /// Profile for pricing an exchange of up to `devices` workers on
    /// `hw`.  Multi-node graphs keep their chassis shape (more workers
    /// extrapolate to more chassis of the same shape); a single-box graph
    /// stays intra-node while the budget fits and spills over the
    /// conservative NIC path once it does not — preserving the planner's
    /// "projection beyond the box sees the slower fabric" behaviour.
    pub fn for_budget(hw: &HwGraph, devices: usize) -> TopoProfile {
        const REF_BYTES: f64 = 64e6;
        let groups = hw.node_groups();
        let physical = hw.n_devices();
        // Intra profile: a co-chassis pair (NVLink default when the graph
        // is degenerate).
        let (intra_bw, intra_lat) = groups
            .iter()
            .find(|g| g.len() >= 2)
            .and_then(|g| hw.path_profile(g[0], g[1], REF_BYTES))
            .unwrap_or((25e9, 1.3e-6));
        if groups.len() > 1 {
            let (inter_bw, inter_lat) = hw
                .path_profile(groups[0][0], groups[1][0], REF_BYTES)
                .unwrap_or((SPILL_INTER_BW, SPILL_INTER_LAT));
            let g_max = groups.iter().map(|g| g.len()).max().unwrap_or(1);
            TopoProfile {
                gpus_per_node: g_max.max(1),
                physical_devices: physical,
                intra_bw,
                intra_lat,
                inter_bw,
                inter_lat,
            }
        } else if devices <= physical.max(1) {
            // In-box on a single chassis: nothing ever crosses a node.
            TopoProfile {
                gpus_per_node: usize::MAX,
                physical_devices: physical,
                intra_bw,
                intra_lat,
                inter_bw: SPILL_INTER_BW,
                inter_lat: SPILL_INTER_LAT,
            }
        } else {
            // Projection past a single box: more boxes of this size over
            // the conservative NIC path.
            TopoProfile {
                gpus_per_node: physical.max(1),
                physical_devices: physical,
                intra_bw,
                intra_lat,
                inter_bw: SPILL_INTER_BW,
                inter_lat: SPILL_INTER_LAT,
            }
        }
    }

    /// Profile for an exchange whose ranks each span `width` devices
    /// (M-way model parallelism): only `⌊g/width⌋` DP ranks fit per
    /// chassis, so the exchange crosses chassis sooner — an M = 8 hybrid
    /// on an 8-GPU-chassis pod puts one rank per chassis and every hop
    /// on the inter-node path.  `width ≤ 1` and in-box single-box
    /// profiles (which never spill) are unchanged; a width that does not
    /// divide the chassis rounds down (conservative packing).
    pub fn for_worker_width(&self, width: usize) -> TopoProfile {
        if width <= 1 || self.gpus_per_node == usize::MAX {
            return self.clone();
        }
        TopoProfile {
            gpus_per_node: (self.gpus_per_node / width).max(1),
            ..self.clone()
        }
    }

    /// Chassis an `n`-worker exchange spans (projections add chassis of
    /// the same shape).
    pub fn nodes_for(&self, n: usize) -> usize {
        if self.gpus_per_node == usize::MAX {
            1
        } else {
            n.div_ceil(self.gpus_per_node.max(1)).max(1)
        }
    }

    /// Worst-hop α-β parameters of an `n`-worker flat ring/tree: the
    /// inter-node path once the exchange spans chassis, the intra path
    /// while it does not.  `alpha` is per-step software overhead added on
    /// top of the wire latency.
    fn worst_hop(&self, n: usize, alpha: f64) -> (f64, f64) {
        if self.nodes_for(n) > 1 {
            (alpha + self.inter_lat, self.inter_bw)
        } else {
            (alpha + self.intra_lat, self.intra_bw)
        }
    }

    /// α-β cost of `algorithm` for an `n`-worker all-reduce of `bytes`
    /// per worker on this topology.
    pub fn cost(&self, algorithm: Algorithm, n: usize, bytes: f64,
                alpha: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        match algorithm {
            Algorithm::Ring => {
                let (a, b) = self.worst_hop(n, alpha);
                ring_cost(n, bytes, a, b)
            }
            Algorithm::Tree => {
                let (a, b) = self.worst_hop(n, alpha);
                tree_cost(n, bytes, a, b)
            }
            Algorithm::Hierarchical => {
                let nodes = self.nodes_for(n);
                let g = if nodes <= 1 {
                    n
                } else {
                    self.gpus_per_node.min(n)
                };
                // One formula owner: the intra and inter phases of
                // [`hierarchical_cost`], each with its own per-step wire
                // latency folded into α.
                hierarchical_cost(1, g, bytes, alpha + self.intra_lat,
                                  self.intra_bw, self.inter_bw)
                    + hierarchical_cost(nodes, 1, bytes,
                                        alpha + self.inter_lat,
                                        self.intra_bw, self.inter_bw)
            }
        }
    }
}

/// The selection layer's verdict: which algorithm prices an exchange
/// cheapest, and at what α-β cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CollectiveChoice {
    pub algorithm: Algorithm,
    pub cost_s: f64,
}

/// Default per-step software overhead (NCCL-kernel-launch class).
pub const DEFAULT_ALPHA: f64 = 5e-6;

/// Pick the best *feasible* all-reduce for an `n`-worker exchange of
/// `bytes` per worker on `p`: every algorithm of [`Algorithm::ALL`] is
/// priced ([`Algorithm::Hierarchical`] only once the exchange actually
/// spans chassis — on a single node it degenerates to the ring) and the
/// strictly cheapest wins, ties keeping the earlier entry, so the choice
/// is deterministic.
pub fn best_allreduce_on(n: usize, bytes: f64, p: &TopoProfile, alpha: f64)
                         -> CollectiveChoice {
    let mut best = CollectiveChoice {
        algorithm: Algorithm::Ring,
        cost_s: p.cost(Algorithm::Ring, n, bytes, alpha),
    };
    if n <= 1 {
        return CollectiveChoice { algorithm: Algorithm::Ring, cost_s: 0.0 };
    }
    for &a in &Algorithm::ALL[1..] {
        if a == Algorithm::Hierarchical && p.nodes_for(n) <= 1 {
            continue; // degenerates to the ring on a single chassis
        }
        let c = p.cost(a, n, bytes, alpha);
        if c < best.cost_s {
            best = CollectiveChoice { algorithm: a, cost_s: c };
        }
    }
    best
}

/// [`best_allreduce_on`] against the physical graph's own profile with
/// the default software α — the `best_allreduce(n, bytes, hw)` entry
/// point the planner's cost models build on.
pub fn best_allreduce(n: usize, bytes: f64, hw: &HwGraph)
                      -> CollectiveChoice {
    best_allreduce_on(n, bytes, &TopoProfile::of(hw), DEFAULT_ALPHA)
}

/// In-place chunked ring all-reduce over real f32 buffers.
///
/// `bufs[r]` is rank r's gradient vector; on return every rank holds the
/// element-wise **sum** (callers divide by N for the sync-SGD average).
/// `ring[r]` is the hardware-graph device of rank r; simulated time uses
/// the slowest inter-neighbor link per step (bulk-synchronous steps, as in
/// NCCL's LL protocol analysis).
pub fn ring_allreduce(bufs: &mut [Vec<f32>], hw: &HwGraph, ring: &[usize])
                      -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    if ring.len() != n {
        bail!("ring/buffer count mismatch");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("buffer length mismatch");
    }
    if n == 1 {
        return Ok(CollectiveResult { sim_time: 0.0, bytes_on_wire: 0.0 });
    }

    // Chunk boundaries: chunk c = [starts[c], starts[c+1]).
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk_bytes =
        |c: usize| ((starts[c + 1] - starts[c]) * 4) as f64;

    // Neighbor transfer time for the largest chunk this step (bulk sync).
    let step_time = |bytes: f64| -> f64 {
        let mut worst: f64 = 0.0;
        for r in 0..n {
            let t = hw.transfer_time(ring[r], ring[(r + 1) % n], bytes);
            worst = worst.max(t);
        }
        worst
    };

    let mut sim_time = 0.0;
    let mut wire = 0.0;

    // --- reduce-scatter: after N-1 steps, rank r owns the full sum of
    // chunk (r+1) mod n. Step s: rank r sends chunk (r - s) mod n to r+1,
    // which accumulates it.
    for s in 0..(n - 1) {
        // Compute transfers for this step before mutating (bulk sync).
        let mut max_bytes: f64 = 0.0;
        let mut incoming: Vec<(usize, usize)> = Vec::with_capacity(n);
        for r in 0..n {
            let c = (r + n - s) % n;
            let dst = (r + 1) % n;
            incoming.push((dst, c));
            max_bytes = max_bytes.max(chunk_bytes(c));
            wire += chunk_bytes(c);
        }
        // Apply: dst += src chunk. Need source values from *before* this
        // step; ring structure guarantees each rank receives exactly one
        // chunk and sends a disjoint one, so sequential apply is safe as
        // long as we read the sender's (possibly already updated this
        // step?) — sender sends chunk it accumulated in PREVIOUS steps,
        // and receives a different chunk this step, so no conflict.
        for &(dst, c) in &incoming {
            let src = (dst + n - 1) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // Split borrow.
            let (a, b) = if src < dst {
                let (l, r_) = bufs.split_at_mut(dst);
                (&l[src], &mut r_[0])
            } else {
                let (l, r_) = bufs.split_at_mut(src);
                (&r_[0], &mut l[dst])
            };
            // Slice zip vectorizes (§Perf: ~3x over indexed loop).
            for (x, y) in b[lo..hi].iter_mut().zip(&a[lo..hi]) {
                *x += *y;
            }
        }
        sim_time += step_time(max_bytes);
    }

    // --- all-gather: rank r owns chunk (r+1)%n; N-1 steps of copying.
    for s in 0..(n - 1) {
        let mut max_bytes: f64 = 0.0;
        let mut moves: Vec<(usize, usize)> = Vec::with_capacity(n);
        for r in 0..n {
            // Step s: rank r sends chunk (r + 1 - s) mod n to rank r+1.
            let c = (r + 1 + n - s) % n;
            let dst = (r + 1) % n;
            moves.push((dst, c));
            max_bytes = max_bytes.max(chunk_bytes(c));
            wire += chunk_bytes(c);
        }
        for &(dst, c) in &moves {
            let src = (dst + n - 1) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = if src < dst {
                let (l, r_) = bufs.split_at_mut(dst);
                (&l[src], &mut r_[0])
            } else {
                let (l, r_) = bufs.split_at_mut(src);
                (&r_[0], &mut l[dst])
            };
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
        sim_time += step_time(max_bytes);
    }

    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

/// Tree all-reduce (reduce-to-root + broadcast) over real buffers.
/// Simpler traffic pattern, 2·log2(N) latency terms; used as the ablation
/// baseline against the ring.
pub fn tree_allreduce(bufs: &mut [Vec<f32>], hw: &HwGraph, ranks: &[usize])
                      -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("buffer length mismatch");
    }
    let bytes = (len * 4) as f64;
    let mut sim_time = 0.0;
    let mut wire = 0.0;
    // Reduce: stride doubling.
    let mut stride = 1;
    while stride < n {
        let mut worst: f64 = 0.0;
        for r in (0..n).step_by(2 * stride) {
            let other = r + stride;
            if other < n {
                let (l, rr) = bufs.split_at_mut(other);
                for (x, y) in l[r].iter_mut().zip(rr[0].iter()) {
                    *x += *y;
                }
                worst = worst.max(hw.transfer_time(ranks[other], ranks[r],
                                                   bytes));
                wire += bytes;
            }
        }
        sim_time += worst;
        stride *= 2;
    }
    // Broadcast root (rank 0) back down.
    let root = bufs[0].clone();
    let mut worst: f64 = 0.0;
    for r in 1..n {
        bufs[r].copy_from_slice(&root);
        worst = worst.max(hw.transfer_time(ranks[0], ranks[r], bytes));
        wire += bytes;
    }
    // Broadcast is log-depth in reality; model as ceil(log2 n) serial hops
    // of the worst link.
    sim_time += worst * (n as f64).log2().ceil();
    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

/// Parameter-server reduce: all workers push to rank 0's device, which sums
/// and pushes back. Real data movement; server NIC serialises.
pub fn parameter_server(bufs: &mut [Vec<f32>], hw: &HwGraph, ranks: &[usize])
                        -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    let len = bufs[0].len();
    let bytes = (len * 4) as f64;
    let mut sum = bufs[0].clone();
    let mut sim_time = 0.0;
    let mut wire = 0.0;
    for r in 1..n {
        for (x, y) in sum.iter_mut().zip(bufs[r].iter()) {
            *x += *y;
        }
        // Serialised incast at the server.
        sim_time += hw.transfer_time(ranks[r], ranks[0], bytes);
        wire += bytes;
    }
    for r in 0..n {
        bufs[r].copy_from_slice(&sum);
        if r > 0 {
            sim_time += hw.transfer_time(ranks[0], ranks[r], bytes);
            wire += bytes;
        }
    }
    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

/// In-place two-level hierarchical all-reduce over real f32 buffers —
/// the executable counterpart of [`hierarchical_cost`].
///
/// Ranks are grouped by [`HwGraph::node_of`]; groups must be equal-sized
/// (one rank set per chassis).  Three phases, each bulk-synchronous like
/// [`ring_allreduce`]:
///
/// 1. **intra-node reduce-scatter** — a (g−1)-step ring inside every
///    chassis concurrently; after it, member `j` of each chassis owns the
///    chassis-local sum of chunk `(j+1) mod g`;
/// 2. **inter-node rings** — for every chunk, its owner ranks (one per
///    chassis) run an n-node ring all-reduce of that chunk; the g
///    concurrent shard rings share each chassis NIC, so a step is charged
///    as one bundled `Σ shard bytes ≈ bytes/n` transfer per chassis pair;
/// 3. **intra-node allgather** — (g−1) ring steps spread every
///    globally-reduced chunk back across the chassis.
///
/// On a single-chassis graph this delegates to [`ring_allreduce`].
pub fn hierarchical_allreduce(bufs: &mut [Vec<f32>], hw: &HwGraph,
                              ranks: &[usize]) -> Result<CollectiveResult> {
    let n_ranks = bufs.len();
    if n_ranks == 0 {
        bail!("no buffers");
    }
    if ranks.len() != n_ranks {
        bail!("rank/buffer count mismatch");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("buffer length mismatch");
    }
    // Group rank indices by chassis, in first-appearance order.
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for (r, &dev) in ranks.iter().enumerate() {
        let nd = hw.node_of(dev);
        match groups.iter_mut().find(|(node, _)| *node == nd) {
            Some((_, g)) => g.push(r),
            None => groups.push((nd, vec![r])),
        }
    }
    let n_nodes = groups.len();
    if n_nodes <= 1 {
        return ring_allreduce(bufs, hw, ranks);
    }
    let g = groups[0].1.len();
    if groups.iter().any(|(_, grp)| grp.len() != g) {
        bail!("hierarchical all-reduce needs equal ranks per node \
               (got {:?})",
              groups.iter().map(|(_, grp)| grp.len()).collect::<Vec<_>>());
    }
    let groups: Vec<Vec<usize>> =
        groups.into_iter().map(|(_, grp)| grp).collect();

    let mut sim_time = 0.0;
    let mut wire = 0.0;

    // Chunk c of the intra partition = [starts[c], starts[c+1]).
    let starts: Vec<usize> = (0..=g).map(|c| c * len / g).collect();
    let chunk_bytes = |c: usize| ((starts[c + 1] - starts[c]) * 4) as f64;
    // Accumulate `src`'s slice into `dst`'s (split-borrow helper).
    fn apply(bufs: &mut [Vec<f32>], src: usize, dst: usize, lo: usize,
             hi: usize, add: bool) {
        let (a, b) = if src < dst {
            let (l, r) = bufs.split_at_mut(dst);
            (&l[src], &mut r[0])
        } else {
            let (l, r) = bufs.split_at_mut(src);
            (&r[0], &mut l[dst])
        };
        if add {
            for (x, y) in b[lo..hi].iter_mut().zip(&a[lo..hi]) {
                *x += *y;
            }
        } else {
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
    }

    if g > 1 {
        // --- phase 1: intra-node reduce-scatter, all chassis concurrent.
        for s in 0..(g - 1) {
            let mut max_t: f64 = 0.0;
            for grp in &groups {
                for (j, &rank) in grp.iter().enumerate() {
                    let c = (j + g - s) % g;
                    let dst = grp[(j + 1) % g];
                    max_t = max_t.max(hw.transfer_time(
                        ranks[rank], ranks[dst], chunk_bytes(c)));
                    wire += chunk_bytes(c);
                    apply(bufs, rank, dst, starts[c], starts[c + 1], true);
                }
            }
            sim_time += max_t;
        }
    }
    // Owner (group-member index) of chunk c after the reduce-scatter.
    let owner = |c: usize| (c + g - 1) % g;

    // --- phase 2: inter-node shard rings, one owner rank per chassis
    // and chunk; per step each chassis bundles its g shard sends through
    // the NIC.
    for half in 0..2 {
        // half 0: reduce-scatter across nodes; half 1: allgather.
        for s in 0..(n_nodes - 1) {
            let mut pair_bytes = vec![0.0f64; n_nodes];
            for c in 0..g {
                let (lo_c, hi_c) = (starts[c], starts[c + 1]);
                let clen = hi_c - lo_c;
                let sub = |k: usize| lo_c + k * clen / n_nodes;
                for nd in 0..n_nodes {
                    let k = if half == 0 {
                        (nd + n_nodes - s) % n_nodes
                    } else {
                        (nd + 1 + n_nodes - s) % n_nodes
                    };
                    let (lo, hi) = (sub(k), sub(k + 1));
                    let src = groups[nd][owner(c)];
                    let dst = groups[(nd + 1) % n_nodes][owner(c)];
                    pair_bytes[nd] += ((hi - lo) * 4) as f64;
                    wire += ((hi - lo) * 4) as f64;
                    apply(bufs, src, dst, lo, hi, half == 0);
                }
            }
            // Bundled per-chassis transfer between representative owners.
            let mut max_t: f64 = 0.0;
            for nd in 0..n_nodes {
                let src = groups[nd][owner(0)];
                let dst = groups[(nd + 1) % n_nodes][owner(0)];
                max_t = max_t.max(hw.transfer_time(
                    ranks[src], ranks[dst], pair_bytes[nd]));
            }
            sim_time += max_t;
        }
    }

    if g > 1 {
        // --- phase 3: intra-node allgather.
        for s in 0..(g - 1) {
            let mut max_t: f64 = 0.0;
            for grp in &groups {
                for (j, &rank) in grp.iter().enumerate() {
                    let c = (j + 1 + g - s) % g;
                    let dst = grp[(j + 1) % g];
                    max_t = max_t.max(hw.transfer_time(
                        ranks[rank], ranks[dst], chunk_bytes(c)));
                    wire += chunk_bytes(c);
                    apply(bufs, rank, dst, starts[c], starts[c + 1], false);
                }
            }
            sim_time += max_t;
        }
    }

    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{dgx1, multi_node};
    use crate::util::rng::Rng;

    fn random_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f64> {
        let len = bufs[0].len();
        let mut s = vec![0.0f64; len];
        for b in bufs {
            for (i, &v) in b.iter().enumerate() {
                s[i] += v as f64;
            }
        }
        s
    }

    #[test]
    fn ring_matches_sum() {
        for n in [2usize, 3, 4, 7, 8] {
            for len in [1usize, 5, 64, 1000] {
                let hw = multi_node(2, 4);
                let ring: Vec<usize> =
                    hw.devices().into_iter().take(n.min(8)).collect();
                let ring = if ring.len() < n {
                    vec![hw.devices()[0]; n]
                } else {
                    ring
                };
                let mut bufs = random_bufs(n, len, (n * len) as u64);
                let want = expected_sum(&bufs);
                ring_allreduce(&mut bufs, &hw, &ring).unwrap();
                for b in &bufs {
                    for (i, &v) in b.iter().enumerate() {
                        assert!((v as f64 - want[i]).abs()
                                < 1e-3 * want[i].abs().max(1.0),
                                "n={n} len={len} i={i}");
                    }
                }
                // All ranks identical.
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0]);
                }
            }
        }
    }

    #[test]
    fn tree_and_ps_match_sum() {
        let hw = dgx1(4);
        let ranks = hw.devices();
        for f in [tree_allreduce, parameter_server] {
            let mut bufs = random_bufs(4, 333, 9);
            let want = expected_sum(&bufs);
            f(&mut bufs, &hw, &ranks).unwrap();
            for b in &bufs {
                for (i, &v) in b.iter().enumerate() {
                    assert!((v as f64 - want[i]).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn single_rank_noop() {
        let hw = dgx1(1);
        let mut bufs = vec![vec![1.0f32, 2.0]];
        let r = ring_allreduce(&mut bufs, &hw, &[0]).unwrap();
        assert_eq!(r.sim_time, 0.0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn ring_wire_bytes_match_theory() {
        // 2(n-1)/n * total bytes * n ranks sending... per-rank traffic is
        // 2(n-1)/n * bytes; total on wire = n * that.
        let n = 4;
        let len = 1024;
        let hw = dgx1(4);
        let mut bufs = random_bufs(n, len, 1);
        let r = ring_allreduce(&mut bufs, &hw, &hw.devices()).unwrap();
        let per_rank = 2.0 * (n as f64 - 1.0) / n as f64 * (len * 4) as f64;
        assert!((r.bytes_on_wire - per_rank * n as f64).abs() < 1.0,
                "wire={} want={}", r.bytes_on_wire, per_rank * n as f64);
    }

    #[test]
    fn ring_cost_model_monotonic_in_n() {
        let bytes = 100e6;
        let mut prev = 0.0;
        for n in [2, 4, 8, 16, 64, 256] {
            let c = ring_cost(n, bytes, 5e-6, 25e9);
            assert!(c > prev);
            prev = c;
        }
        // Asymptote: 2*bytes/bw.
        let inf = ring_cost(100_000, bytes, 0.0, 25e9);
        assert!((inf - 2.0 * bytes / 25e9).abs() / inf < 1e-3);
    }

    #[test]
    fn ps_worse_than_ring_at_scale() {
        let bytes = 100e6;
        assert!(ps_cost(64, bytes, 5e-6, 12e9)
                > 5.0 * ring_cost(64, bytes, 5e-6, 12e9));
    }

    #[test]
    fn ring_sim_time_scales_with_slow_link() {
        // Multi-node ring must be slower than single-node NVLink ring.
        let len = 1 << 20;
        let hw1 = dgx1(4);
        let mut b1 = random_bufs(4, len, 2);
        let t1 = ring_allreduce(&mut b1, &hw1, &hw1.devices())
            .unwrap()
            .sim_time;
        let hw2 = multi_node(2, 2);
        let mut b2 = random_bufs(4, len, 2);
        let t2 = ring_allreduce(&mut b2, &hw2, &hw2.devices())
            .unwrap()
            .sim_time;
        assert!(t2 > t1, "inter-node {t2} must exceed NVLink {t1}");
    }

    #[test]
    fn hierarchical_matches_sum_and_all_ranks_agree() {
        for (nodes, g) in [(2usize, 4usize), (4, 2), (3, 3), (2, 1)] {
            let hw = multi_node(nodes, g.max(2));
            // One rank per chassis slot: the first g devices of each node.
            let groups = hw.node_groups();
            let devs: Vec<usize> = groups
                .iter()
                .flat_map(|grp| grp.iter().take(g).copied())
                .collect();
            for len in [1usize, 7, 64, 1000] {
                let mut bufs = random_bufs(nodes * g, len,
                                           (nodes * g * len) as u64);
                let want = expected_sum(&bufs);
                let r = hierarchical_allreduce(&mut bufs, &hw, &devs)
                    .unwrap();
                assert!(r.sim_time > 0.0);
                for b in &bufs {
                    for (i, &v) in b.iter().enumerate() {
                        assert!((v as f64 - want[i]).abs()
                                < 1e-3 * want[i].abs().max(1.0),
                                "{nodes}x{g} len={len} i={i}");
                    }
                }
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0], "ranks must agree bitwise");
                }
            }
        }
    }

    #[test]
    fn hierarchical_single_node_delegates_to_ring() {
        let hw = dgx1(4);
        let devs = hw.devices();
        let mut a = random_bufs(4, 333, 7);
        let mut b = a.clone();
        let rh = hierarchical_allreduce(&mut a, &hw, &devs).unwrap();
        let rr = ring_allreduce(&mut b, &hw, &devs).unwrap();
        assert_eq!(a, b, "single chassis must be the ring bit-for-bit");
        assert_eq!(rh.sim_time, rr.sim_time);
    }

    #[test]
    fn hierarchical_rejects_uneven_groups() {
        let hw = multi_node(2, 4);
        let devs = hw.devices();
        // 3 ranks on node 0, 1 on node 1.
        let ranks = vec![devs[0], devs[1], devs[2], devs[4]];
        let mut bufs = random_bufs(4, 16, 1);
        assert!(hierarchical_allreduce(&mut bufs, &hw, &ranks).is_err());
    }

    #[test]
    fn hierarchical_beats_flat_ring_across_nodes() {
        let hw = multi_node(4, 8);
        let devs = hw.devices();
        let len = 1 << 20; // 4 MB per rank
        let mut a = random_bufs(32, len, 3);
        let t_hier = hierarchical_allreduce(&mut a, &hw, &devs)
            .unwrap()
            .sim_time;
        let mut b = random_bufs(32, len, 3);
        let t_ring = ring_allreduce(&mut b, &hw, &devs).unwrap().sim_time;
        assert!(t_hier < t_ring,
                "two-level {t_hier} must beat the flat ring {t_ring}");
    }

    #[test]
    fn hierarchical_cost_degenerates_sanely() {
        assert_eq!(hierarchical_cost(1, 1, 1e9, 5e-6, 25e9, 3e9), 0.0);
        // One node → pure intra ring; one GPU per node → pure inter ring.
        let intra = hierarchical_cost(1, 8, 4e8, 5e-6, 25e9, 3e9);
        assert!((intra - ring_cost(8, 4e8, 5e-6, 25e9)).abs() < 1e-12);
        let inter = hierarchical_cost(8, 1, 4e8, 5e-6, 25e9, 3e9);
        assert!((inter - ring_cost(8, 4e8, 5e-6, 3e9)).abs() < 1e-12);
    }

    #[test]
    fn algorithm_parse_round_trip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.as_str()).unwrap(), a);
        }
        assert!(Algorithm::parse("butterfly").is_err());
    }

    #[test]
    fn best_allreduce_is_topology_aware() {
        // Multi-node + paper-size gradients → hierarchical.
        let pod = multi_node(4, 8);
        let big = best_allreduce(32, 640e6, &pod);
        assert_eq!(big.algorithm, Algorithm::Hierarchical);
        let p = TopoProfile::of(&pod);
        assert!(big.cost_s
                < p.cost(Algorithm::Ring, 32, 640e6, DEFAULT_ALPHA));
        // Tiny payloads are latency-dominated → tree.
        let small = best_allreduce(32, 1e3, &pod);
        assert_eq!(small.algorithm, Algorithm::Tree);
        // Single box in-budget → plain ring (hierarchical degenerates).
        let box1 = dgx1(8);
        let inbox = best_allreduce(8, 640e6, &box1);
        assert_eq!(inbox.algorithm, Algorithm::Ring);
        // n = 1 → free.
        assert_eq!(best_allreduce(1, 640e6, &box1).cost_s, 0.0);
    }

    #[test]
    fn topo_profile_spills_single_boxes_conservatively() {
        let hw = dgx1(8);
        let inbox = TopoProfile::for_budget(&hw, 8);
        assert_eq!(inbox.nodes_for(256), 1, "in-box budgets never spill");
        let spilled = TopoProfile::for_budget(&hw, 256);
        assert_eq!(spilled.gpus_per_node, 8);
        assert_eq!(spilled.nodes_for(256), 32);
        assert!(spilled.inter_bw < spilled.intra_bw);
        // Multi-node graphs keep their chassis shape either way.
        let mn = TopoProfile::for_budget(&multi_node(2, 4), 4);
        assert_eq!(mn.gpus_per_node, 4);
        assert_eq!(mn.nodes_for(8), 2);
        assert!((mn.inter_bw - 3e9).abs() < 1e3);
    }

    #[test]
    fn uneven_chunks_handled() {
        // len not divisible by n.
        let hw = dgx1(4);
        let mut bufs = random_bufs(3, 10, 5);
        let want = expected_sum(&bufs);
        ring_allreduce(&mut bufs, &hw, &[0, 1, 2]).unwrap();
        for (i, &w) in want.iter().enumerate() {
            assert!((bufs[0][i] as f64 - w).abs() < 1e-4);
        }
    }
}
