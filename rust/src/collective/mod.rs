//! Collective communication: the gradient-exchange substrate (paper §3.1).
//!
//! The paper uses NCCL ring all-reduce for sync-SGD gradient sharing and
//! cites Thakur'05 / Patarasuk-Yuan'09 for its cost.  This module provides
//!
//! * [`ring_allreduce`] — a **real data-moving** chunked ring all-reduce:
//!   N worker buffers are reduced exactly as NCCL does it (N−1 reduce-
//!   scatter steps + N−1 all-gather steps over per-rank chunks), producing
//!   bit-identical sums on every rank while accounting simulated wall time
//!   over the hardware graph's links;
//! * [`tree_allreduce`] and a [`parameter_server`] baseline (the paper's
//!   "performs poorly at scale" comparison point);
//! * α-β analytical cost models used by the scaling-efficiency projections.

pub mod compress;

use anyhow::{bail, Result};

use crate::cluster::HwGraph;

/// Result of a collective: per-rank reduced buffers + simulated time.
#[derive(Clone, Debug)]
pub struct CollectiveResult {
    pub sim_time: f64,
    pub bytes_on_wire: f64,
}

/// α-β cost of ring all-reduce over n ranks for `bytes` per rank:
/// `2(n−1) α + 2 (n−1)/n · bytes / β` (Patarasuk & Yuan 2009).
pub fn ring_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let n_f = n as f64;
    2.0 * (n_f - 1.0) * alpha + 2.0 * (n_f - 1.0) / n_f * bytes / beta_bw
}

/// α-β cost of a binary-tree all-reduce (reduce + broadcast):
/// `2 log2(n) (α + bytes/β)`.
pub fn tree_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let lg = (n as f64).log2().ceil();
    2.0 * lg * (alpha + bytes / beta_bw)
}

/// α-β cost of parameter-server all-reduce: every worker sends to + receives
/// from one server over its link: `2 α + 2 n bytes / β` serialised at the
/// server's NIC — the incast bottleneck that makes PS scale poorly.
pub fn ps_cost(n: usize, bytes: f64, alpha: f64, beta_bw: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    2.0 * alpha + 2.0 * (n as f64) * bytes / beta_bw
}

/// In-place chunked ring all-reduce over real f32 buffers.
///
/// `bufs[r]` is rank r's gradient vector; on return every rank holds the
/// element-wise **sum** (callers divide by N for the sync-SGD average).
/// `ring[r]` is the hardware-graph device of rank r; simulated time uses
/// the slowest inter-neighbor link per step (bulk-synchronous steps, as in
/// NCCL's LL protocol analysis).
pub fn ring_allreduce(bufs: &mut [Vec<f32>], hw: &HwGraph, ring: &[usize])
                      -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    if ring.len() != n {
        bail!("ring/buffer count mismatch");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("buffer length mismatch");
    }
    if n == 1 {
        return Ok(CollectiveResult { sim_time: 0.0, bytes_on_wire: 0.0 });
    }

    // Chunk boundaries: chunk c = [starts[c], starts[c+1]).
    let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let chunk_bytes =
        |c: usize| ((starts[c + 1] - starts[c]) * 4) as f64;

    // Neighbor transfer time for the largest chunk this step (bulk sync).
    let step_time = |bytes: f64| -> f64 {
        let mut worst: f64 = 0.0;
        for r in 0..n {
            let t = hw.transfer_time(ring[r], ring[(r + 1) % n], bytes);
            worst = worst.max(t);
        }
        worst
    };

    let mut sim_time = 0.0;
    let mut wire = 0.0;

    // --- reduce-scatter: after N-1 steps, rank r owns the full sum of
    // chunk (r+1) mod n. Step s: rank r sends chunk (r - s) mod n to r+1,
    // which accumulates it.
    for s in 0..(n - 1) {
        // Compute transfers for this step before mutating (bulk sync).
        let mut max_bytes: f64 = 0.0;
        let mut incoming: Vec<(usize, usize)> = Vec::with_capacity(n);
        for r in 0..n {
            let c = (r + n - s) % n;
            let dst = (r + 1) % n;
            incoming.push((dst, c));
            max_bytes = max_bytes.max(chunk_bytes(c));
            wire += chunk_bytes(c);
        }
        // Apply: dst += src chunk. Need source values from *before* this
        // step; ring structure guarantees each rank receives exactly one
        // chunk and sends a disjoint one, so sequential apply is safe as
        // long as we read the sender's (possibly already updated this
        // step?) — sender sends chunk it accumulated in PREVIOUS steps,
        // and receives a different chunk this step, so no conflict.
        for &(dst, c) in &incoming {
            let src = (dst + n - 1) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            // Split borrow.
            let (a, b) = if src < dst {
                let (l, r_) = bufs.split_at_mut(dst);
                (&l[src], &mut r_[0])
            } else {
                let (l, r_) = bufs.split_at_mut(src);
                (&r_[0], &mut l[dst])
            };
            // Slice zip vectorizes (§Perf: ~3x over indexed loop).
            for (x, y) in b[lo..hi].iter_mut().zip(&a[lo..hi]) {
                *x += *y;
            }
        }
        sim_time += step_time(max_bytes);
    }

    // --- all-gather: rank r owns chunk (r+1)%n; N-1 steps of copying.
    for s in 0..(n - 1) {
        let mut max_bytes: f64 = 0.0;
        let mut moves: Vec<(usize, usize)> = Vec::with_capacity(n);
        for r in 0..n {
            // Step s: rank r sends chunk (r + 1 - s) mod n to rank r+1.
            let c = (r + 1 + n - s) % n;
            let dst = (r + 1) % n;
            moves.push((dst, c));
            max_bytes = max_bytes.max(chunk_bytes(c));
            wire += chunk_bytes(c);
        }
        for &(dst, c) in &moves {
            let src = (dst + n - 1) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            let (a, b) = if src < dst {
                let (l, r_) = bufs.split_at_mut(dst);
                (&l[src], &mut r_[0])
            } else {
                let (l, r_) = bufs.split_at_mut(src);
                (&r_[0], &mut l[dst])
            };
            b[lo..hi].copy_from_slice(&a[lo..hi]);
        }
        sim_time += step_time(max_bytes);
    }

    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

/// Tree all-reduce (reduce-to-root + broadcast) over real buffers.
/// Simpler traffic pattern, 2·log2(N) latency terms; used as the ablation
/// baseline against the ring.
pub fn tree_allreduce(bufs: &mut [Vec<f32>], hw: &HwGraph, ranks: &[usize])
                      -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("buffer length mismatch");
    }
    let bytes = (len * 4) as f64;
    let mut sim_time = 0.0;
    let mut wire = 0.0;
    // Reduce: stride doubling.
    let mut stride = 1;
    while stride < n {
        let mut worst: f64 = 0.0;
        for r in (0..n).step_by(2 * stride) {
            let other = r + stride;
            if other < n {
                let (l, rr) = bufs.split_at_mut(other);
                for (x, y) in l[r].iter_mut().zip(rr[0].iter()) {
                    *x += *y;
                }
                worst = worst.max(hw.transfer_time(ranks[other], ranks[r],
                                                   bytes));
                wire += bytes;
            }
        }
        sim_time += worst;
        stride *= 2;
    }
    // Broadcast root (rank 0) back down.
    let root = bufs[0].clone();
    let mut worst: f64 = 0.0;
    for r in 1..n {
        bufs[r].copy_from_slice(&root);
        worst = worst.max(hw.transfer_time(ranks[0], ranks[r], bytes));
        wire += bytes;
    }
    // Broadcast is log-depth in reality; model as ceil(log2 n) serial hops
    // of the worst link.
    sim_time += worst * (n as f64).log2().ceil();
    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

/// Parameter-server reduce: all workers push to rank 0's device, which sums
/// and pushes back. Real data movement; server NIC serialises.
pub fn parameter_server(bufs: &mut [Vec<f32>], hw: &HwGraph, ranks: &[usize])
                        -> Result<CollectiveResult> {
    let n = bufs.len();
    if n == 0 {
        bail!("no buffers");
    }
    let len = bufs[0].len();
    let bytes = (len * 4) as f64;
    let mut sum = bufs[0].clone();
    let mut sim_time = 0.0;
    let mut wire = 0.0;
    for r in 1..n {
        for (x, y) in sum.iter_mut().zip(bufs[r].iter()) {
            *x += *y;
        }
        // Serialised incast at the server.
        sim_time += hw.transfer_time(ranks[r], ranks[0], bytes);
        wire += bytes;
    }
    for r in 0..n {
        bufs[r].copy_from_slice(&sum);
        if r > 0 {
            sim_time += hw.transfer_time(ranks[0], ranks[r], bytes);
            wire += bytes;
        }
    }
    Ok(CollectiveResult { sim_time, bytes_on_wire: wire })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{dgx1, multi_node};
    use crate::util::rng::Rng;

    fn random_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f64> {
        let len = bufs[0].len();
        let mut s = vec![0.0f64; len];
        for b in bufs {
            for (i, &v) in b.iter().enumerate() {
                s[i] += v as f64;
            }
        }
        s
    }

    #[test]
    fn ring_matches_sum() {
        for n in [2usize, 3, 4, 7, 8] {
            for len in [1usize, 5, 64, 1000] {
                let hw = multi_node(2, 4);
                let ring: Vec<usize> =
                    hw.devices().into_iter().take(n.min(8)).collect();
                let ring = if ring.len() < n {
                    vec![hw.devices()[0]; n]
                } else {
                    ring
                };
                let mut bufs = random_bufs(n, len, (n * len) as u64);
                let want = expected_sum(&bufs);
                ring_allreduce(&mut bufs, &hw, &ring).unwrap();
                for b in &bufs {
                    for (i, &v) in b.iter().enumerate() {
                        assert!((v as f64 - want[i]).abs()
                                < 1e-3 * want[i].abs().max(1.0),
                                "n={n} len={len} i={i}");
                    }
                }
                // All ranks identical.
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0]);
                }
            }
        }
    }

    #[test]
    fn tree_and_ps_match_sum() {
        let hw = dgx1(4);
        let ranks = hw.devices();
        for f in [tree_allreduce, parameter_server] {
            let mut bufs = random_bufs(4, 333, 9);
            let want = expected_sum(&bufs);
            f(&mut bufs, &hw, &ranks).unwrap();
            for b in &bufs {
                for (i, &v) in b.iter().enumerate() {
                    assert!((v as f64 - want[i]).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn single_rank_noop() {
        let hw = dgx1(1);
        let mut bufs = vec![vec![1.0f32, 2.0]];
        let r = ring_allreduce(&mut bufs, &hw, &[0]).unwrap();
        assert_eq!(r.sim_time, 0.0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn ring_wire_bytes_match_theory() {
        // 2(n-1)/n * total bytes * n ranks sending... per-rank traffic is
        // 2(n-1)/n * bytes; total on wire = n * that.
        let n = 4;
        let len = 1024;
        let hw = dgx1(4);
        let mut bufs = random_bufs(n, len, 1);
        let r = ring_allreduce(&mut bufs, &hw, &hw.devices()).unwrap();
        let per_rank = 2.0 * (n as f64 - 1.0) / n as f64 * (len * 4) as f64;
        assert!((r.bytes_on_wire - per_rank * n as f64).abs() < 1.0,
                "wire={} want={}", r.bytes_on_wire, per_rank * n as f64);
    }

    #[test]
    fn ring_cost_model_monotonic_in_n() {
        let bytes = 100e6;
        let mut prev = 0.0;
        for n in [2, 4, 8, 16, 64, 256] {
            let c = ring_cost(n, bytes, 5e-6, 25e9);
            assert!(c > prev);
            prev = c;
        }
        // Asymptote: 2*bytes/bw.
        let inf = ring_cost(100_000, bytes, 0.0, 25e9);
        assert!((inf - 2.0 * bytes / 25e9).abs() / inf < 1e-3);
    }

    #[test]
    fn ps_worse_than_ring_at_scale() {
        let bytes = 100e6;
        assert!(ps_cost(64, bytes, 5e-6, 12e9)
                > 5.0 * ring_cost(64, bytes, 5e-6, 12e9));
    }

    #[test]
    fn ring_sim_time_scales_with_slow_link() {
        // Multi-node ring must be slower than single-node NVLink ring.
        let len = 1 << 20;
        let hw1 = dgx1(4);
        let mut b1 = random_bufs(4, len, 2);
        let t1 = ring_allreduce(&mut b1, &hw1, &hw1.devices())
            .unwrap()
            .sim_time;
        let hw2 = multi_node(2, 2);
        let mut b2 = random_bufs(4, len, 2);
        let t2 = ring_allreduce(&mut b2, &hw2, &hw2.devices())
            .unwrap()
            .sim_time;
        assert!(t2 > t1, "inter-node {t2} must exceed NVLink {t1}");
    }

    #[test]
    fn uneven_chunks_handled() {
        // len not divisible by n.
        let hw = dgx1(4);
        let mut bufs = random_bufs(3, 10, 5);
        let want = expected_sum(&bufs);
        ring_allreduce(&mut bufs, &hw, &[0, 1, 2]).unwrap();
        for (i, &w) in want.iter().enumerate() {
            assert!((bufs[0][i] as f64 - w).abs() < 1e-4);
        }
    }
}
