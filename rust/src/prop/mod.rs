//! Minimal property-testing harness (proptest unavailable offline).
//!
//! `run_cases(n, seed, |g| ...)` executes `n` generated cases; on failure
//! the panic message includes the case seed so it can be replayed with
//! `replay(seed, ...)`.  Generators are methods on [`Gen`].

use crate::util::rng::Rng;

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() as f32 * scale).collect()
    }

    pub fn vec_f64(&mut self, len: usize, scale: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.normal() * scale).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `n` property cases derived from `seed`.  Panics (with the failing
/// case seed) on the first failure.
pub fn run_cases<F: FnMut(&mut Gen)>(n: usize, seed: u64, mut body: F) {
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || body(&mut g),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its seed.
pub fn replay<F: FnMut(&mut Gen)>(case_seed: u64, mut body: F) {
    let mut g = Gen { rng: Rng::new(case_seed), case_seed };
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        run_cases(50, 1, |_| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    fn generators_in_bounds() {
        run_cases(100, 2, |g| {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let len = g.usize_in(0, 10);
            let v = g.vec_f32(len, 1.0);
            assert!(v.len() <= 10);
        });
    }

    #[test]
    fn failure_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            run_cases(10, 3, |g| {
                let x = g.usize_in(0, 100);
                assert!(x < 101); // never fails
                if g.case_seed % 2 == 1 || true {
                    // Force a failure on case 0 deterministically:
                }
                panic!("boom");
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("replay seed"), "msg: {msg}");
    }

    #[test]
    fn replay_reproduces() {
        let mut first = None;
        run_cases(1, 7, |g| first = Some(g.rng.next_u64()));
        let seed = 7u64.wrapping_mul(0x9E3779B97F4A7C15);
        let mut again = None;
        replay(seed, |g| again = Some(g.rng.next_u64()));
        assert_eq!(first, again);
    }
}
