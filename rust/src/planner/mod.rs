//! Unified planner: one typed entry point for strategy search across the
//! model × topology × strategy space.
//!
//! The paper's core deliverable is a *decision procedure* — given a network
//! and a device budget, pick the DP/MP/hybrid configuration that minimises
//! end-to-end training time (Eq. 1: `C = T × S × E`).  Before this module,
//! that procedure lived as a dozen free functions that every entry point
//! re-wired by hand.  The planner is the façade:
//!
//! ```no_run
//! use hybridpar::planner::{PlanRequest, Planner};
//!
//! let planner = Planner::new();
//! let plan = planner
//!     .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
//!     .unwrap();
//! println!("{:?} — projected speedup {:.1}x", plan.strategy,
//!          plan.predicted_speedup);
//! println!("{}", plan.to_json()); // serialisable scorecard + curve
//! ```
//!
//! * [`PlanRequest`] — builder for the query (model, topology, device
//!   budget, objective, candidate MP degrees, batch override);
//! * [`Planner`] — holds a [`ModelRegistry`], a [`TopologyRegistry`] and a
//!   pluggable [`CostModel`]; [`Planner::plan`] runs the search;
//! * [`Plan`] — the typed answer: chosen [`Strategy`], predicted step
//!   time, epochs-to-converge, end-to-end speedup curve, placement /
//!   pipeline partition, per-candidate scorecard; round-trips through
//!   [`crate::util::json`].
//!
//! Every candidate is also checked against the per-device footprint model
//! of [`crate::memory`] (weights + gradients + optimizer state +
//! activations, GPipe stashing included): candidates that estimate but
//! overflow the device are marked
//! [`crate::memory::Feasibility::Infeasible`] in the scorecard instead
//! of being scored, `PlanRequest::device_mem_gb` overrides the
//! topology's capacity, and a memory-infeasible DP baseline drops out of
//! selection entirely — strategies chosen because DP *cannot fit*, not
//! just because hybrid is faster.  A degree whose estimation fails
//! outright (deeper than the topology, or no stage split under the raw
//! Eq. 13 cap) drops out of the search without a scorecard row, as
//! topology-infeasible degrees always have.
//!
//! The candidate space covers both of the paper's MP mechanisms *per
//! degree*: the Table 1 structural default (DLPlacer placement for branchy
//! graphs, GPipe pipeline for chains) and an explicit
//! [`Strategy::PipelinedHybrid`] pipeline for every graph — so the
//! pipelined ConvNet hybrids a placement-only search never sees compete on
//! equal footing.  For grid evaluation over many
//! `(model × topology × batch × strategy-family)` scenarios, use the
//! work-sharing parallel [`sweep`] engine instead of calling
//! [`Planner::plan`] in a loop.

pub mod cost;
pub mod registry;
pub mod sweep;
pub mod timeline;

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

pub use cost::{cost_by_name, AlphaBetaCost, AnalyticalCost, CostModel,
               MpEstimate, MpMechanism, SimulatorCost};
pub use registry::{ModelEntry, ModelRegistry, TopologyEntry,
                   TopologyRegistry};

use crate::collective::{best_allreduce_on, Algorithm, TopoProfile,
                        DEFAULT_ALPHA};
use crate::coordinator::Strategy;
use crate::layerwise::{self, LayerwiseOptions};
use crate::memory::{self, Feasibility, MemoryEstimate, MemoryModel};
use crate::parallel::overlap::OverlapModel;
use crate::parallel::NetworkModel;
use crate::util::json::Json;

/// What the planner optimises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimise projected time-to-converge (Eq. 1) — the paper's metric.
    TimeToConverge,
    /// Maximise per-step throughput, ignoring statistical efficiency.
    StepTime,
}

impl Objective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::TimeToConverge => "time-to-converge",
            Objective::StepTime => "step-time",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "time-to-converge" | "ttc" | "converge" => {
                Objective::TimeToConverge
            }
            "step-time" | "step" | "throughput" => Objective::StepTime,
            other => bail!("unknown objective '{other}' \
                            (known: time-to-converge, step-time)"),
        })
    }
}

/// Which search mechanism drives plan *selection*.
///
/// Under [`PlanMechanism::Auto`] the planner picks among the paper's
/// fixed candidates (DP / placed / pipelined) exactly as before — the
/// layer-wise rows are analysis material in the scorecard.  Under
/// [`PlanMechanism::Layerwise`] the per-op search
/// ([`crate::layerwise::solve`]) drives selection: the chosen strategy is
/// the best mixed assignment across the requested degrees.  Under
/// [`PlanMechanism::Tensor`] a Megatron-style intra-layer split drives
/// selection across the requested `tensor_degrees` (with DP workers
/// layered on top of each split).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMechanism {
    /// Fixed-candidate selection (the default; layer-wise rows are
    /// advisory).
    Auto,
    /// The layer-wise mixed assignment drives selection.
    Layerwise,
    /// A tensor-parallel intra-layer split drives selection.
    Tensor,
}

impl PlanMechanism {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanMechanism::Auto => "auto",
            PlanMechanism::Layerwise => "layerwise",
            PlanMechanism::Tensor => "tensor",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" | "fixed" => PlanMechanism::Auto,
            "layerwise" | "layer-wise" | "pase" => PlanMechanism::Layerwise,
            "tensor" | "tensor-parallel" | "tp" | "megatron" => {
                PlanMechanism::Tensor
            }
            other => bail!("unknown plan mechanism '{other}' \
                            (known: auto, layerwise, tensor)"),
        })
    }
}

/// A planner query, built fluently.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub model: String,
    pub topology: String,
    /// Device budget N (projections beyond the physical topology are
    /// allowed, as in the paper's 256-GPU sweeps from an 8-GPU box).
    pub devices: usize,
    /// Per-device mini-batch override (None = the registry default).
    pub batch: Option<usize>,
    pub objective: Objective,
    /// Candidate model-parallel widths M (> 1); DP-only (M = 1) is always
    /// considered.  Degrees other than 2 are analysed (scorecard + curve)
    /// but the chosen strategy is restricted to the runtime-executable
    /// M ∈ {1, 2} — the coordinator executes 2-stage pipelines.  A degree
    /// that is infeasible on the topology (more stages than ops or
    /// physical devices) drops out of the search rather than failing it.
    pub mp_degrees: Vec<usize>,
    /// Candidate tensor-parallel widths T (> 1): Megatron-style
    /// intra-layer splits where every op's compute divides by T and each
    /// op pays 4 activation all-reduces per step (2 forward + 2
    /// backward) over the T-rank group, priced through
    /// [`crate::collective::best_allreduce_on`] on the topology's
    /// profile.  Empty (the default) adds no tensor rows, keeping
    /// existing plans byte-identical.  Tensor rows are scorecard
    /// analysis under [`PlanMechanism::Auto`] unless no fixed candidate
    /// fits in memory, in which case a feasible tensor split rescues
    /// the plan instead of failing it.
    pub tensor_degrees: Vec<usize>,
    /// Restrict M > 1 candidates to the pipelined mechanism (skip the
    /// structural DLPlacer default).  This is the sweep engine's
    /// "pipelined" strategy family; the default `false` scores both
    /// mechanisms per degree and keeps the better one.
    pub pipeline_only: bool,
    /// Upper bound of the speedup-curve sweep (powers of two).
    pub curve_max_devices: usize,
    /// Per-device memory override in GB (None = the topology's own
    /// Mem(n)).  "What if these were 16 GB parts?" — the sweep engine's
    /// `device_mem_gb` axis.
    pub device_mem_gb: Option<f64>,
    /// Footprint accounting (optimizer state, activation stash,
    /// recompute) used to mark candidates
    /// [`crate::memory::Feasibility::Infeasible`].
    pub memory: MemoryModel,
    /// Chassis count for multi-node-capable topologies (`dgx1-pod`,
    /// `cloud-25gbe`, `multinode`): `Some(4)` on `dgx1-pod` builds the
    /// 4×8 system.  `None` (or 1) keeps the topology's own single-arg
    /// sizing.  Single-box topologies reject values > 1.
    pub nodes: Option<usize>,
    /// Pin the collective algorithm pricing DP/hybrid gradient exchange
    /// (`--collective ring|tree|hierarchical`); `None` lets the cost
    /// model pick the best feasible one per candidate
    /// ([`crate::collective::best_allreduce`]).
    pub collective: Option<Algorithm>,
    /// Which mechanism drives selection (`--mechanism layerwise` runs
    /// the per-op search; the default `auto` keeps fixed-candidate
    /// selection with layer-wise rows as scorecard analysis).
    pub mechanism: PlanMechanism,
    /// Bucket budget for comm/compute overlap of the gradient exchange
    /// (`--overlap-buckets`): the SE model hides each bucket's
    /// all-reduce under the remaining backward time and charges only
    /// the exposed tail ([`crate::parallel::overlap::overlapped_step`]).
    /// `1` (the default) is the paper's serial charge, bit-for-bit.
    pub overlap_buckets: usize,
    /// Gradient-compression factor in `(0, 1]` applied to the exchanged
    /// *bytes* before pricing (`--compression`); α latency terms are
    /// never scaled.  `1.0` (the default) is uncompressed.
    pub compression: f64,
    /// Attach a [`PlanExplain`] cost waterfall to the plan
    /// (`--explain`): per-candidate compute / MP-overhead / exchange
    /// decomposition whose components sum to the reported step time.
    /// Off by default so existing plan documents stay byte-identical.
    pub explain: bool,
}

impl PlanRequest {
    pub fn new(model: &str, topology: &str) -> Self {
        PlanRequest {
            model: model.to_string(),
            topology: topology.to_string(),
            devices: 8,
            batch: None,
            objective: Objective::TimeToConverge,
            mp_degrees: vec![2],
            tensor_degrees: vec![],
            pipeline_only: false,
            curve_max_devices: 256,
            device_mem_gb: None,
            memory: MemoryModel::default(),
            nodes: None,
            collective: None,
            mechanism: PlanMechanism::Auto,
            overlap_buckets: 1,
            compression: 1.0,
            explain: false,
        }
    }

    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n;
        self
    }

    pub fn batch(mut self, b: usize) -> Self {
        self.batch = Some(b);
        self
    }

    pub fn objective(mut self, o: Objective) -> Self {
        self.objective = o;
        self
    }

    pub fn mp_degrees(mut self, ms: &[usize]) -> Self {
        self.mp_degrees = ms.to_vec();
        self
    }

    /// Candidate tensor-parallel (intra-layer split) widths.
    pub fn tensor_degrees(mut self, ts: &[usize]) -> Self {
        self.tensor_degrees = ts.to_vec();
        self
    }

    pub fn pipeline_only(mut self, only: bool) -> Self {
        self.pipeline_only = only;
        self
    }

    pub fn curve_to(mut self, n: usize) -> Self {
        self.curve_max_devices = n;
        self
    }

    /// Override every device's memory capacity (GB).
    pub fn device_mem_gb(mut self, gb: f64) -> Self {
        self.device_mem_gb = Some(gb);
        self
    }

    /// Use a specific footprint accounting model.
    pub fn memory(mut self, m: MemoryModel) -> Self {
        self.memory = m;
        self
    }

    /// Build the topology as `n` chassis (multi-node entries only).
    pub fn nodes(mut self, n: usize) -> Self {
        self.nodes = Some(n);
        self
    }

    /// Pin the collective algorithm pricing gradient exchange.
    pub fn collective(mut self, a: Algorithm) -> Self {
        self.collective = Some(a);
        self
    }

    /// Let the layer-wise per-op search drive selection.
    pub fn mechanism(mut self, m: PlanMechanism) -> Self {
        self.mechanism = m;
        self
    }

    /// Allow up to `n` gradient buckets for comm/compute overlap.
    pub fn overlap_buckets(mut self, n: usize) -> Self {
        self.overlap_buckets = n;
        self
    }

    /// Compress exchanged gradient bytes by `factor` ∈ (0, 1].
    pub fn compression(mut self, factor: f64) -> Self {
        self.compression = factor;
        self
    }

    /// Attach the cost-waterfall explanation to the plan.
    pub fn explain(mut self, on: bool) -> Self {
        self.explain = on;
        self
    }

    /// The request's overlap axes as one [`OverlapModel`] (what
    /// [`Planner::plan`] validates and threads into the SE model).
    pub fn overlap_model(&self) -> OverlapModel {
        OverlapModel {
            buckets: self.overlap_buckets,
            compression: self.compression,
        }
    }

    /// Wire-format keys accepted by [`plan_request_from_json`] (the
    /// service's `POST /plan` body).  `"cost"` selects the cost model
    /// and is returned separately by the parser — it configures the
    /// [`Planner`], not the request.
    pub const WIRE_KEYS: [&'static str; 18] = [
        "model", "topology", "devices", "batch", "objective", "mp_degrees",
        "tensor_degrees", "pipeline_only", "curve_max_devices",
        "device_mem_gb", "memory", "nodes", "collective", "mechanism",
        "cost", "overlap", "compression", "explain",
    ];

    /// The cache-canonical form of this request: a sorted-key JSON
    /// object with every field fully defaulted, so request spellings
    /// that cannot change one byte of the plan share one cache entry.
    /// `cost_model` must be the *resolved* [`CostModel::name`] (so the
    /// `"sim"` alias and `"simulator"` share too).
    ///
    /// Collapses applied — each is provably output-invariant:
    /// * model aliases resolve to the canonical registry name
    ///   (`Plan.model` records the canonical name);
    /// * a `None` batch resolves to the registry default
    ///   (`Plan.mini_batch` records the resolved batch);
    /// * `mp_degrees` is sorted, deduplicated and filtered to `> 1` —
    ///   exactly what [`Planner::plan`] does before scoring;
    /// * `tensor_degrees` gets the same sort/dedup/filter treatment;
    /// * `recompute_overhead` normalises to the default when recompute
    ///   is off ([`MemoryModel::time_factor`] is 1.0 either way);
    /// * `overlap`/`compression` serialise their values outright
    ///   (defaults 1 / 1.0), so an explicit overlap-off spelling shares
    ///   the default's cache entry while any real overlap setting gets
    ///   its own — the service cache distinguishes overlap settings.
    ///
    /// NOT collapsed, because they echo verbatim into the plan JSON:
    /// the topology spelling (`Plan.topology`), `nodes` `None` vs
    /// `Some(1)` (`Plan.nodes`), and `device_mem_gb` `None` vs an
    /// explicit value equal to the topology's own capacity
    /// (`Plan.device_mem_gb`).
    pub fn canonical_json(&self, models: &ModelRegistry, cost_model: &str)
                          -> Json {
        let model = models
            .canonical_name(&self.model)
            .unwrap_or(&self.model)
            .to_string();
        let batch =
            self.batch.or_else(|| models.default_batch(&model).ok());
        let mut degrees: Vec<usize> = self
            .mp_degrees
            .iter()
            .copied()
            .filter(|&m| m > 1)
            .collect();
        degrees.sort_unstable();
        degrees.dedup();
        let mut tensor: Vec<usize> = self
            .tensor_degrees
            .iter()
            .copied()
            .filter(|&t| t > 1)
            .collect();
        tensor.sort_unstable();
        tensor.dedup();
        let memory = if self.memory.recompute {
            self.memory.clone()
        } else {
            MemoryModel {
                recompute_overhead: MemoryModel::default()
                    .recompute_overhead,
                ..self.memory.clone()
            }
        };
        jobj(vec![
            ("model", Json::Str(model)),
            ("topology", Json::Str(self.topology.clone())),
            ("devices", junum(self.devices)),
            ("batch", jounum(batch)),
            ("objective", Json::Str(self.objective.as_str().into())),
            ("mp_degrees",
             Json::Arr(degrees.into_iter().map(junum).collect())),
            ("tensor_degrees",
             Json::Arr(tensor.into_iter().map(junum).collect())),
            ("pipeline_only", Json::Bool(self.pipeline_only)),
            ("curve_max_devices", junum(self.curve_max_devices)),
            ("device_mem_gb", jonum(self.device_mem_gb)),
            ("memory", memory.to_json()),
            ("nodes", jounum(self.nodes)),
            ("collective",
             self.collective
                 .map(|a| Json::Str(a.as_str().into()))
                 .unwrap_or(Json::Null)),
            ("mechanism", Json::Str(self.mechanism.as_str().into())),
            ("cost", Json::Str(cost_model.to_string())),
            ("overlap", junum(self.overlap_buckets)),
            ("compression", jnum(self.compression)),
            ("explain", Json::Bool(self.explain)),
        ])
    }
}

/// Wire cap on device budgets: scale-out topologies materialise a
/// hardware graph proportional to the budget, and the service parses
/// attacker-chosen JSON — 64 Ki devices is far beyond any paper
/// projection (256) while keeping the largest buildable graph small.
/// The CLI and direct [`PlanRequest`] construction are uncapped.
pub const MAX_WIRE_DEVICES: usize = 64 * 1024;
/// Wire cap on chassis counts (pod builders allocate per chassis).
pub const MAX_WIRE_NODES: usize = 8 * 1024;
/// Wire cap on the remaining integer knobs (batch, curve bound, MP
/// degrees, sweep threads) — they drive arithmetic, not allocation, so
/// the cap is generous.
pub const MAX_WIRE_INT: usize = 1 << 20;

/// Strict wire integer: a JSON number that is a non-negative integer no
/// larger than `max`.  `2.5` and `-1` are errors, never truncated —
/// the wire parsers promise malformed input is rejected, not coerced.
/// Shared with [`sweep::SweepSpec::from_json`], the other wire surface.
pub(crate) fn wire_int(v: &Json, key: &str, max: usize) -> Result<usize> {
    let n = v.as_f64()?;
    if !n.is_finite() || n.fract() != 0.0 || n < 0.0 {
        bail!("{key} must be a non-negative integer, got {n}");
    }
    if n > max as f64 {
        bail!("{key} of {n} exceeds the wire cap of {max}");
    }
    Ok(n as usize)
}

/// Optional strict wire integer (`None`/`null` = absent).
fn opt_wire_int(j: &Json, key: &str, max: usize) -> Result<Option<usize>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(wire_int(v, key, max)?)),
    }
}

/// Parse the service wire format for a planner query: a JSON object with
/// any subset of [`PlanRequest::WIRE_KEYS`].  `model` is required; every
/// other key defaults exactly as [`PlanRequest::new`] and the `plan` CLI
/// default, so a minimal body and the bare CLI produce byte-identical
/// plans.  Returns the request plus the optional `"cost"` model name
/// (resolve it with [`cost_by_name`]).  Unknown keys are rejected so a
/// typo cannot silently fall back to a default; explicit `null` values
/// mean "default" throughout.  Integer fields are strict (no silent
/// truncation) and capped — see [`MAX_WIRE_DEVICES`] — because this
/// parser faces the network.
pub fn plan_request_from_json(j: &Json)
                              -> Result<(PlanRequest, Option<String>)> {
    for key in j.as_obj()?.keys() {
        if !PlanRequest::WIRE_KEYS.contains(&key.as_str()) {
            bail!("unknown plan request key '{key}' (known: {})",
                  PlanRequest::WIRE_KEYS.join(", "));
        }
    }
    let model = j.get("model")?.as_str()?;
    let topology = match j.opt("topology") {
        None | Some(Json::Null) => "dgx1",
        Some(v) => v.as_str()?,
    };
    let mut req = PlanRequest::new(model, topology);
    if let Some(n) = opt_wire_int(j, "devices", MAX_WIRE_DEVICES)? {
        req.devices = n;
    }
    req.batch = opt_wire_int(j, "batch", MAX_WIRE_INT)?;
    if let Some(o) = j.opt("objective").filter(|v| **v != Json::Null) {
        req.objective = Objective::parse(o.as_str()?)?;
    }
    if let Some(ms) = j.opt("mp_degrees").filter(|v| **v != Json::Null) {
        req.mp_degrees = ms
            .as_arr()?
            .iter()
            .map(|x| wire_int(x, "mp_degrees", MAX_WIRE_INT))
            .collect::<Result<_>>()?;
    }
    if let Some(ts) = j.opt("tensor_degrees").filter(|v| **v != Json::Null)
    {
        req.tensor_degrees = ts
            .as_arr()?
            .iter()
            .map(|x| wire_int(x, "tensor_degrees", MAX_WIRE_INT))
            .collect::<Result<_>>()?;
    }
    req.pipeline_only = match j.opt("pipeline_only") {
        None | Some(Json::Null) => false,
        Some(Json::Bool(b)) => *b,
        Some(other) => bail!("pipeline_only must be a bool, got {other:?}"),
    };
    if let Some(n) = opt_wire_int(j, "curve_max_devices", MAX_WIRE_INT)? {
        req.curve_max_devices = n;
    }
    req.device_mem_gb = opt_f64(j, "device_mem_gb")?;
    if let Some(m) = j.opt("memory").filter(|v| **v != Json::Null) {
        req.memory = MemoryModel::from_json(m)?;
    }
    req.nodes = opt_wire_int(j, "nodes", MAX_WIRE_NODES)?;
    req.collective = match j.opt("collective") {
        None | Some(Json::Null) => None,
        Some(v) => match v.as_str()? {
            "auto" => None,
            other => Some(Algorithm::parse(other)?),
        },
    };
    if let Some(m) = j.opt("mechanism").filter(|v| **v != Json::Null) {
        req.mechanism = PlanMechanism::parse(m.as_str()?)?;
    }
    if let Some(n) = opt_wire_int(j, "overlap", MAX_WIRE_INT)? {
        req.overlap_buckets = n;
    }
    if let Some(c) = opt_f64(j, "compression")? {
        req.compression = c;
    }
    req.explain = match j.opt("explain") {
        None | Some(Json::Null) => false,
        Some(Json::Bool(b)) => *b,
        Some(other) => bail!("explain must be a bool, got {other:?}"),
    };
    // Loud validation at the wire (the planner re-checks, but a typo'd
    // body should fail parse, not plan).
    req.overlap_model().validate()?;
    let cost = match j.opt("cost") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_str()?.to_string()),
    };
    Ok((req, cost))
}

/// One strategy candidate's score at the requested device budget.
///
/// A degree M > 1 can appear twice: once under its structural-default
/// mechanism and once as an explicit pipeline.  Rows are ordered best
/// first per degree, so `find(|c| c.mp_degree == m)` returns the candidate
/// that drives Eq. 5.
#[derive(Clone, Debug, PartialEq)]
pub struct CandidateScore {
    /// M (1 = DP-only).
    pub mp_degree: usize,
    /// SU^M — the M-way model-parallel step speedup of one worker under
    /// this row's mechanism.
    pub su_m: f64,
    /// N_dp = devices / M (0 when M does not divide the budget).
    pub dp_workers: usize,
    /// Emulated global batch N_dp × mini_batch.
    pub global_batch: usize,
    /// E(B) at that global batch (None = diverges).
    pub epochs: Option<f64>,
    /// Predicted per-step wall time including DP communication.
    pub step_time_s: Option<f64>,
    /// End-to-end speedup vs 1 device (Eq. 3/5; None = infeasible).
    pub speedup: Option<f64>,
    pub feasible: bool,
    /// "none" | "placed" | "pipelined" | "layerwise" | "tensor".
    pub mechanism: String,
    /// Searched micro-batch count when pipelined.
    pub microbatches: Option<usize>,
    /// The strategy shape of this candidate at the requested budget
    /// ([`Strategy::PipelinedHybrid`] for pipelined rows).  Only
    /// meaningful when `feasible`: infeasible rows (M does not divide the
    /// budget) carry `dp_workers`/`replicas` of 0, which
    /// [`crate::coordinator::Coordinator::train`] rejects with an error.
    pub strategy: Strategy,
    /// Peak per-device footprint of this candidate's worker layout.
    pub memory: Option<MemoryEstimate>,
    /// Whether that footprint fits the device — infeasible candidates
    /// stay visible in the scorecard with `{required, available}` instead
    /// of being scored.
    pub feasibility: Feasibility,
    /// Collective algorithm pricing this row's N_dp-way gradient exchange
    /// ("ring" | "tree" | "hierarchical"; "none" when N_dp ≤ 1, when M
    /// does not divide the budget, or under the SE = 1 analytical model
    /// where communication is free).
    pub collective: String,
    /// Exposed gradient-exchange tail this row's step actually pays
    /// (seconds) under the request's overlap model — equal to the full
    /// serial exchange when overlap is off, smaller when buckets hide
    /// part of it under backward compute.  `None` when nothing is
    /// exchanged (N_dp ≤ 1, M does not divide the budget) or the SE
    /// model prices no communication (analytical SE = 1).
    pub exchange_tail_s: Option<f64>,
    pub note: String,
}

/// One point of the end-to-end speedup curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurvePoint {
    pub devices: usize,
    /// DP-only speedup (None = E(B) diverges).
    pub dp: Option<f64>,
    /// Best hybrid speedup over the candidate M > 1 degrees.
    pub hybrid: Option<f64>,
}

/// One candidate's additive cost waterfall.
///
/// `compute_s` is the ideal M-way split of the serial step (recompute
/// inflation included); `mp_overhead_s` is what the mechanism actually
/// loses on top of that — GPipe fill/drain bubble, placement
/// communication; `exchange_s` is the DP gradient-exchange charge the
/// SE model prices (0 under Eq. 1–6's SE = 1).  The three sum to
/// `total_s`, the candidate's reported step time, *exactly* — the
/// decomposition is algebraic, not re-measured.
#[derive(Clone, Debug, PartialEq)]
pub struct ExplainRow {
    pub mp_degree: usize,
    /// "none" | "placed" | "pipelined" | "layerwise" | "tensor".
    pub mechanism: String,
    pub compute_s: f64,
    pub mp_overhead_s: f64,
    pub exchange_s: f64,
    /// `compute_s + mp_overhead_s + exchange_s` — the reported step time.
    pub total_s: f64,
    /// Algorithm pricing this row's exchange ("none" when free).
    pub collective: String,
}

impl ExplainRow {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("mp_degree", junum(self.mp_degree)),
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("compute_s", jnum(self.compute_s)),
            ("mp_overhead_s", jnum(self.mp_overhead_s)),
            ("exchange_s", jnum(self.exchange_s)),
            ("total_s", jnum(self.total_s)),
            ("collective", Json::Str(self.collective.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(ExplainRow {
            mp_degree: j.get("mp_degree")?.as_usize()?,
            mechanism: j.get("mechanism")?.as_str()?.to_string(),
            compute_s: j.get("compute_s")?.as_f64()?,
            mp_overhead_s: j.get("mp_overhead_s")?.as_f64()?,
            exchange_s: j.get("exchange_s")?.as_f64()?,
            total_s: j.get("total_s")?.as_f64()?,
            collective: j.get("collective")?.as_str()?.to_string(),
        })
    }
}

/// Why the plan chose what it chose: the chosen candidate's cost
/// waterfall plus one row per scored scorecard candidate, the
/// statistical-efficiency penalty, and the memory verdict.  Attached to
/// [`Plan::explain`] when [`PlanRequest::explain`] is set (`plan
/// --explain`); rendered as text by [`Plan::explain_text`].
#[derive(Clone, Debug, PartialEq)]
pub struct PlanExplain {
    /// Single-device serial step time (seconds, before recompute).
    pub serial_step_s: f64,
    /// Recompute inflation factor folded into every time below.
    pub time_factor: f64,
    /// SE_N(n_dp, M) of the chosen candidate (1.0 under Eq. 1–6).
    pub se: f64,
    /// The chosen candidate's waterfall; `chosen.total_s` equals
    /// [`Plan::predicted_step_s`].
    pub chosen: ExplainRow,
    /// One waterfall per scored (step-timed) scorecard row, scorecard
    /// order.
    pub candidates: Vec<ExplainRow>,
    /// Statistical-efficiency penalty E(B₁)/E(B) at the chosen global
    /// batch (None = divergent or unknown).
    pub epochs_ratio: Option<f64>,
    /// Memory verdict of the chosen candidate ("fits: … of … GB" /
    /// "infeasible: …" / "unknown").
    pub memory_verdict: String,
}

impl PlanExplain {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("serial_step_s", jnum(self.serial_step_s)),
            ("time_factor", jnum(self.time_factor)),
            ("se", jnum(self.se)),
            ("chosen", self.chosen.to_json()),
            ("candidates",
             Json::Arr(self.candidates
                 .iter()
                 .map(|r| r.to_json())
                 .collect())),
            ("epochs_ratio", jonum(self.epochs_ratio)),
            ("memory_verdict", Json::Str(self.memory_verdict.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(PlanExplain {
            serial_step_s: j.get("serial_step_s")?.as_f64()?,
            time_factor: j.get("time_factor")?.as_f64()?,
            se: j.get("se")?.as_f64()?,
            chosen: ExplainRow::from_json(j.get("chosen")?)?,
            candidates: j
                .get("candidates")?
                .as_arr()?
                .iter()
                .map(ExplainRow::from_json)
                .collect::<Result<Vec<_>>>()?,
            epochs_ratio: opt_f64(j, "epochs_ratio")?,
            memory_verdict: j
                .get("memory_verdict")?
                .as_str()?
                .to_string(),
        })
    }
}

/// The planner's typed answer.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub model: String,
    pub topology: String,
    pub device_budget: usize,
    /// Devices the chosen strategy actually uses (≤ budget: when every
    /// strategy diverges at the full budget the planner backs off, as the
    /// paper does for BigLSTM).
    pub devices_used: usize,
    pub mini_batch: usize,
    pub global_batch: usize,
    pub cost_model: String,
    pub objective: Objective,
    /// The chosen runtime strategy.
    pub strategy: Strategy,
    /// M of the chosen strategy (1 = DP-only).
    pub mp_degree: usize,
    pub dp_workers: usize,
    /// "none" | "placed" | "pipelined" | "layerwise" | "tensor".
    pub mechanism: String,
    pub microbatches: Option<usize>,
    /// Predicted per-step wall time of the chosen strategy (seconds).
    pub predicted_step_s: f64,
    /// Predicted epochs-to-converge at the chosen global batch.
    pub predicted_epochs: Option<f64>,
    /// Predicted end-to-end speedup vs 1 device (under
    /// [`Objective::StepTime`], the step-rate speedup instead).
    pub predicted_speedup: f64,
    /// Eq. 6 tipping point: device count where the first hybrid degree
    /// overtakes DP-only.
    pub crossover_devices: Option<usize>,
    /// Op → device assignment when the chosen MP mechanism is "placed".
    pub placement: Option<Vec<usize>>,
    /// Stage bounds when the chosen MP mechanism is "pipelined".
    pub pipeline_bounds: Option<Vec<usize>>,
    /// The request's per-device memory override, if any (GB).
    pub device_mem_gb: Option<f64>,
    /// Per-device Mem(n) the feasibility checks ran against (bytes).
    pub available_mem_bytes: f64,
    /// Optimizer family of the footprint model ("sgd" | "momentum" |
    /// "adam").
    pub optimizer: String,
    /// Whether gradient-checkpointing recompute was assumed.
    pub recompute: bool,
    /// Peak per-device footprint of the chosen strategy.
    pub memory: Option<MemoryEstimate>,
    /// The request's chassis count, if any (`--nodes`).
    pub nodes: Option<usize>,
    /// Collective algorithm pricing the chosen strategy's gradient
    /// exchange (see [`CandidateScore::collective`]).
    pub collective: String,
    /// The request's overlap bucket budget (1 = overlap off).
    pub overlap_buckets: usize,
    /// The request's gradient-compression factor (1.0 = off).
    pub compression: f64,
    /// Exposed exchange tail of the chosen strategy (see
    /// [`CandidateScore::exchange_tail_s`]).
    pub exchange_tail_s: Option<f64>,
    /// Cost-waterfall explanation, present only when the request set
    /// [`PlanRequest::explain`] — absent, the plan JSON is byte-identical
    /// to pre-explain documents.
    pub explain: Option<PlanExplain>,
    pub scorecard: Vec<CandidateScore>,
    pub curve: Vec<CurvePoint>,
}

/// The planner: registries + a pluggable cost model.
pub struct Planner {
    models: ModelRegistry,
    topologies: TopologyRegistry,
    cost: Box<dyn CostModel>,
}

impl Default for Planner {
    fn default() -> Self {
        Planner::new()
    }
}

impl Planner {
    /// Built-in registries, analytical (Eq. 1–6) cost model.
    pub fn new() -> Self {
        Planner::with_cost(Box::new(AnalyticalCost::default()))
    }

    /// Built-in registries, caller-chosen cost model.
    pub fn with_cost(cost: Box<dyn CostModel>) -> Self {
        Planner {
            models: ModelRegistry::builtin(),
            topologies: TopologyRegistry::builtin(),
            cost,
        }
    }

    /// Fully custom construction.
    pub fn with_parts(models: ModelRegistry, topologies: TopologyRegistry,
                      cost: Box<dyn CostModel>) -> Self {
        Planner { models, topologies, cost }
    }

    pub fn models(&self) -> &ModelRegistry {
        &self.models
    }

    pub fn models_mut(&mut self) -> &mut ModelRegistry {
        &mut self.models
    }

    pub fn topologies(&self) -> &TopologyRegistry {
        &self.topologies
    }

    pub fn topologies_mut(&mut self) -> &mut TopologyRegistry {
        &mut self.topologies
    }

    pub fn cost(&self) -> &dyn CostModel {
        self.cost.as_ref()
    }

    /// Run the strategy search: score DP-only (Eq. 3) against every
    /// requested hybrid degree (Eq. 5) — placed and pipelined mechanisms
    /// both — under the Eq. 1 time-to-converge objective, and return the
    /// typed [`Plan`].
    ///
    /// ```
    /// use hybridpar::planner::{PlanRequest, Planner};
    ///
    /// let planner = Planner::new(); // Eq. 1–6 analytical cost model
    /// let plan = planner
    ///     .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
    ///     .unwrap();
    /// assert_eq!(plan.mp_degree, 1, "DP-only wins at small scale (Eq. 6)");
    /// // Every M > 1 candidate was still scored — GNMT's chain DFG makes
    /// // them PipelinedHybrid candidates in the scorecard.
    /// assert!(plan.scorecard.iter().any(|c| c.mechanism == "pipelined"));
    /// ```
    pub fn plan(&self, req: &PlanRequest) -> Result<Plan> {
        if req.devices == 0 {
            bail!("device budget must be >= 1");
        }
        if req.nodes == Some(0) {
            bail!("node count must be >= 1");
        }
        req.overlap_model().validate()?;
        let prof = self.models.build(&req.model, req.batch)?;
        let mut hw = match req.nodes {
            Some(n) if n > 1 => {
                self.topologies.build_nodes(&req.topology, n, req.devices)?
            }
            _ => self.topologies.build(&req.topology, req.devices)?,
        };
        if let Some(gb) = req.device_mem_gb {
            if !gb.is_finite() || gb <= 0.0 {
                bail!("device memory override must be a positive finite \
                       GB figure, got {gb}");
            }
            hw.set_device_mem(gb * 1e9);
        }
        if !req.memory.act_factor.is_finite() || req.memory.act_factor <= 0.0
            || !req.memory.reserved_bytes.is_finite()
            || req.memory.reserved_bytes < 0.0
        {
            bail!("memory model knobs out of range: act_factor {} \
                   (want > 0), reserved_bytes {} (want >= 0)",
                  req.memory.act_factor, req.memory.reserved_bytes);
        }
        // Per-device Mem(n) every candidate's peak footprint must fit.
        let available = hw.min_device_mem();
        let mem_model = &req.memory;
        // Recompute trades footprint for one extra forward pass: it
        // inflates every worker's step time uniformly, so SU^M ratios are
        // unaffected and only reported step times carry the factor.
        let time_factor = mem_model.time_factor();

        // Candidate MP degrees: {1} ∪ requested (deduplicated, > 1).
        let mut degrees: Vec<usize> = req
            .mp_degrees
            .iter()
            .copied()
            .filter(|&m| m > 1)
            .collect();
        degrees.sort_unstable();
        degrees.dedup();

        // Per-degree worker estimates from the cost model.  Each M > 1 is
        // scored under its Table 1 structural default (placed / pipelined)
        // AND as an explicit GPipe pipeline over the topo linearisation;
        // the fastest *memory-feasible* one drives Eq. 5 and the
        // runner-up stays in the scorecard.  A degree with no feasible
        // mechanism keeps its fastest candidate visible as
        // `Infeasible{required, available}` instead of being scored.
        // `pipeline_only` requests skip the structural default.
        let serial_est = self.cost.mp_step_time(&prof, &hw, 1)?;
        let serial = serial_est.step_time_s;
        let serial_mem =
            self.cost.memory_estimate(&prof, &serial_est, mem_model)?;
        // DP replicas all hold the whole model, so M = 1 feasibility is
        // the single-device footprint — *unless* ZeRO sharding spreads
        // optimizer state / gradients / weights across the DP ranks, in
        // which case feasibility becomes N-dependent: the same model can
        // be infeasible on 8 devices and feasible on 64.
        let dp_mem =
            memory::zero_sharded(&serial_mem, mem_model, req.devices);
        let dp_fits = dp_mem.fits(available);

        struct Scored {
            est: MpEstimate,
            mem: MemoryEstimate,
            fits: bool,
        }
        let mut best_scored: BTreeMap<usize, Scored> = BTreeMap::new();
        let mut alt_scored: BTreeMap<usize, Scored> = BTreeMap::new();
        let mut mp_speedups: Vec<(usize, f64)> = Vec::new();
        // A degree whose estimation is infeasible on this topology (more
        // stages than ops or physical devices, or no stage split fits the
        // device memory) drops out of the search instead of failing the
        // plan — M > 1 candidates are analysis material, and the M = 1
        // baseline above still surfaces real cost model failures.
        for &m in &degrees {
            let default = if req.pipeline_only {
                None
            } else {
                self.cost.mp_step_time(&prof, &hw, m).ok()
            };
            // Candidate list in mechanism-preference order (structural
            // default first — ties keep it, as before the memory layer).
            let mut cands: Vec<MpEstimate> = Vec::new();
            let default_is_pipe = matches!(
                &default,
                Some(d) if d.mechanism == MpMechanism::Pipelined);
            if let Some(d) = default {
                cands.push(d);
            }
            if !default_is_pipe {
                if let Ok(p) =
                    self.cost.pipelined_mp_step_time(&prof, &hw, m)
                {
                    cands.push(p);
                }
            }
            if cands.is_empty() {
                continue;
            }
            let mut scored: Vec<Scored> = Vec::with_capacity(cands.len());
            // ZeRO shards each stage's state across the degree's DP
            // replicas (a no-op at the default `zero = off`).
            let zero_nd =
                if req.devices % m == 0 { req.devices / m } else { 1 };
            for est in cands {
                let mem =
                    self.cost.memory_estimate(&prof, &est, mem_model)?;
                let mem = memory::zero_sharded(&mem, mem_model, zero_nd);
                let fits = mem.fits(available);
                scored.push(Scored { est, mem, fits });
            }
            // Fastest feasible candidate wins (strictly-faster replaces,
            // so the structural default keeps ties); if nothing fits, the
            // fastest overall stays as the degree's infeasible row.
            let mut best_idx = 0usize;
            let mut best_key = (!scored[0].fits, scored[0].est.step_time_s);
            for (i, s) in scored.iter().enumerate().skip(1) {
                let key = (!s.fits, s.est.step_time_s);
                if key < best_key {
                    best_idx = i;
                    best_key = key;
                }
            }
            let best = scored.swap_remove(best_idx);
            if best.fits {
                mp_speedups.push((m, serial / best.est.step_time_s));
            }
            best_scored.insert(m, best);
            if let Some(a) = scored.pop() {
                alt_scored.insert(m, a);
            }
        }
        // --- layer-wise mixed candidates ---------------------------------
        // One per degree: the per-op configuration DP
        // ([`crate::layerwise::solve`]) priced with this cost model's own
        // Δ(k) parameters, surfaced as `mechanism = "layerwise"` scorecard
        // rows — and, under `--mechanism layerwise`, driving selection.
        // When the degree's best *fixed* candidate is faster than the
        // mixed assignment (deep GPipe micro-batch overlap is outside the
        // per-op configuration space), the layer-wise row honestly mirrors
        // that fixed candidate instead: the search can always fall back to
        // a fixed strategy, so its row is never worse than the fixed
        // family at the same degree.  `pipeline_only` requests restrict
        // the scorecard to pipelined rows, so advisory layer-wise rows are
        // suppressed unless the request pins the layer-wise mechanism.
        struct LwScored {
            step_time_s: f64,
            strategy: Strategy,
            mem: MemoryEstimate,
            microbatches: Option<usize>,
            note: String,
        }
        let mut lw_scored: BTreeMap<usize, LwScored> = BTreeMap::new();
        if req.mechanism == PlanMechanism::Layerwise || !req.pipeline_only {
            let (fps, launch) = self.cost.op_time_params();
            let lw_opts = LayerwiseOptions {
                flops_per_sec: fps,
                launch_overhead_s: launch,
                ..Default::default()
            };
            for &m in &degrees {
                let sol = match layerwise::solve(&prof.dfg, &hw, m,
                                                 &lw_opts) {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let nd =
                    if req.devices % m == 0 { req.devices / m } else { 0 };
                let fallback = best_scored
                    .get(&m)
                    .filter(|b| b.est.step_time_s < sol.step_time_s);
                let entry = match fallback {
                    Some(b) => {
                        let mb = b.est.microbatches.unwrap_or(2);
                        let strategy =
                            if b.est.mechanism == MpMechanism::Pipelined {
                                Strategy::PipelinedHybrid {
                                    stages: m,
                                    microbatches: mb,
                                    replicas: nd,
                                }
                            } else {
                                Strategy::Hybrid { dp_workers: nd,
                                                   microbatches: mb }
                            };
                        LwScored {
                            step_time_s: b.est.step_time_s,
                            strategy,
                            mem: b.mem,
                            microbatches: b.est.microbatches,
                            note: format!(
                                "layer-wise search fell back to the fixed \
                                 {} candidate (mixed assignment priced \
                                 {:.3} ms)",
                                b.est.mechanism.as_str(),
                                sol.step_time_s * 1e3),
                        }
                    }
                    None => LwScored {
                        step_time_s: sol.step_time_s,
                        mem: memory::layerwise(mem_model, &sol.per_device),
                        microbatches: None,
                        note: format!(
                            "{} per-op assignment at {} granularity",
                            if sol.mixed { "mixed" } else { "uniform" },
                            sol.granularity),
                        strategy: Strategy::LayerWise {
                            degree: m,
                            dp_workers: nd,
                            assignment: sol.assignment,
                        },
                    },
                };
                lw_scored.insert(m, entry);
            }
        }

        // --- tensor-parallel candidates ----------------------------------
        // One per requested degree T: a Megatron-style intra-layer split.
        // Every op's compute divides by T, and every op pays 4 activation
        // all-reduces per step (2 forward + 2 backward) over the T-rank
        // group — allreduce-per-layer instead of allreduce-per-step, so
        // the penalty grows with layer count while DP's gradient exchange
        // stays flat.  Priced through the same best_allreduce/TopoProfile
        // layer as the DP exchange, so a TP group spanning chassis costs
        // what the topology says.  The footprint combines the 1/T tensor
        // shard with ZeRO sharding across the DP ranks stacked on top.
        let mut tensor_degrees: Vec<usize> = req
            .tensor_degrees
            .iter()
            .copied()
            .filter(|&t| t > 1)
            .collect();
        tensor_degrees.sort_unstable();
        tensor_degrees.dedup();
        let mut tp_scored: BTreeMap<usize, LwScored> = BTreeMap::new();
        if !tensor_degrees.is_empty() {
            let tp_topo = TopoProfile::for_budget(&hw, req.devices);
            for &t in &tensor_degrees {
                if t > req.devices {
                    continue;
                }
                let allreduce_s: f64 = prof
                    .dfg
                    .ops
                    .iter()
                    .map(|op| {
                        4.0 * best_allreduce_on(t, op.out_bytes, &tp_topo,
                                                DEFAULT_ALPHA)
                            .cost_s
                    })
                    .sum();
                let step = serial / t as f64 + allreduce_s;
                let nd =
                    if req.devices % t == 0 { req.devices / t } else { 1 };
                let mem = memory::zero_sharded(
                    &memory::tensor_sharded(&prof, mem_model, t),
                    mem_model, nd);
                tp_scored.insert(t, LwScored {
                    step_time_s: step,
                    strategy: Strategy::TensorParallel { degree: t,
                                                         dp_workers: nd },
                    mem,
                    microbatches: None,
                    note: format!(
                        "Megatron {t}-way intra-layer split: 4 activation \
                         all-reduces x {} ops per step",
                        prof.dfg.n_ops()),
                });
            }
        }
        if req.mechanism == PlanMechanism::Tensor && tp_scored.is_empty() {
            bail!("--mechanism tensor needs at least one tensor-parallel \
                   degree > 1 (pass --tensor-degrees, e.g. \
                   --tensor-degrees 8)");
        }

        // Degrees whose best mechanism both estimated and fit in memory —
        // the ones Eq. 5 and the speedup curve may use.
        let feasible_degrees: Vec<usize> =
            mp_speedups.iter().map(|&(m, _)| m).collect();
        // SE_N sees the recompute-inflated compute time: the extra
        // forward overlaps nothing, so it (slightly) improves the
        // compute/communication ratio.  A `--collective` override pins
        // the algorithm the SE model prices with; the request's overlap
        // axes switch the charge from serial to bucketed-overlapped
        // (a no-op at the defaults and under SE models that price no
        // communication).  ZeRO sharding re-materialises the sharded
        // state on demand, so the exchange payload grows by
        // `allgather_volume_factor × weight bytes` per step (0 extra at
        // the default `zero = off` — the paper's pricing, bit-for-bit).
        let zero_extra =
            mem_model.zero.allgather_volume_factor() * prof.grad_bytes;
        let se_prof = if zero_extra > 0.0 {
            let mut p = prof.clone();
            p.grad_bytes += zero_extra;
            Some(p)
        } else {
            None
        };
        let se = self
            .cost
            .scaling(se_prof.as_ref().unwrap_or(&prof), &hw,
                     serial * time_factor, req.devices)
            .with_forced(req.collective)
            .with_overlap(req.overlap_model());
        let net = NetworkModel {
            name: prof.name.clone(),
            epochs: prof.epochs.clone(),
            mini_batch: prof.mini_batch,
            se,
            mp_speedups,
        };

        // Runtime-executable MP widths: the coordinator executes 2-stage
        // pipelines ([`Strategy::Hybrid`] / [`Strategy::PipelinedHybrid`]
        // with `stages == 2`), so only M ∈ {1, 2} maps onto a runnable
        // strategy.  Wider requested degrees still appear in the scorecard
        // and speedup curve for analysis, but the *chosen* strategy is
        // restricted to what the runtime can execute — and to what fits
        // in device memory: a memory-infeasible M = 1 drops DP-only from
        // the selection entirely (the "hybrid because DP cannot fit"
        // regime the paper's projections could not express).
        let exec_net = NetworkModel {
            mp_speedups: net
                .mp_speedups
                .iter()
                .copied()
                .filter(|&(m, _)| m == 2)
                .collect(),
            ..net.clone()
        };
        let mut exec_ms: Vec<usize> = Vec::new();
        if dp_fits {
            exec_ms.push(1);
        }
        exec_ms.extend(exec_net.mp_speedups.iter().map(|&(m, _)| m));

        // Best feasible tensor-parallel candidate at a given device
        // budget, scored by the same objective math as the fixed family.
        // Footprints are re-derived per budget because the ZeRO shard
        // count (the DP width) changes with it.
        let tp_best_at = |budget: usize| {
            let mut best: Option<(usize, usize, f64)> = None;
            for (&t, tp) in &tp_scored {
                if budget % t != 0 {
                    continue;
                }
                let nd = budget / t;
                let mem = memory::zero_sharded(
                    &memory::tensor_sharded(&prof, mem_model, t),
                    mem_model, nd);
                if !mem.fits(available) {
                    continue;
                }
                let su_m = serial / tp.step_time_s;
                let score = match req.objective {
                    Objective::TimeToConverge => match net
                        .epochs
                        .efficiency_ratio((nd * prof.mini_batch) as f64)
                    {
                        Some(r) => {
                            su_m * net.se.at_mp(nd, t) * nd as f64 * r
                        }
                        None => continue,
                    },
                    Objective::StepTime => {
                        su_m * nd as f64 * net.se.at_mp(nd, t)
                    }
                };
                if best.map_or(true, |(_, _, b)| score > b) {
                    best = Some((t, budget, score));
                }
            }
            best
        };
        let tp_search = |start: usize| {
            let mut found = tp_best_at(start);
            let mut budget = start / 2;
            while found.is_none() && budget >= 2 {
                found = tp_best_at(budget);
                budget /= 2;
            }
            found
        };
        // Under `--mechanism tensor` the intra-layer split drives
        // selection outright.  Under `auto`, a feasible tensor split
        // steps in only when *no* fixed candidate fits in memory — the
        // 70B-at-80-GB regime where TP × ZeRO is the difference between
        // a plan and an error.
        let tp_chosen: Option<(usize, usize, f64)> = match req.mechanism {
            PlanMechanism::Tensor => {
                Some(tp_search(req.devices).ok_or_else(|| anyhow!(
                    "no tensor-parallel candidate is feasible for '{}' at \
                     {} devices (requested degrees {:?} must divide the \
                     budget, fit {:.1} GB per device, and converge; \
                     consider ZeRO sharding, e.g. --zero weights)",
                    prof.name, req.devices, tensor_degrees,
                    available / 1e9))?)
            }
            PlanMechanism::Auto if exec_ms.is_empty() => {
                tp_search(req.devices)
            }
            _ => None,
        };
        if exec_ms.is_empty() && tp_chosen.is_none()
            && req.mechanism == PlanMechanism::Auto
        {
            bail!(
                "no runtime-executable strategy fits in {:.1} GB per \
                 device for '{}' (DP-only needs {:.1} GB){}",
                available / 1e9, prof.name, dp_mem.total_bytes / 1e9,
                if mem_model.recompute {
                    ""
                } else {
                    "; consider recompute, a smaller batch, tensor \
                     parallelism with ZeRO sharding (--tensor-degrees 8 \
                     --zero weights), or a larger device"
                });
        }

        // --- selection ---------------------------------------------------
        // Under `--mechanism layerwise` the per-op search drives
        // selection: the best feasible layer-wise candidate across the
        // requested degrees wins, scored by the same objective math as
        // the fixed family.  Layer-wise strategies are planner/sweep
        // projections (the coordinator executes fixed strategies only),
        // so the runtime M ∈ {1, 2} restriction does not apply.
        let lw_chosen: Option<(usize, usize, f64)> =
            if req.mechanism == PlanMechanism::Layerwise {
                let lw_best_at = |budget: usize| {
                    let mut best: Option<(usize, usize, f64)> = None;
                    for (&m, lw) in &lw_scored {
                        if budget % m != 0 || !lw.mem.fits(available) {
                            continue;
                        }
                        let nd = budget / m;
                        let su_m = serial / lw.step_time_s;
                        let score = match req.objective {
                            Objective::TimeToConverge => match net
                                .epochs
                                .efficiency_ratio(
                                    (nd * prof.mini_batch) as f64)
                            {
                                Some(r) => {
                                    su_m * net.se.at_mp(nd, m)
                                        * nd as f64 * r
                                }
                                None => continue,
                            },
                            Objective::StepTime => {
                                su_m * nd as f64 * net.se.at_mp(nd, m)
                            }
                        };
                        if best.map_or(true, |(_, _, b)| score > b) {
                            best = Some((m, budget, score));
                        }
                    }
                    best
                };
                // Same divergence back-off as the fixed family: halve
                // the budget until some degree converges (the BigLSTM
                // regime — the best configuration uses fewer devices
                // than are available).
                let mut found = lw_best_at(req.devices);
                let mut budget = req.devices / 2;
                while found.is_none() && budget >= 2 {
                    found = lw_best_at(budget);
                    budget /= 2;
                }
                Some(found.ok_or_else(|| anyhow!(
                    "no layer-wise candidate is feasible for '{}' at {} \
                     devices (requested degrees {:?} must divide the \
                     budget, fit {:.1} GB per device, and converge)",
                    prof.name, req.devices, degrees, available / 1e9))?)
            } else {
                None
            };

        let (chosen_m, devices_used, chosen_score) =
            match tp_chosen.or(lw_chosen) {
            Some((m, d, score)) => (m, d, score),
            None => match req.objective {
                Objective::TimeToConverge => {
                    match Self::best_among(&exec_net, &exec_ms,
                                           req.devices) {
                        Some((m, su)) => (m, req.devices, su),
                        None => self
                            .back_off(&exec_net, &exec_ms, req.devices)
                            .ok_or_else(|| anyhow!(
                                "no strategy converges for '{}' at any \
                                 device count <= {}",
                                prof.name, req.devices))?,
                    }
                }
                Objective::StepTime => {
                    // Step-rate score: SU^M × N_dp × SE(N_dp), no E(B)
                    // term.
                    let mut best: Option<(usize, usize, f64)> = None;
                    for &m in &exec_ms {
                        if req.devices % m != 0 {
                            continue;
                        }
                        let n_dp = req.devices / m;
                        let su_m = net.su_m(m).unwrap_or(1.0);
                        let score =
                            su_m * n_dp as f64 * net.se.at_mp(n_dp, m);
                        if best.map_or(true, |(_, _, b)| score > b) {
                            best = Some((m, req.devices, score));
                        }
                    }
                    best.ok_or_else(|| anyhow!("no feasible strategy"))?
                }
            },
        };
        let n_dp = devices_used / chosen_m.max(1);
        let global_batch = n_dp * prof.mini_batch;
        // The chosen candidate's artifacts: tensor-parallel and
        // layer-wise winners carry their own step time, footprint and
        // strategy; fixed winners keep the cost-model estimate's.
        let tp_row = if tp_chosen.is_some() {
            tp_scored.get(&chosen_m)
        } else {
            None
        };
        let lw_row = if lw_chosen.is_some() && tp_row.is_none() {
            lw_scored.get(&chosen_m)
        } else {
            None
        };
        let chosen_su_m = match (tp_row, lw_row) {
            (Some(tp), _) => serial / tp.step_time_s,
            (None, Some(lw)) => serial / lw.step_time_s,
            (None, None) => net.su_m(chosen_m).unwrap_or(1.0),
        };
        let step_worker = serial * time_factor / chosen_su_m;
        let predicted_step_s =
            step_worker / net.se.at_mp(n_dp, chosen_m).max(1e-12);
        let predicted_epochs = net.epochs.epochs(global_batch as f64);

        let chosen_est = if lw_row.is_some() || tp_row.is_some() {
            None
        } else {
            best_scored.get(&chosen_m).map(|s| &s.est)
        };
        let chosen_mem = if tp_row.is_some() {
            // Re-derive at the devices actually used: a backed-off
            // budget changes the ZeRO shard count.
            Some(memory::zero_sharded(
                &memory::tensor_sharded(&prof, mem_model, chosen_m),
                mem_model, n_dp))
        } else {
            match lw_row {
                Some(lw) => Some(lw.mem),
                None if chosen_m == 1 => Some(memory::zero_sharded(
                    &serial_mem, mem_model, n_dp)),
                None => best_scored.get(&chosen_m).map(|s| s.mem),
            }
        };
        let mechanism_str = if tp_row.is_some() {
            "tensor".to_string()
        } else {
            match lw_row {
                Some(_) => "layerwise".to_string(),
                None => chosen_est
                    .map(|e| e.mechanism)
                    .unwrap_or(MpMechanism::None)
                    .as_str()
                    .to_string(),
            }
        };
        let strategy = if tp_row.is_some() {
            Strategy::TensorParallel { degree: chosen_m,
                                       dp_workers: n_dp }
        } else if let Some(lw) = lw_row {
            // Scorecard rows price the full budget; a backed-off plan
            // re-derives the DP width from the devices actually used.
            let mut s = lw.strategy.clone();
            match &mut s {
                Strategy::LayerWise { dp_workers, .. } => *dp_workers = n_dp,
                Strategy::Hybrid { dp_workers, .. } => *dp_workers = n_dp,
                Strategy::PipelinedHybrid { replicas, .. } => {
                    *replicas = n_dp;
                }
                _ => {}
            }
            s
        } else if devices_used == 1 {
            Strategy::Single
        } else if chosen_m <= 1 {
            Strategy::DataParallel { workers: devices_used,
                                     delayed_factor: 1 }
        } else {
            // Pipelined estimates carry their searched micro-batch count;
            // placed (DLPlacer) estimates don't, and a 1-micro-batch
            // runtime pipeline is degenerate — default to 2.
            let microbatches =
                chosen_est.and_then(|e| e.microbatches).unwrap_or(2);
            if chosen_est.map(|e| e.mechanism)
                == Some(MpMechanism::Pipelined)
            {
                Strategy::PipelinedHybrid {
                    stages: chosen_m,
                    microbatches,
                    replicas: n_dp,
                }
            } else {
                Strategy::Hybrid { dp_workers: n_dp, microbatches }
            }
        };
        let chosen_microbatches = match lw_row {
            Some(lw) => lw.microbatches,
            None => chosen_est.and_then(|e| e.microbatches),
        };

        // --- scorecard ---------------------------------------------------
        // One row per (degree, mechanism): best mechanism first per degree
        // (it is the one Eq. 5 used), the runner-up after it for analysis.
        // Memory-infeasible rows stay visible — su_m and footprint filled
        // in, speedup withheld, the overflow recorded in
        // `feasibility`/`note`.
        let mut scorecard = Vec::new();
        let mut push_row = |m: usize, su_row: f64,
                            est: Option<&MpEstimate>,
                            mem: Option<&MemoryEstimate>,
                            lw: Option<(&LwScored, &'static str)>| {
            let feasibility = mem
                .map(|e| Feasibility::check(e, available))
                .unwrap_or(Feasibility::Feasible);
            let fits = feasibility.is_feasible();
            let divides = req.devices % m == 0;
            let nd = if divides { req.devices / m } else { 0 };
            let b = nd * prof.mini_batch;
            let epochs =
                if divides { net.epochs.epochs(b as f64) } else { None };
            let speedup = if !divides || !fits {
                None
            } else if m == 1 {
                net.su_dp(req.devices)
            } else {
                // Eq. 5 with this row's own SU^M (the runner-up mechanism
                // scores lower than `net.su_hybrid` by construction).
                net.epochs
                    .efficiency_ratio(b as f64)
                    .map(|r| su_row * net.se.at_mp(nd, m) * nd as f64 * r)
            };
            let step_time_s = if divides && fits {
                Some((serial * time_factor / su_row)
                     / net.se.at_mp(nd, m).max(1e-12))
            } else {
                None
            };
            let row_mechanism =
                est.map(|e| e.mechanism).unwrap_or(MpMechanism::None);
            let mechanism_label = match lw {
                Some((_, label)) => label.to_string(),
                None => row_mechanism.as_str().to_string(),
            };
            let microbatches = match lw {
                Some((l, _)) => l.microbatches,
                None => est.and_then(|e| e.microbatches),
            };
            // Algorithm pricing this row's N_dp-way exchange of M-wide
            // ranks ("none" when nothing is exchanged or communication
            // is free).
            let collective = if divides && nd > 1 {
                net.se
                    .collective_algorithm_mp(nd, m)
                    .map(|a| a.as_str().to_string())
                    .unwrap_or_else(|| "none".into())
            } else {
                "none".to_string()
            };
            // Exposed exchange tail under the request's overlap model
            // (None when nothing is exchanged or communication is free).
            let exchange_tail_s = if divides && nd > 1 {
                net.se.exchange_breakdown_mp(nd, m).map(|b| b.tail_s)
            } else {
                None
            };
            let strategy = if let Some((l, _)) = lw {
                l.strategy.clone()
            } else if m == 1 {
                if req.devices == 1 {
                    Strategy::Single
                } else {
                    Strategy::DataParallel { workers: req.devices,
                                             delayed_factor: 1 }
                }
            } else if row_mechanism == MpMechanism::Pipelined {
                Strategy::PipelinedHybrid {
                    stages: m,
                    microbatches: microbatches.unwrap_or(2),
                    replicas: nd,
                }
            } else {
                Strategy::Hybrid { dp_workers: nd,
                                   microbatches: microbatches.unwrap_or(2) }
            };
            let note = if !fits {
                format!(
                    "infeasible: needs {:.1} GB > {:.1} GB per device",
                    mem.map_or(0.0, |e| e.total_bytes) / 1e9,
                    available / 1e9)
            } else if !divides {
                format!("M={m} does not divide the {}-device budget",
                        req.devices)
            } else if epochs.is_none() {
                format!("E(B) diverges at global batch {b}")
            } else if let Some((l, _)) = lw {
                l.note.clone()
            } else {
                String::new()
            };
            scorecard.push(CandidateScore {
                mp_degree: m,
                su_m: su_row,
                dp_workers: nd,
                global_batch: b,
                epochs,
                step_time_s,
                speedup,
                feasible: speedup.is_some(),
                mechanism: mechanism_label,
                microbatches,
                strategy,
                memory: mem.copied(),
                feasibility,
                collective,
                exchange_tail_s,
                note,
            });
        };
        push_row(1, 1.0, None, Some(&dp_mem), None);
        let row_ms: BTreeSet<usize> = best_scored
            .keys()
            .chain(lw_scored.keys())
            .chain(tp_scored.keys())
            .copied()
            .collect();
        for &m in &row_ms {
            if let Some(best) = best_scored.get(&m) {
                push_row(m, serial / best.est.step_time_s, Some(&best.est),
                         Some(&best.mem), None);
                if let Some(alt) = alt_scored.get(&m) {
                    push_row(m, serial / alt.est.step_time_s,
                             Some(&alt.est), Some(&alt.mem), None);
                }
            }
            if let Some(lw) = lw_scored.get(&m) {
                push_row(m, serial / lw.step_time_s, None, Some(&lw.mem),
                         Some((lw, "layerwise")));
            }
            if let Some(tp) = tp_scored.get(&m) {
                push_row(m, serial / tp.step_time_s, None, Some(&tp.mem),
                         Some((tp, "tensor")));
            }
        }

        // --- end-to-end speedup curve ------------------------------------
        // Memory-infeasible strategies contribute no curve points: a DP
        // that cannot fit shows as a missing DP line, exactly the "hybrid
        // because DP cannot fit" scenario family.
        let mut curve = Vec::new();
        let mut n = 1usize;
        while n <= req.curve_max_devices {
            let hybrid = feasible_degrees
                .iter()
                .filter_map(|&m| net.su_hybrid(n, m))
                .fold(None::<f64>, |acc, v| {
                    Some(acc.map_or(v, |a| a.max(v)))
                });
            let dp = if dp_fits { net.su_dp(n) } else { None };
            curve.push(CurvePoint { devices: n, dp, hybrid });
            n *= 2;
        }
        let crossover_devices = feasible_degrees
            .first()
            .and_then(|&m| net.crossover_point(m, req.curve_max_devices));

        // --- explain waterfall (opt-in) ----------------------------------
        // Algebraic decomposition of each candidate's reported step time:
        //   compute   = serial × tf / M          (ideal M-way split)
        //   mp over.  = serial × tf / SU^M − compute   (bubble/placement)
        //   exchange  = step − serial × tf / SU^M      (SE charge)
        // The three sum to the reported step time exactly — the renderer
        // never re-measures, so `--explain` cannot drift from the plan.
        let chosen_collective = if n_dp > 1 {
            net.se
                .collective_algorithm_mp(n_dp, chosen_m)
                .map(|a| a.as_str().to_string())
                .unwrap_or_else(|| "none".into())
        } else {
            "none".to_string()
        };
        let explain = if req.explain {
            let row = |m: usize, mech: &str, su: f64, total: f64,
                       collective: &str| {
                let worker = serial * time_factor / su;
                let compute = serial * time_factor / m.max(1) as f64;
                ExplainRow {
                    mp_degree: m,
                    mechanism: mech.to_string(),
                    compute_s: compute,
                    mp_overhead_s: worker - compute,
                    exchange_s: total - worker,
                    total_s: total,
                    collective: collective.to_string(),
                }
            };
            let chosen_row = row(chosen_m, &mechanism_str, chosen_su_m,
                                 predicted_step_s, &chosen_collective);
            let candidates = scorecard
                .iter()
                .filter_map(|c| {
                    c.step_time_s.map(|t| row(c.mp_degree, &c.mechanism,
                                              c.su_m, t, &c.collective))
                })
                .collect();
            let memory_verdict = match &chosen_mem {
                Some(m) if m.fits(available) => format!(
                    "fits: {:.1} GB of {:.1} GB per device",
                    m.total_bytes / 1e9, available / 1e9),
                Some(m) => format!(
                    "infeasible: needs {:.1} GB > {:.1} GB per device",
                    m.total_bytes / 1e9, available / 1e9),
                None => "unknown".to_string(),
            };
            Some(PlanExplain {
                serial_step_s: serial,
                time_factor,
                se: net.se.at_mp(n_dp, chosen_m),
                chosen: chosen_row,
                candidates,
                epochs_ratio: net
                    .epochs
                    .efficiency_ratio(global_batch as f64),
                memory_verdict,
            })
        } else {
            None
        };

        Ok(Plan {
            model: prof.name.clone(),
            topology: req.topology.clone(),
            device_budget: req.devices,
            devices_used,
            mini_batch: prof.mini_batch,
            global_batch,
            cost_model: self.cost.name().to_string(),
            objective: req.objective,
            strategy,
            mp_degree: chosen_m,
            dp_workers: n_dp,
            mechanism: mechanism_str,
            microbatches: chosen_microbatches,
            predicted_step_s,
            predicted_epochs,
            predicted_speedup: chosen_score,
            crossover_devices,
            placement: chosen_est.and_then(|e| e.placement.clone()),
            pipeline_bounds: chosen_est
                .and_then(|e| e.pipeline_bounds.clone()),
            device_mem_gb: req.device_mem_gb,
            available_mem_bytes: available,
            optimizer: mem_model.optimizer.as_str().to_string(),
            recompute: mem_model.recompute,
            memory: chosen_mem,
            nodes: req.nodes,
            collective: chosen_collective,
            overlap_buckets: req.overlap_buckets,
            compression: req.compression,
            exchange_tail_s: if n_dp > 1 {
                net.se
                    .exchange_breakdown_mp(n_dp, chosen_m)
                    .map(|b| b.tail_s)
            } else {
                None
            },
            explain,
            scorecard,
            curve,
        })
    }

    /// Best Eq. 3/5 score at `total` devices over the given MP widths
    /// (`m == 1` is DP-only).  Identical to
    /// [`NetworkModel::best_strategy`] except the candidate set is
    /// explicit, so memory-infeasible widths (including DP itself) can be
    /// excluded from selection.
    fn best_among(net: &NetworkModel, ms: &[usize], total: usize)
                  -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for &m in ms {
            let su = if m == 1 {
                net.su_dp(total)
            } else {
                net.su_hybrid(total, m)
            };
            if let Some(su) = su {
                if best.map_or(true, |(_, b)| su > b) {
                    best = Some((m, su));
                }
            }
        }
        best
    }

    /// When every strategy diverges at the full budget, halve the device
    /// count until something converges (the paper's BigLSTM regime, where
    /// the best configuration uses fewer devices than are available).
    /// Only the memory-feasible widths in `ms` are considered.
    fn back_off(&self, net: &NetworkModel, ms: &[usize], budget: usize)
                -> Option<(usize, usize, f64)> {
        let mut n = budget / 2;
        while n >= 1 {
            if let Some((m, su)) = Self::best_among(net, ms, n) {
                return Some((m, n, su));
            }
            n /= 2;
        }
        None
    }
}

// ==========================================================================
// JSON (de)serialisation via util::json
// ==========================================================================

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

fn junum(x: usize) -> Json {
    Json::Num(x as f64)
}

fn jonum(x: Option<f64>) -> Json {
    x.map(Json::Num).unwrap_or(Json::Null)
}

fn jounum(x: Option<usize>) -> Json {
    x.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null)
}

pub(crate) fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(v.as_f64()?)),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    Ok(opt_f64(j, key)?.map(|v| v as usize))
}

fn opt_usize_arr(j: &Json, key: &str) -> Result<Option<Vec<usize>>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

/// Serialise a [`Strategy`] to a tagged JSON object (the tag is
/// [`Strategy::kind`], shared with the sweep CSV).
pub fn strategy_to_json(s: &Strategy) -> Json {
    let kind = Json::Str(s.kind().into());
    match s {
        Strategy::Single => jobj(vec![("kind", kind)]),
        Strategy::DataParallel { workers, delayed_factor } => jobj(vec![
            ("kind", kind),
            ("workers", junum(*workers)),
            ("delayed_factor", junum(*delayed_factor)),
        ]),
        Strategy::Hybrid { dp_workers, microbatches } => jobj(vec![
            ("kind", kind),
            ("dp_workers", junum(*dp_workers)),
            ("microbatches", junum(*microbatches)),
        ]),
        Strategy::PipelinedHybrid { stages, microbatches, replicas } => {
            jobj(vec![
                ("kind", kind),
                ("stages", junum(*stages)),
                ("microbatches", junum(*microbatches)),
                ("replicas", junum(*replicas)),
            ])
        }
        Strategy::AsyncPs { workers, staleness } => jobj(vec![
            ("kind", kind),
            ("workers", junum(*workers)),
            ("staleness", junum(*staleness)),
        ]),
        Strategy::LocalSgd { workers, sync_every } => jobj(vec![
            ("kind", kind),
            ("workers", junum(*workers)),
            ("sync_every", junum(*sync_every)),
        ]),
        Strategy::TensorParallel { degree, dp_workers } => jobj(vec![
            ("kind", kind),
            ("degree", junum(*degree)),
            ("dp_workers", junum(*dp_workers)),
        ]),
        Strategy::LayerWise { degree, dp_workers, assignment } => {
            jobj(vec![
                ("kind", kind),
                ("degree", junum(*degree)),
                ("dp_workers", junum(*dp_workers)),
                ("assignment",
                 Json::Arr(assignment
                     .iter()
                     .map(|(op, cfg)| Json::Arr(vec![
                         Json::Str(op.clone()),
                         Json::Str(cfg.clone()),
                     ]))
                     .collect())),
            ])
        }
    }
}

/// Parse a [`Strategy`] from its tagged JSON object.
pub fn strategy_from_json(j: &Json) -> Result<Strategy> {
    let kind = j.get("kind")?.as_str()?;
    Ok(match kind {
        "single" => Strategy::Single,
        "data-parallel" => Strategy::DataParallel {
            workers: j.get("workers")?.as_usize()?,
            delayed_factor: j.get("delayed_factor")?.as_usize()?,
        },
        "hybrid" => Strategy::Hybrid {
            dp_workers: j.get("dp_workers")?.as_usize()?,
            microbatches: j.get("microbatches")?.as_usize()?,
        },
        "pipelined-hybrid" => Strategy::PipelinedHybrid {
            stages: j.get("stages")?.as_usize()?,
            microbatches: j.get("microbatches")?.as_usize()?,
            replicas: j.get("replicas")?.as_usize()?,
        },
        "async-ps" => Strategy::AsyncPs {
            workers: j.get("workers")?.as_usize()?,
            staleness: j.get("staleness")?.as_usize()?,
        },
        "local-sgd" => Strategy::LocalSgd {
            workers: j.get("workers")?.as_usize()?,
            sync_every: j.get("sync_every")?.as_usize()?,
        },
        "tensor-parallel" => Strategy::TensorParallel {
            degree: j.get("degree")?.as_usize()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
        },
        "layerwise" => Strategy::LayerWise {
            degree: j.get("degree")?.as_usize()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
            assignment: j
                .get("assignment")?
                .as_arr()?
                .iter()
                .map(|pair| {
                    let p = pair.as_arr()?;
                    if p.len() != 2 {
                        bail!("assignment entries are [op, config] pairs, \
                               got {} elements", p.len());
                    }
                    Ok((p[0].as_str()?.to_string(),
                        p[1].as_str()?.to_string()))
                })
                .collect::<Result<Vec<_>>>()?,
        },
        other => bail!("unknown strategy kind '{other}'"),
    })
}

impl CandidateScore {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("mp_degree", junum(self.mp_degree)),
            ("su_m", jnum(self.su_m)),
            ("dp_workers", junum(self.dp_workers)),
            ("global_batch", junum(self.global_batch)),
            ("epochs", jonum(self.epochs)),
            ("step_time_s", jonum(self.step_time_s)),
            ("speedup", jonum(self.speedup)),
            ("feasible", Json::Bool(self.feasible)),
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("microbatches", jounum(self.microbatches)),
            ("strategy", strategy_to_json(&self.strategy)),
            ("memory",
             self.memory
                 .as_ref()
                 .map(|m| m.to_json())
                 .unwrap_or(Json::Null)),
            ("feasibility", self.feasibility.to_json()),
            ("collective", Json::Str(self.collective.clone())),
            ("exchange_tail_s", jonum(self.exchange_tail_s)),
            ("note", Json::Str(self.note.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let memory = match j.opt("memory") {
            None | Some(Json::Null) => None,
            Some(v) => Some(MemoryEstimate::from_json(v)?),
        };
        let feasibility = match j.opt("feasibility") {
            None | Some(Json::Null) => Feasibility::Feasible,
            Some(v) => Feasibility::from_json(v)?,
        };
        Ok(CandidateScore {
            mp_degree: j.get("mp_degree")?.as_usize()?,
            su_m: j.get("su_m")?.as_f64()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            epochs: opt_f64(j, "epochs")?,
            step_time_s: opt_f64(j, "step_time_s")?,
            speedup: opt_f64(j, "speedup")?,
            feasible: matches!(j.get("feasible")?, Json::Bool(true)),
            mechanism: j.get("mechanism")?.as_str()?.to_string(),
            microbatches: opt_usize(j, "microbatches")?,
            strategy: strategy_from_json(j.get("strategy")?)?,
            memory,
            feasibility,
            collective: match j.opt("collective") {
                None | Some(Json::Null) => "none".to_string(),
                Some(v) => v.as_str()?.to_string(),
            },
            exchange_tail_s: opt_f64(j, "exchange_tail_s")?,
            note: j.get("note")?.as_str()?.to_string(),
        })
    }
}

impl CurvePoint {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("devices", junum(self.devices)),
            ("dp", jonum(self.dp)),
            ("hybrid", jonum(self.hybrid)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(CurvePoint {
            devices: j.get("devices")?.as_usize()?,
            dp: opt_f64(j, "dp")?,
            hybrid: opt_f64(j, "hybrid")?,
        })
    }
}

impl Plan {
    /// Serialise the full plan (scorecard and curve included).  The
    /// `explain` key is emitted only when present, so default plan
    /// documents are byte-identical to pre-explain ones.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("device_budget", junum(self.device_budget)),
            ("devices_used", junum(self.devices_used)),
            ("mini_batch", junum(self.mini_batch)),
            ("global_batch", junum(self.global_batch)),
            ("cost_model", Json::Str(self.cost_model.clone())),
            ("objective", Json::Str(self.objective.as_str().into())),
            ("strategy", strategy_to_json(&self.strategy)),
            ("mp_degree", junum(self.mp_degree)),
            ("dp_workers", junum(self.dp_workers)),
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("microbatches", jounum(self.microbatches)),
            ("predicted_step_s", jnum(self.predicted_step_s)),
            ("predicted_epochs", jonum(self.predicted_epochs)),
            ("predicted_speedup", jnum(self.predicted_speedup)),
            ("crossover_devices",
             self.crossover_devices
                 .map(|v| Json::Num(v as f64))
                 .unwrap_or(Json::Null)),
            ("placement",
             self.placement
                 .as_ref()
                 .map(|p| Json::Arr(
                     p.iter().map(|&d| Json::Num(d as f64)).collect()))
                 .unwrap_or(Json::Null)),
            ("pipeline_bounds",
             self.pipeline_bounds
                 .as_ref()
                 .map(|p| Json::Arr(
                     p.iter().map(|&d| Json::Num(d as f64)).collect()))
                 .unwrap_or(Json::Null)),
            ("device_mem_gb", jonum(self.device_mem_gb)),
            ("available_mem_bytes", jnum(self.available_mem_bytes)),
            ("optimizer", Json::Str(self.optimizer.clone())),
            ("recompute", Json::Bool(self.recompute)),
            ("nodes", jounum(self.nodes)),
            ("collective", Json::Str(self.collective.clone())),
            ("overlap_buckets", junum(self.overlap_buckets)),
            ("compression", jnum(self.compression)),
            ("exchange_tail_s", jonum(self.exchange_tail_s)),
            ("memory",
             self.memory
                 .as_ref()
                 .map(|m| m.to_json())
                 .unwrap_or(Json::Null)),
            ("scorecard",
             Json::Arr(self.scorecard.iter().map(|c| c.to_json()).collect())),
            ("curve",
             Json::Arr(self.curve.iter().map(|c| c.to_json()).collect())),
        ];
        if let Some(e) = &self.explain {
            pairs.push(("explain", e.to_json()));
        }
        jobj(pairs)
    }

    /// The canonical serialised plan document: compact sorted-key JSON
    /// plus a trailing newline — the exact bytes the `plan` CLI prints
    /// on stdout and writes with `--out-json`, the service's
    /// `POST /plan` returns, and the golden-plan fixtures pin.  One
    /// writer, so the surfaces cannot drift apart byte-wise.
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().to_string();
        s.push('\n');
        s
    }

    /// Reconstruct a plan from [`Plan::to_json`] output.
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Plan {
            model: j.get("model")?.as_str()?.to_string(),
            topology: j.get("topology")?.as_str()?.to_string(),
            device_budget: j.get("device_budget")?.as_usize()?,
            devices_used: j.get("devices_used")?.as_usize()?,
            mini_batch: j.get("mini_batch")?.as_usize()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            cost_model: j.get("cost_model")?.as_str()?.to_string(),
            objective: Objective::parse(j.get("objective")?.as_str()?)?,
            strategy: strategy_from_json(j.get("strategy")?)?,
            mp_degree: j.get("mp_degree")?.as_usize()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
            mechanism: j.get("mechanism")?.as_str()?.to_string(),
            microbatches: opt_usize(j, "microbatches")?,
            predicted_step_s: j.get("predicted_step_s")?.as_f64()?,
            predicted_epochs: opt_f64(j, "predicted_epochs")?,
            predicted_speedup: j.get("predicted_speedup")?.as_f64()?,
            crossover_devices: opt_usize(j, "crossover_devices")?,
            placement: opt_usize_arr(j, "placement")?,
            pipeline_bounds: opt_usize_arr(j, "pipeline_bounds")?,
            device_mem_gb: opt_f64(j, "device_mem_gb")?,
            available_mem_bytes: j.get("available_mem_bytes")?.as_f64()?,
            optimizer: j.get("optimizer")?.as_str()?.to_string(),
            recompute: matches!(j.get("recompute")?, Json::Bool(true)),
            nodes: opt_usize(j, "nodes")?,
            collective: match j.opt("collective") {
                None | Some(Json::Null) => "none".to_string(),
                Some(v) => v.as_str()?.to_string(),
            },
            overlap_buckets: opt_usize(j, "overlap_buckets")?.unwrap_or(1),
            compression: opt_f64(j, "compression")?.unwrap_or(1.0),
            exchange_tail_s: opt_f64(j, "exchange_tail_s")?,
            explain: match j.opt("explain") {
                None | Some(Json::Null) => None,
                Some(v) => Some(PlanExplain::from_json(v)?),
            },
            memory: match j.opt("memory") {
                None | Some(Json::Null) => None,
                Some(v) => Some(MemoryEstimate::from_json(v)?),
            },
            scorecard: j
                .get("scorecard")?
                .as_arr()?
                .iter()
                .map(CandidateScore::from_json)
                .collect::<Result<Vec<_>>>()?,
            curve: j
                .get("curve")?
                .as_arr()?
                .iter()
                .map(CurvePoint::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Human-readable multi-line summary for CLIs and examples.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "plan: {} on {} (budget {} devices, objective {}, cost {})\n",
            self.model, self.topology, self.device_budget,
            self.objective.as_str(), self.cost_model));
        s.push_str(&format!(
            "  chosen: {:?} — M={} x N_dp={} ({} devices used, \
             mechanism {})\n",
            self.strategy, self.mp_degree, self.dp_workers,
            self.devices_used, self.mechanism));
        s.push_str(&format!(
            "  predicted: step {:.3} ms, epochs {}, end-to-end speedup \
             {:.2}x vs 1 device\n",
            self.predicted_step_s * 1e3,
            self.predicted_epochs
                .map(|e| format!("{e:.1}"))
                .unwrap_or_else(|| "-".into()),
            self.predicted_speedup));
        if self.collective != "none" {
            s.push_str(&format!(
                "  gradient exchange: {} all-reduce across {} workers\n",
                self.collective, self.dp_workers));
        }
        if self.overlap_buckets > 1 || self.compression != 1.0 {
            s.push_str(&format!(
                "  overlap: up to {} buckets, compression {:.2}{}\n",
                self.overlap_buckets, self.compression,
                self.exchange_tail_s
                    .map(|t| format!(", exposed tail {:.3} ms", t * 1e3))
                    .unwrap_or_default()));
        }
        if let Some(m) = &self.memory {
            s.push_str(&format!(
                "  memory: peak {:.1} GB / {:.1} GB per device \
                 (optimizer {}, recompute {})\n",
                m.total_bytes / 1e9, self.available_mem_bytes / 1e9,
                self.optimizer, self.recompute));
        }
        match self.crossover_devices {
            Some(x) => s.push_str(&format!(
                "  Eq. 6 crossover: hybrid overtakes DP-only at {x} \
                 devices\n")),
            None => s.push_str("  Eq. 6 crossover: none in sweep range\n"),
        }
        for c in &self.scorecard {
            s.push_str(&format!(
                "  candidate M={}: SU^M {:.3}, speedup {}{}\n",
                c.mp_degree, c.su_m,
                c.speedup
                    .map(|v| format!("{v:.2}x"))
                    .unwrap_or_else(|| "-".into()),
                if c.note.is_empty() {
                    String::new()
                } else {
                    format!(" ({})", c.note)
                }));
        }
        s
    }

    /// Render the attached [`PlanExplain`] as a human-readable cost
    /// waterfall (what `plan --explain` prints to stderr).  Returns a
    /// pointer at `--explain` when the plan carries no explanation.
    pub fn explain_text(&self) -> String {
        let e = match &self.explain {
            Some(e) => e,
            None => {
                return "no explanation attached (re-plan with --explain)\n"
                    .to_string()
            }
        };
        let ms = |t: f64| format!("{:.3} ms", t * 1e3);
        let mut s = String::new();
        s.push_str(&format!(
            "why M={} {} on {}@{} (cost {}):\n",
            self.mp_degree, self.mechanism, self.model, self.topology,
            self.cost_model));
        s.push_str(&format!(
            "  serial step {} (recompute x{:.2}), SE_N {:.4}\n",
            ms(e.serial_step_s), e.time_factor, e.se));
        s.push_str(&format!(
            "  chosen waterfall (sums to predicted step {}):\n",
            ms(self.predicted_step_s)));
        s.push_str(&format!(
            "    compute (ideal /{})   {}\n",
            self.mp_degree.max(1), ms(e.chosen.compute_s)));
        s.push_str(&format!(
            "    mp overhead (bubble)  {}\n", ms(e.chosen.mp_overhead_s)));
        s.push_str(&format!(
            "    dp exchange ({})      {}\n",
            e.chosen.collective, ms(e.chosen.exchange_s)));
        s.push_str(&format!(
            "  statistical efficiency: E(B1)/E(B) = {} at global batch \
             {}\n",
            e.epochs_ratio
                .map(|r| format!("{r:.4}"))
                .unwrap_or_else(|| "diverges".into()),
            self.global_batch));
        s.push_str(&format!("  memory: {}\n", e.memory_verdict));
        for r in &e.candidates {
            s.push_str(&format!(
                "  candidate M={} {:<9}: step {} = {} compute + {} mp \
                 + {} exchange\n",
                r.mp_degree, r.mechanism, ms(r.total_s), ms(r.compute_s),
                ms(r.mp_overhead_s), ms(r.exchange_s)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_only_wins_at_small_scale() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
            .unwrap();
        assert_eq!(plan.mp_degree, 1, "DP-only at 8 devices");
        assert_eq!(plan.strategy,
                   Strategy::DataParallel { workers: 8, delayed_factor: 1 });
        assert!((plan.predicted_speedup - 8.0).abs() < 1e-6,
                "flat E(B) region: SU = N, got {}", plan.predicted_speedup);
        assert_eq!(plan.devices_used, 8);
        assert_eq!(plan.global_batch, 8 * 32);
    }

    #[test]
    fn explain_waterfall_sums_to_the_reported_step_time() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .devices(256)
                .explain(true))
            .unwrap();
        let e = plan.explain.as_ref().expect("explain requested");
        let sum = e.chosen.compute_s + e.chosen.mp_overhead_s
            + e.chosen.exchange_s;
        assert!((sum - plan.predicted_step_s).abs() <= 1e-12
                    + 1e-9 * plan.predicted_step_s,
                "chosen waterfall must sum exactly: {sum} vs {}",
                plan.predicted_step_s);
        assert_eq!(e.chosen.total_s, plan.predicted_step_s);
        assert!(!e.candidates.is_empty());
        for r in &e.candidates {
            let s = r.compute_s + r.mp_overhead_s + r.exchange_s;
            assert!((s - r.total_s).abs() <= 1e-12 + 1e-9 * r.total_s,
                    "candidate M={} waterfall must sum: {s} vs {}",
                    r.mp_degree, r.total_s);
        }
        assert!(plan.explain_text().contains("chosen waterfall"));
    }

    #[test]
    fn explain_is_absent_by_default_and_round_trips() {
        let planner = Planner::new();
        let req = PlanRequest::new("gnmt", "dgx1").devices(8);
        let bare = planner.plan(&req).unwrap();
        assert!(bare.explain.is_none());
        assert!(bare.to_json().opt("explain").is_none(),
                "default plan documents must not grow an explain key");
        let explained =
            planner.plan(&req.clone().explain(true)).unwrap();
        let j = explained.to_json();
        assert!(j.opt("explain").is_some());
        let back = Plan::from_json(&j).unwrap();
        assert_eq!(back.explain, explained.explain,
                   "Plan.explain must round-trip through JSON");
        // Everything except the explain attachment matches the bare plan.
        let mut stripped = explained.clone();
        stripped.explain = None;
        assert_eq!(stripped.to_json().to_string(),
                   bare.to_json().to_string());
    }

    #[test]
    fn hybrid_wins_at_scale_for_gnmt() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(256))
            .unwrap();
        assert_eq!(plan.mp_degree, 2, "paper: hybrid wins at 256 GPUs");
        assert!(matches!(plan.strategy,
                         Strategy::PipelinedHybrid { stages: 2,
                                                     replicas: 128, .. }),
                "chain MP is the runtime-executable 2-stage pipeline: {:?}",
                plan.strategy);
        assert_eq!(plan.mechanism, "pipelined");
        assert!(plan.pipeline_bounds.is_some());
        assert!(plan.crossover_devices.is_some());
    }

    #[test]
    fn scorecard_considers_pipelined_hybrids_for_every_paper_network() {
        // The acceptance bar of the pipelined-search change: branchy
        // Inception included, every paper network's plan weighs at least
        // one PipelinedHybrid candidate.
        let planner = Planner::new();
        for model in ["inception-v3", "gnmt", "biglstm"] {
            let plan = planner
                .plan(&PlanRequest::new(model, "dgx1").devices(8))
                .unwrap();
            let pipelined: Vec<&CandidateScore> = plan
                .scorecard
                .iter()
                .filter(|c| matches!(c.strategy,
                                     Strategy::PipelinedHybrid { .. }))
                .collect();
            assert!(!pipelined.is_empty(),
                    "{model}: no PipelinedHybrid candidate in scorecard");
            for c in pipelined {
                assert_eq!(c.mechanism, "pipelined");
                assert!(c.microbatches.unwrap_or(0) >= 1);
                assert!(c.su_m > 0.0);
            }
        }
    }

    #[test]
    fn pipeline_only_requests_skip_the_placer() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1")
                .devices(8)
                .pipeline_only(true))
            .unwrap();
        for c in plan.scorecard.iter().filter(|c| c.mp_degree > 1) {
            assert_eq!(c.mechanism, "pipelined",
                       "pipeline_only must not place: {c:?}");
        }
    }

    #[test]
    fn infeasible_degrees_drop_out_instead_of_failing() {
        // GNMT has 11 ops: a 64-stage pipeline cannot exist.  Any search
        // mode must keep the valid M=2 candidate and drop M=64, not error
        // out — including the simulator, which refuses pipelines deeper
        // than the physical box.
        for (pipeline_only, cost) in [
            (true, None),
            (false, None),
            (false, Some(cost_by_name("simulator").unwrap())),
        ] {
            let planner = match cost {
                Some(c) => Planner::with_cost(c),
                None => Planner::new(),
            };
            let plan = planner
                .plan(&PlanRequest::new("gnmt", "dgx1")
                    .devices(8)
                    .mp_degrees(&[2, 64])
                    .pipeline_only(pipeline_only))
                .unwrap();
            assert!(plan.scorecard.iter().any(|c| c.mp_degree == 2),
                    "pipeline_only={pipeline_only}");
            assert!(plan.scorecard.iter().all(|c| c.mp_degree != 64),
                    "pipeline_only={pipeline_only}");
        }
    }

    #[test]
    fn best_mechanism_leads_each_degree_in_the_scorecard() {
        // When both mechanisms are scored for a degree, the first row is
        // the one Eq. 5 used — i.e. the lower per-worker step time / the
        // higher SU^M.
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
            .unwrap();
        let rows: Vec<&CandidateScore> = plan
            .scorecard
            .iter()
            .filter(|c| c.mp_degree == 2 && c.mechanism != "layerwise")
            .collect();
        assert_eq!(rows.len(), 2,
                   "branchy graph: placed + pipelined rows expected");
        assert!(rows[0].su_m >= rows[1].su_m,
                "best-first ordering violated: {} < {}",
                rows[0].su_m, rows[1].su_m);
        // The fixed mechanisms are followed by the degree's layer-wise row.
        assert!(plan
            .scorecard
            .iter()
            .any(|c| c.mp_degree == 2 && c.mechanism == "layerwise"),
            "every scored degree also carries a layer-wise row");
    }

    #[test]
    fn biglstm_backs_off_when_everything_diverges() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("biglstm", "dgx1").devices(256))
            .unwrap();
        assert!(plan.devices_used < 256,
                "must back off below the divergence ceiling");
        assert!(plan.predicted_epochs.is_some());
    }

    #[test]
    fn single_device_budget() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(1))
            .unwrap();
        assert_eq!(plan.strategy, Strategy::Single);
        assert_eq!(plan.mp_degree, 1);
    }

    #[test]
    fn step_time_objective_ignores_epochs() {
        let planner = Planner::new();
        // BigLSTM at 64 devices: DP diverges statistically, but pure
        // throughput doesn't care.
        let plan = planner
            .plan(&PlanRequest::new("biglstm", "dgx1")
                .devices(64)
                .objective(Objective::StepTime))
            .unwrap();
        assert_eq!(plan.devices_used, 64);
        assert_eq!(plan.objective, Objective::StepTime);
    }

    #[test]
    fn unknown_names_error() {
        let planner = Planner::new();
        assert!(planner
            .plan(&PlanRequest::new("alexnet", "dgx1"))
            .is_err());
        assert!(planner
            .plan(&PlanRequest::new("gnmt", "ringworld"))
            .is_err());
    }

    #[test]
    fn biglstm_dp_is_infeasible_on_16gb_parts() {
        // The acceptance bar of the memory layer: on 16 GB devices the
        // BigLSTM DP-only candidate overflows (it needs the 32 GB V100,
        // paper §4.1) and the planner picks the 2-stage pipeline instead;
        // on 80 GB parts the same candidate is feasible again.
        let planner = Planner::new();
        let small = planner
            .plan(&PlanRequest::new("biglstm", "dgx1")
                .devices(8)
                .device_mem_gb(16.0))
            .unwrap();
        let dp_row = small
            .scorecard
            .iter()
            .find(|c| c.mp_degree == 1)
            .unwrap();
        assert!(!dp_row.feasibility.is_feasible(),
                "BigLSTM DP must overflow 16 GB: {dp_row:?}");
        match dp_row.feasibility {
            Feasibility::Infeasible { required_bytes, available_bytes } => {
                assert!(required_bytes > available_bytes);
                assert!((available_bytes - 16e9).abs() < 1.0);
            }
            Feasibility::Feasible => unreachable!(),
        }
        assert!(dp_row.speedup.is_none());
        assert!(dp_row.note.contains("infeasible"));
        assert!(small.mp_degree > 1,
                "DP cannot fit: the plan must go hybrid");
        assert!(small.curve.iter().all(|p| p.dp.is_none()),
                "infeasible DP contributes no curve points");

        let big = planner
            .plan(&PlanRequest::new("biglstm", "dgx1")
                .devices(8)
                .device_mem_gb(80.0))
            .unwrap();
        let dp_row = big.scorecard.iter().find(|c| c.mp_degree == 1);
        assert!(dp_row.unwrap().feasibility.is_feasible(),
                "the same candidate must fit an 80 GB part");
        assert_eq!(big.mp_degree, 1, "with room to fit, DP wins at 8");
    }

    #[test]
    fn nothing_fits_errors_with_memory_hint() {
        let planner = Planner::new();
        let err = planner
            .plan(&PlanRequest::new("biglstm", "dgx1")
                .devices(8)
                .device_mem_gb(1.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("GB"), "error must name the capacity: {err}");
        assert!(planner
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .devices(8)
                .device_mem_gb(-4.0))
            .is_err());
    }

    #[test]
    fn recompute_shrinks_footprint_and_inflates_step_time() {
        use crate::memory::MemoryModel;
        let planner = Planner::new();
        let base = PlanRequest::new("inception-v3", "dgx1").devices(8);
        let full = planner.plan(&base.clone()).unwrap();
        let rc = planner
            .plan(&base.memory(MemoryModel {
                recompute: true,
                ..Default::default()
            }))
            .unwrap();
        assert!(rc.recompute && !full.recompute);
        let (mf, mr) = (full.memory.unwrap(), rc.memory.unwrap());
        assert!(mr.total_bytes < mf.total_bytes,
                "recompute must shrink the footprint");
        assert!(rc.predicted_step_s > full.predicted_step_s * 1.30,
                "…and pay roughly one extra forward: {} vs {}",
                rc.predicted_step_s, full.predicted_step_s);
        assert!((rc.predicted_speedup - full.predicted_speedup).abs()
                    < 1e-9,
                "uniform inflation must not change relative speedups");
    }

    #[test]
    fn default_memory_model_keeps_paper_plans_feasible() {
        // On the registry's 32 GB dgx1 every scorecard row of the paper
        // networks stays feasible — the memory layer must not perturb the
        // fig5 grid.
        let planner = Planner::new();
        for model in ["inception-v3", "gnmt", "biglstm"] {
            let plan = planner
                .plan(&PlanRequest::new(model, "dgx1").devices(8))
                .unwrap();
            for c in &plan.scorecard {
                assert!(c.feasibility.is_feasible(),
                        "{model}: {c:?} must fit the 32 GB V100");
                assert!(c.memory.is_some());
            }
            assert!(plan.memory.unwrap().fits(plan.available_mem_bytes));
        }
    }

    #[test]
    fn analytical_plans_record_no_collective() {
        // SE = 1: communication is free, so nothing is priced.
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap();
        assert_eq!(plan.collective, "none");
        assert!(plan.scorecard.iter().all(|c| c.collective == "none"));
    }

    #[test]
    fn alpha_beta_plans_record_the_pricing_algorithm() {
        use crate::planner::cost::AlphaBetaCost;
        // Single box: the DP exchange is priced as a ring.
        let planner = Planner::with_cost(Box::new(AlphaBetaCost::default()));
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap();
        let dp = plan.scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
        assert_eq!(dp.collective, "ring");
        // Multi-node pod: the same candidate prices hierarchically.
        let pod = planner
            .plan(&PlanRequest::new("gnmt", "dgx1-pod")
                .devices(32)
                .nodes(4))
            .unwrap();
        assert_eq!(pod.nodes, Some(4));
        let dp = pod.scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
        assert_eq!(dp.collective, "hierarchical");
        // And --collective ring pins the flat ring everywhere.
        let flat = planner
            .plan(&PlanRequest::new("gnmt", "dgx1-pod")
                .devices(32)
                .nodes(4)
                .collective(Algorithm::Ring))
            .unwrap();
        let dp = flat.scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
        assert_eq!(dp.collective, "ring");
    }

    #[test]
    fn single_box_topologies_reject_multi_node_requests() {
        let planner = Planner::new();
        let err = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(16).nodes(2))
            .unwrap_err()
            .to_string();
        assert!(err.contains("dgx1"), "{err}");
        assert!(planner
            .plan(&PlanRequest::new("gnmt", "dgx1-pod").devices(16).nodes(0))
            .is_err());
        // nodes(1) on a single-box topology is the box itself.
        let one = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8).nodes(1))
            .unwrap();
        assert_eq!(one.devices_used, 8);
    }

    #[test]
    fn strategy_json_round_trip() {
        for s in [
            Strategy::Single,
            Strategy::DataParallel { workers: 8, delayed_factor: 2 },
            Strategy::Hybrid { dp_workers: 4, microbatches: 8 },
            Strategy::PipelinedHybrid { stages: 4, microbatches: 8,
                                        replicas: 16 },
            Strategy::AsyncPs { workers: 3, staleness: 2 },
            Strategy::LocalSgd { workers: 4, sync_every: 16 },
            Strategy::TensorParallel { degree: 8, dp_workers: 4 },
            Strategy::LayerWise {
                degree: 2,
                dp_workers: 4,
                assignment: vec![
                    ("embed".into(), "replicate".into()),
                    ("lstm0".into(), "split-feature".into()),
                ],
            },
        ] {
            let j = strategy_to_json(&s);
            let text = j.to_string();
            let back =
                strategy_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(s, back);
        }
    }

    #[test]
    fn tensor_rows_are_opt_in_scorecard_analysis() {
        // No tensor degrees requested: no tensor rows, selection exactly
        // as before the axis existed.
        let plain = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap();
        assert!(plain.scorecard.iter().all(|c| c.mechanism != "tensor"));
        // Requested: a "tensor" row appears for the degree, but Auto
        // selection still picks among the fixed candidates when they fit.
        let with_tp = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .devices(8)
                .tensor_degrees(&[2]))
            .unwrap();
        let row = with_tp
            .scorecard
            .iter()
            .find(|c| c.mechanism == "tensor")
            .expect("a tensor scorecard row");
        assert_eq!(row.mp_degree, 2);
        assert_eq!(row.dp_workers, 4);
        assert!(matches!(
            row.strategy,
            Strategy::TensorParallel { degree: 2, dp_workers: 4 }));
        assert!(row.su_m > 1.0, "an intra-layer split beats serial");
        assert_eq!(with_tp.strategy.kind(), plain.strategy.kind());
        assert_eq!(with_tp.mechanism, plain.mechanism);
    }

    #[test]
    fn tensor_mechanism_drives_selection() {
        let plan = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .devices(8)
                .tensor_degrees(&[2])
                .mechanism(PlanMechanism::Tensor))
            .unwrap();
        assert_eq!(plan.mechanism, "tensor");
        assert_eq!(plan.mp_degree, 2);
        assert_eq!(plan.dp_workers, 4);
        assert!(matches!(
            plan.strategy,
            Strategy::TensorParallel { degree: 2, dp_workers: 4 }));
        assert!(plan.microbatches.is_none());
        assert!(plan.predicted_step_s > 0.0);
        // The mechanism with no degree to drive it fails loudly instead
        // of silently planning something else.
        assert!(Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .mechanism(PlanMechanism::Tensor))
            .is_err());
    }

    #[test]
    fn zero_sharding_makes_dp_feasibility_n_dependent() {
        use crate::memory::ZeroMode;
        use crate::planner::cost::AlphaBetaCost;
        let dp = |p: &Plan| {
            let c =
                p.scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
            (c.feasible, c.memory.unwrap().total_bytes)
        };
        // BigLSTM's Adam state overflows 16 GB parts when every DP
        // replica holds the whole model…
        let base = PlanRequest::new("biglstm", "dgx1")
            .devices(8)
            .device_mem_gb(16.0);
        let replicated = Planner::new().plan(&base.clone()).unwrap();
        let (fits_rep, bytes_rep) = dp(&replicated);
        assert!(!fits_rep);
        // …and ZeRO-3 sharding across the 8 DP ranks makes the same
        // model fit the same parts: feasibility is now N-dependent.
        let mut sharded_req = base.clone();
        sharded_req.memory.zero = ZeroMode::Weights;
        let sharded = Planner::new().plan(&sharded_req).unwrap();
        let (fits_shard, bytes_shard) = dp(&sharded);
        assert!(fits_shard);
        assert!(bytes_shard < bytes_rep);
        // With one device there is nothing to shard across, so the same
        // request fails outright.
        let mut single = sharded_req.clone();
        single.devices = 1;
        single.curve_max_devices = 1;
        assert!(Planner::new().plan(&single).is_err());
        // ZeRO is not a free lunch under a priced exchange: sharded
        // state is re-gathered every step, so the predicted step slows.
        let priced = |zero: ZeroMode| {
            let mut r = PlanRequest::new("gnmt", "dgx1").devices(8);
            r.memory.zero = zero;
            let p = Planner::with_cost(Box::new(AlphaBetaCost::default()))
                .plan(&r)
                .unwrap();
            p.scorecard
                .iter()
                .find(|c| c.mp_degree == 1)
                .unwrap()
                .step_time_s
                .unwrap()
        };
        assert!(priced(ZeroMode::Weights) > priced(ZeroMode::Off));
    }

    #[test]
    fn objective_parse_round_trip() {
        for o in [Objective::TimeToConverge, Objective::StepTime] {
            assert_eq!(Objective::parse(o.as_str()).unwrap(), o);
        }
        assert!(Objective::parse("fastest").is_err());
    }

    #[test]
    fn plan_request_wire_format_parses_and_defaults() {
        // A minimal body defaults exactly like PlanRequest::new.
        let (req, cost) = plan_request_from_json(
            &Json::parse(r#"{"model":"gnmt"}"#).unwrap()).unwrap();
        let d = PlanRequest::new("gnmt", "dgx1");
        assert_eq!(req.topology, d.topology);
        assert_eq!(req.devices, d.devices);
        assert_eq!(req.batch, None);
        assert_eq!(req.mp_degrees, d.mp_degrees);
        assert!(req.tensor_degrees.is_empty());
        assert_eq!(req.curve_max_devices, d.curve_max_devices);
        assert_eq!(req.memory, d.memory);
        assert_eq!(req.mechanism, PlanMechanism::Auto);
        assert_eq!(cost, None);
        // Every field parses.
        let (req, cost) = plan_request_from_json(&Json::parse(
            r#"{"model":"biglstm","topology":"dgx1-pod","devices":32,
                "nodes":4,"collective":"ring","device_mem_gb":16,
                "objective":"step-time","mp_degrees":[4,2],
                "tensor_degrees":[8,2],
                "pipeline_only":true,"curve_max_devices":64,
                "batch":32,"memory":{"recompute":true},
                "mechanism":"layerwise","cost":"sim",
                "overlap":8,"compression":0.25}"#)
            .unwrap()).unwrap();
        assert_eq!(req.model, "biglstm");
        assert_eq!(req.topology, "dgx1-pod");
        assert_eq!(req.devices, 32);
        assert_eq!(req.nodes, Some(4));
        assert_eq!(req.collective, Some(Algorithm::Ring));
        assert_eq!(req.device_mem_gb, Some(16.0));
        assert_eq!(req.objective, Objective::StepTime);
        assert_eq!(req.mp_degrees, vec![4, 2]);
        assert_eq!(req.tensor_degrees, vec![8, 2]);
        assert!(req.pipeline_only);
        assert_eq!(req.curve_max_devices, 64);
        assert_eq!(req.batch, Some(32));
        assert!(req.memory.recompute);
        assert_eq!(req.mechanism, PlanMechanism::Layerwise);
        assert_eq!(cost.as_deref(), Some("sim"));
        assert_eq!(req.overlap_buckets, 8);
        assert_eq!(req.compression, 0.25);
        // "auto" collective and explicit nulls mean default.
        let (req, _) = plan_request_from_json(&Json::parse(
            r#"{"model":"gnmt","collective":"auto","batch":null,
                "nodes":null}"#).unwrap()).unwrap();
        assert_eq!(req.collective, None);
        assert_eq!(req.batch, None);
        assert_eq!(req.nodes, None);
        // Unknown keys, missing model and mistyped values are rejected.
        for bad in [r#"{"model":"gnmt","modle":1}"#,
                    r#"{"topology":"dgx1"}"#,
                    r#"{"model":"gnmt","pipeline_only":3}"#,
                    r#"{"model":"gnmt","mechanism":"oracle"}"#,
                    r#"{"model":"gnmt","collective":"pigeon"}"#] {
            assert!(plan_request_from_json(&Json::parse(bad).unwrap())
                        .is_err(), "{bad}");
        }
        // The wire is strict about integers: fractions and negatives
        // error instead of truncating, and allocation-bearing fields
        // are capped (the daemon parses attacker-chosen JSON).
        for bad in [r#"{"model":"gnmt","devices":2.5}"#,
                    r#"{"model":"gnmt","devices":-8}"#,
                    r#"{"model":"gnmt","devices":1000000000000000}"#,
                    r#"{"model":"gnmt","nodes":100000}"#,
                    r#"{"model":"gnmt","mp_degrees":[2.5]}"#,
                    r#"{"model":"gnmt","tensor_degrees":[2.5]}"#,
                    r#"{"model":"gnmt","batch":-1}"#] {
            let err = plan_request_from_json(&Json::parse(bad).unwrap())
                .unwrap_err()
                .to_string();
            assert!(err.contains("integer") || err.contains("wire cap"),
                    "{bad}: {err}");
        }
        let (req, _) = plan_request_from_json(&Json::parse(
            r#"{"model":"gnmt","devices":65536}"#).unwrap()).unwrap();
        assert_eq!(req.devices, MAX_WIRE_DEVICES, "the cap is inclusive");
        // The overlap axes validate at the wire: zero buckets, a bucket
        // budget past the cap, and out-of-range compression all reject.
        for bad in [r#"{"model":"gnmt","overlap":0}"#,
                    r#"{"model":"gnmt","overlap":2048}"#,
                    r#"{"model":"gnmt","compression":0}"#,
                    r#"{"model":"gnmt","compression":1.5}"#,
                    r#"{"model":"gnmt","compression":-0.5}"#] {
            assert!(plan_request_from_json(&Json::parse(bad).unwrap())
                        .is_err(), "{bad}");
        }
        // Explicit nulls default the overlap axes like every other key.
        let (req, _) = plan_request_from_json(&Json::parse(
            r#"{"model":"gnmt","overlap":null,"compression":null}"#)
            .unwrap()).unwrap();
        assert_eq!(req.overlap_buckets, 1);
        assert_eq!(req.compression, 1.0);
    }

    #[test]
    fn canonical_json_collapses_equivalent_spellings_only() {
        let models = ModelRegistry::builtin();
        let key = |r: &PlanRequest, cost: &str| {
            r.canonical_json(&models, cost).to_string()
        };
        // Alias + explicit-default batch + degenerate degree list all
        // collapse onto the bare spelling.
        let a = PlanRequest::new("inception", "dgx1");
        let b = PlanRequest::new("inception-v3", "dgx1")
            .batch(32)
            .mp_degrees(&[2, 2, 1])
            .tensor_degrees(&[1]);
        assert_eq!(key(&a, "analytical"), key(&b, "analytical"));
        // A real tensor-degree list is cache-distinct (it adds scorecard
        // rows), and duplicate spellings of it collapse.
        let t1 = PlanRequest::new("inception", "dgx1")
            .tensor_degrees(&[8, 2]);
        let t2 = PlanRequest::new("inception", "dgx1")
            .tensor_degrees(&[2, 8, 8, 1]);
        assert_ne!(key(&a, "analytical"), key(&t1, "analytical"));
        assert_eq!(key(&t1, "analytical"), key(&t2, "analytical"));
        // The ZeRO mode rides in the embedded memory model, so a sharded
        // request can never share a replicated request's cache entry.
        let mut z = PlanRequest::new("inception", "dgx1");
        z.memory.zero = crate::memory::ZeroMode::Weights;
        assert_ne!(key(&a, "analytical"), key(&z, "analytical"));
        // recompute_overhead is invisible while recompute is off…
        let mut e = PlanRequest::new("inception", "dgx1");
        e.memory.recompute_overhead = 0.9;
        assert_eq!(key(&a, "analytical"), key(&e, "analytical"));
        // …and significant once it is on.
        let mut f = e.clone();
        f.memory.recompute = true;
        let mut g = PlanRequest::new("inception", "dgx1");
        g.memory = MemoryModel { recompute: true, ..g.memory.clone() };
        assert_ne!(key(&f, "analytical"), key(&g, "analytical"));
        // Output-visible differences stay distinct: nodes(1) vs None,
        // device_mem_gb override vs topology default, cost model.
        let c = PlanRequest::new("inception-v3", "dgx1").nodes(1);
        assert_ne!(key(&a, "analytical"), key(&c, "analytical"));
        let d = PlanRequest::new("inception-v3", "dgx1")
            .device_mem_gb(32.0);
        assert_ne!(key(&a, "analytical"), key(&d, "analytical"));
        assert_ne!(key(&a, "analytical"), key(&a, "simulator"));
        // The mechanism is part of the cache identity: a layer-wise plan
        // must never be served from an auto-mechanism cache entry.
        let h = PlanRequest::new("inception", "dgx1")
            .mechanism(PlanMechanism::Layerwise);
        assert_ne!(key(&a, "analytical"), key(&h, "analytical"));
        // Explicit overlap-off spellings collapse onto the default entry;
        // any real overlap/compression setting gets its own entry, so the
        // service cache can never serve an overlapped plan from a serial
        // one (or vice versa).
        let off = PlanRequest::new("inception", "dgx1")
            .overlap_buckets(1)
            .compression(1.0);
        assert_eq!(key(&a, "analytical"), key(&off, "analytical"));
        let bucketed =
            PlanRequest::new("inception", "dgx1").overlap_buckets(8);
        assert_ne!(key(&a, "analytical"), key(&bucketed, "analytical"));
        let squeezed =
            PlanRequest::new("inception", "dgx1").compression(0.5);
        assert_ne!(key(&a, "analytical"), key(&squeezed, "analytical"));
        assert_ne!(key(&bucketed, "analytical"),
                   key(&squeezed, "analytical"));
        // Canonical keys are themselves sorted-key JSON (BTreeMap), so
        // re-parsing and re-printing is identity.
        let k = key(&a, "analytical");
        assert_eq!(Json::parse(&k).unwrap().to_string(), k);
    }

    #[test]
    fn overlap_request_shrinks_the_exchange_tail() {
        use crate::planner::cost::AlphaBetaCost;
        let planner =
            Planner::with_cost(Box::new(AlphaBetaCost::default()));
        let base = PlanRequest::new("gnmt", "dgx1").devices(8);
        let off = planner.plan(&base.clone()).unwrap();
        // Explicit defaults are byte-identical to the bare request.
        let explicit = planner
            .plan(&base.clone().overlap_buckets(1).compression(1.0))
            .unwrap();
        assert_eq!(off.to_json_string(), explicit.to_json_string());
        assert_eq!(off.overlap_buckets, 1);
        assert_eq!(off.compression, 1.0);
        // Overlap off: the DP row's tail is the full serial exchange.
        let dp_off = off
            .scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
        let tail_off = dp_off.exchange_tail_s.unwrap();
        assert!(tail_off > 0.0);
        // Overlap + compression on: the exposed tail shrinks and the
        // step prediction improves (or at worst ties).
        let on = planner
            .plan(&base.overlap_buckets(8).compression(0.25))
            .unwrap();
        assert_eq!(on.overlap_buckets, 8);
        assert_eq!(on.compression, 0.25);
        let dp_on =
            on.scorecard.iter().find(|c| c.mp_degree == 1).unwrap();
        let tail_on = dp_on.exchange_tail_s.unwrap();
        assert!(tail_on < tail_off,
                "overlap must shrink the tail: {tail_on} vs {tail_off}");
        assert!(dp_on.step_time_s.unwrap() < dp_off.step_time_s.unwrap());
        // An invalid overlap request fails loudly.
        assert!(planner
            .plan(&PlanRequest::new("gnmt", "dgx1").compression(0.0))
            .is_err());
        // Analytical SE = 1 prices no exchange: no tail either way.
        let ana = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1")
                .devices(8)
                .overlap_buckets(8))
            .unwrap();
        assert!(ana.exchange_tail_s.is_none());
        assert!(ana.scorecard.iter()
            .all(|c| c.exchange_tail_s.is_none()));
    }

    #[test]
    fn plan_document_is_json_plus_newline() {
        let plan = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
            .unwrap();
        let doc = plan.to_json_string();
        assert!(doc.ends_with('\n'));
        assert_eq!(doc.trim_end_matches('\n'), plan.to_json().to_string());
    }
}
