//! Unified planner: one typed entry point for strategy search across the
//! model × topology × strategy space.
//!
//! The paper's core deliverable is a *decision procedure* — given a network
//! and a device budget, pick the DP/MP/hybrid configuration that minimises
//! end-to-end training time (Eq. 1: `C = T × S × E`).  Before this module,
//! that procedure lived as a dozen free functions that every entry point
//! re-wired by hand.  The planner is the façade:
//!
//! ```no_run
//! use hybridpar::planner::{PlanRequest, Planner};
//!
//! let planner = Planner::new();
//! let plan = planner
//!     .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
//!     .unwrap();
//! println!("{:?} — projected speedup {:.1}x", plan.strategy,
//!          plan.predicted_speedup);
//! println!("{}", plan.to_json()); // serialisable scorecard + curve
//! ```
//!
//! * [`PlanRequest`] — builder for the query (model, topology, device
//!   budget, objective, candidate MP degrees, batch override);
//! * [`Planner`] — holds a [`ModelRegistry`], a [`TopologyRegistry`] and a
//!   pluggable [`CostModel`]; [`Planner::plan`] runs the search;
//! * [`Plan`] — the typed answer: chosen [`Strategy`], predicted step
//!   time, epochs-to-converge, end-to-end speedup curve, placement /
//!   pipeline partition, per-candidate scorecard; round-trips through
//!   [`crate::util::json`].
//!
//! The candidate space covers both of the paper's MP mechanisms *per
//! degree*: the Table 1 structural default (DLPlacer placement for branchy
//! graphs, GPipe pipeline for chains) and an explicit
//! [`Strategy::PipelinedHybrid`] pipeline for every graph — so the
//! pipelined ConvNet hybrids a placement-only search never sees compete on
//! equal footing.  For grid evaluation over many
//! `(model × topology × batch × strategy-family)` scenarios, use the
//! work-sharing parallel [`sweep`] engine instead of calling
//! [`Planner::plan`] in a loop.

pub mod cost;
pub mod registry;
pub mod sweep;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

pub use cost::{cost_by_name, AlphaBetaCost, AnalyticalCost, CostModel,
               MpEstimate, MpMechanism, SimulatorCost};
pub use registry::{ModelEntry, ModelRegistry, TopologyEntry,
                   TopologyRegistry};

use crate::coordinator::Strategy;
use crate::parallel::NetworkModel;
use crate::util::json::Json;

/// What the planner optimises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimise projected time-to-converge (Eq. 1) — the paper's metric.
    TimeToConverge,
    /// Maximise per-step throughput, ignoring statistical efficiency.
    StepTime,
}

impl Objective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::TimeToConverge => "time-to-converge",
            Objective::StepTime => "step-time",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "time-to-converge" | "ttc" | "converge" => {
                Objective::TimeToConverge
            }
            "step-time" | "step" | "throughput" => Objective::StepTime,
            other => bail!("unknown objective '{other}' \
                            (known: time-to-converge, step-time)"),
        })
    }
}

/// A planner query, built fluently.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub model: String,
    pub topology: String,
    /// Device budget N (projections beyond the physical topology are
    /// allowed, as in the paper's 256-GPU sweeps from an 8-GPU box).
    pub devices: usize,
    /// Per-device mini-batch override (None = the registry default).
    pub batch: Option<usize>,
    pub objective: Objective,
    /// Candidate model-parallel widths M (> 1); DP-only (M = 1) is always
    /// considered.  Degrees other than 2 are analysed (scorecard + curve)
    /// but the chosen strategy is restricted to the runtime-executable
    /// M ∈ {1, 2} — the coordinator executes 2-stage pipelines.  A degree
    /// that is infeasible on the topology (more stages than ops or
    /// physical devices) drops out of the search rather than failing it.
    pub mp_degrees: Vec<usize>,
    /// Restrict M > 1 candidates to the pipelined mechanism (skip the
    /// structural DLPlacer default).  This is the sweep engine's
    /// "pipelined" strategy family; the default `false` scores both
    /// mechanisms per degree and keeps the better one.
    pub pipeline_only: bool,
    /// Upper bound of the speedup-curve sweep (powers of two).
    pub curve_max_devices: usize,
}

impl PlanRequest {
    pub fn new(model: &str, topology: &str) -> Self {
        PlanRequest {
            model: model.to_string(),
            topology: topology.to_string(),
            devices: 8,
            batch: None,
            objective: Objective::TimeToConverge,
            mp_degrees: vec![2],
            pipeline_only: false,
            curve_max_devices: 256,
        }
    }

    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n;
        self
    }

    pub fn batch(mut self, b: usize) -> Self {
        self.batch = Some(b);
        self
    }

    pub fn objective(mut self, o: Objective) -> Self {
        self.objective = o;
        self
    }

    pub fn mp_degrees(mut self, ms: &[usize]) -> Self {
        self.mp_degrees = ms.to_vec();
        self
    }

    pub fn pipeline_only(mut self, only: bool) -> Self {
        self.pipeline_only = only;
        self
    }

    pub fn curve_to(mut self, n: usize) -> Self {
        self.curve_max_devices = n;
        self
    }
}

/// One strategy candidate's score at the requested device budget.
///
/// A degree M > 1 can appear twice: once under its structural-default
/// mechanism and once as an explicit pipeline.  Rows are ordered best
/// first per degree, so `find(|c| c.mp_degree == m)` returns the candidate
/// that drives Eq. 5.
#[derive(Clone, Debug, PartialEq)]
pub struct CandidateScore {
    /// M (1 = DP-only).
    pub mp_degree: usize,
    /// SU^M — the M-way model-parallel step speedup of one worker under
    /// this row's mechanism.
    pub su_m: f64,
    /// N_dp = devices / M (0 when M does not divide the budget).
    pub dp_workers: usize,
    /// Emulated global batch N_dp × mini_batch.
    pub global_batch: usize,
    /// E(B) at that global batch (None = diverges).
    pub epochs: Option<f64>,
    /// Predicted per-step wall time including DP communication.
    pub step_time_s: Option<f64>,
    /// End-to-end speedup vs 1 device (Eq. 3/5; None = infeasible).
    pub speedup: Option<f64>,
    pub feasible: bool,
    /// "none" | "placed" | "pipelined".
    pub mechanism: String,
    /// Searched micro-batch count when pipelined.
    pub microbatches: Option<usize>,
    /// The strategy shape of this candidate at the requested budget
    /// ([`Strategy::PipelinedHybrid`] for pipelined rows).  Only
    /// meaningful when `feasible`: infeasible rows (M does not divide the
    /// budget) carry `dp_workers`/`replicas` of 0, which
    /// [`crate::coordinator::Coordinator::train`] rejects with an error.
    pub strategy: Strategy,
    pub note: String,
}

/// One point of the end-to-end speedup curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurvePoint {
    pub devices: usize,
    /// DP-only speedup (None = E(B) diverges).
    pub dp: Option<f64>,
    /// Best hybrid speedup over the candidate M > 1 degrees.
    pub hybrid: Option<f64>,
}

/// The planner's typed answer.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub model: String,
    pub topology: String,
    pub device_budget: usize,
    /// Devices the chosen strategy actually uses (≤ budget: when every
    /// strategy diverges at the full budget the planner backs off, as the
    /// paper does for BigLSTM).
    pub devices_used: usize,
    pub mini_batch: usize,
    pub global_batch: usize,
    pub cost_model: String,
    pub objective: Objective,
    /// The chosen runtime strategy.
    pub strategy: Strategy,
    /// M of the chosen strategy (1 = DP-only).
    pub mp_degree: usize,
    pub dp_workers: usize,
    /// "none" | "placed" | "pipelined".
    pub mechanism: String,
    pub microbatches: Option<usize>,
    /// Predicted per-step wall time of the chosen strategy (seconds).
    pub predicted_step_s: f64,
    /// Predicted epochs-to-converge at the chosen global batch.
    pub predicted_epochs: Option<f64>,
    /// Predicted end-to-end speedup vs 1 device (under
    /// [`Objective::StepTime`], the step-rate speedup instead).
    pub predicted_speedup: f64,
    /// Eq. 6 tipping point: device count where the first hybrid degree
    /// overtakes DP-only.
    pub crossover_devices: Option<usize>,
    /// Op → device assignment when the chosen MP mechanism is "placed".
    pub placement: Option<Vec<usize>>,
    /// Stage bounds when the chosen MP mechanism is "pipelined".
    pub pipeline_bounds: Option<Vec<usize>>,
    pub scorecard: Vec<CandidateScore>,
    pub curve: Vec<CurvePoint>,
}

/// The planner: registries + a pluggable cost model.
pub struct Planner {
    models: ModelRegistry,
    topologies: TopologyRegistry,
    cost: Box<dyn CostModel>,
}

impl Default for Planner {
    fn default() -> Self {
        Planner::new()
    }
}

impl Planner {
    /// Built-in registries, analytical (Eq. 1–6) cost model.
    pub fn new() -> Self {
        Planner::with_cost(Box::new(AnalyticalCost::default()))
    }

    /// Built-in registries, caller-chosen cost model.
    pub fn with_cost(cost: Box<dyn CostModel>) -> Self {
        Planner {
            models: ModelRegistry::builtin(),
            topologies: TopologyRegistry::builtin(),
            cost,
        }
    }

    /// Fully custom construction.
    pub fn with_parts(models: ModelRegistry, topologies: TopologyRegistry,
                      cost: Box<dyn CostModel>) -> Self {
        Planner { models, topologies, cost }
    }

    pub fn models(&self) -> &ModelRegistry {
        &self.models
    }

    pub fn models_mut(&mut self) -> &mut ModelRegistry {
        &mut self.models
    }

    pub fn topologies(&self) -> &TopologyRegistry {
        &self.topologies
    }

    pub fn topologies_mut(&mut self) -> &mut TopologyRegistry {
        &mut self.topologies
    }

    pub fn cost(&self) -> &dyn CostModel {
        self.cost.as_ref()
    }

    /// Run the strategy search: score DP-only (Eq. 3) against every
    /// requested hybrid degree (Eq. 5) — placed and pipelined mechanisms
    /// both — under the Eq. 1 time-to-converge objective, and return the
    /// typed [`Plan`].
    ///
    /// ```
    /// use hybridpar::planner::{PlanRequest, Planner};
    ///
    /// let planner = Planner::new(); // Eq. 1–6 analytical cost model
    /// let plan = planner
    ///     .plan(&PlanRequest::new("gnmt", "dgx1").devices(8))
    ///     .unwrap();
    /// assert_eq!(plan.mp_degree, 1, "DP-only wins at small scale (Eq. 6)");
    /// // Every M > 1 candidate was still scored — GNMT's chain DFG makes
    /// // them PipelinedHybrid candidates in the scorecard.
    /// assert!(plan.scorecard.iter().any(|c| c.mechanism == "pipelined"));
    /// ```
    pub fn plan(&self, req: &PlanRequest) -> Result<Plan> {
        if req.devices == 0 {
            bail!("device budget must be >= 1");
        }
        let prof = self.models.build(&req.model, req.batch)?;
        let hw = self.topologies.build(&req.topology, req.devices)?;

        // Candidate MP degrees: {1} ∪ requested (deduplicated, > 1).
        let mut degrees: Vec<usize> = req
            .mp_degrees
            .iter()
            .copied()
            .filter(|&m| m > 1)
            .collect();
        degrees.sort_unstable();
        degrees.dedup();

        // Per-degree worker estimates from the cost model.  Each M > 1 is
        // scored under its Table 1 structural default (placed / pipelined)
        // AND as an explicit GPipe pipeline over the topo linearisation;
        // the faster one drives Eq. 5 and the runner-up stays in the
        // scorecard.  `pipeline_only` requests skip the structural default.
        let serial = self.cost.mp_step_time(&prof, &hw, 1)?.step_time_s;
        let mut estimates: BTreeMap<usize, MpEstimate> = BTreeMap::new();
        let mut alt_estimates: BTreeMap<usize, MpEstimate> = BTreeMap::new();
        let mut mp_speedups: Vec<(usize, f64)> = Vec::new();
        // A degree whose estimation is infeasible on this topology (more
        // stages than ops or physical devices) drops out of the search
        // instead of failing the plan — M > 1 candidates are analysis
        // material, and the M = 1 baseline above still surfaces real cost
        // model failures.
        for &m in &degrees {
            let default = if req.pipeline_only {
                None
            } else {
                self.cost.mp_step_time(&prof, &hw, m).ok()
            };
            let (best, alt) = match default {
                // The structural default *is* the pipeline: one candidate.
                Some(d) if d.mechanism == MpMechanism::Pipelined => {
                    (d, None)
                }
                Some(d) => {
                    match self.cost.pipelined_mp_step_time(&prof, &hw, m) {
                        Ok(p) if p.step_time_s < d.step_time_s => {
                            (p, Some(d))
                        }
                        Ok(p) => (d, Some(p)),
                        Err(_) => (d, None),
                    }
                }
                // pipeline_only, or the structural default itself was
                // infeasible: the explicit pipeline is the only candidate.
                None => {
                    match self.cost.pipelined_mp_step_time(&prof, &hw, m) {
                        Ok(p) => (p, None),
                        Err(_) => continue,
                    }
                }
            };
            mp_speedups.push((m, serial / best.step_time_s));
            estimates.insert(m, best);
            if let Some(a) = alt {
                alt_estimates.insert(m, a);
            }
        }
        // Degrees that survived estimation (pipeline-only may drop some).
        let degrees: Vec<usize> = estimates.keys().copied().collect();
        let se = self.cost.scaling(&prof, &hw, serial, req.devices);
        let net = NetworkModel {
            name: prof.name.clone(),
            epochs: prof.epochs.clone(),
            mini_batch: prof.mini_batch,
            se,
            mp_speedups,
        };

        // Runtime-executable MP widths: the coordinator executes 2-stage
        // pipelines ([`Strategy::Hybrid`] / [`Strategy::PipelinedHybrid`]
        // with `stages == 2`), so only M ∈ {1, 2} maps onto a runnable
        // strategy.  Wider requested degrees still appear in the scorecard
        // and speedup curve for analysis, but the *chosen* strategy is
        // restricted to what the runtime can execute.
        let exec_net = NetworkModel {
            mp_speedups: net
                .mp_speedups
                .iter()
                .copied()
                .filter(|&(m, _)| m == 2)
                .collect(),
            ..net.clone()
        };
        let exec_ms: Vec<usize> = std::iter::once(1)
            .chain(exec_net.mp_speedups.iter().map(|&(m, _)| m))
            .collect();

        // --- selection ---------------------------------------------------
        let (chosen_m, devices_used, chosen_score) = match req.objective {
            Objective::TimeToConverge => {
                match exec_net.best_strategy(req.devices) {
                    Some((m, su)) => (m, req.devices, su),
                    None => self
                        .back_off(&exec_net, req.devices)
                        .ok_or_else(|| anyhow!(
                            "no strategy converges for '{}' at any device \
                             count <= {}", prof.name, req.devices))?,
                }
            }
            Objective::StepTime => {
                // Step-rate score: SU^M × N_dp × SE(N_dp), no E(B) term.
                let mut best: Option<(usize, usize, f64)> = None;
                for &m in &exec_ms {
                    if req.devices % m != 0 {
                        continue;
                    }
                    let n_dp = req.devices / m;
                    let su_m = net.su_m(m).unwrap_or(1.0);
                    let score = su_m * n_dp as f64 * net.se.at(n_dp);
                    if best.map_or(true, |(_, _, b)| score > b) {
                        best = Some((m, req.devices, score));
                    }
                }
                best.ok_or_else(|| anyhow!("no feasible strategy"))?
            }
        };
        let n_dp = devices_used / chosen_m.max(1);
        let global_batch = n_dp * prof.mini_batch;
        let chosen_su_m = net.su_m(chosen_m).unwrap_or(1.0);
        let step_worker = serial / chosen_su_m;
        let predicted_step_s = step_worker / net.se.at(n_dp).max(1e-12);
        let predicted_epochs = net.epochs.epochs(global_batch as f64);

        let chosen_est = estimates.get(&chosen_m);
        let mechanism = chosen_est
            .map(|e| e.mechanism)
            .unwrap_or(MpMechanism::None);
        let strategy = if devices_used == 1 {
            Strategy::Single
        } else if chosen_m <= 1 {
            Strategy::DataParallel { workers: devices_used,
                                     delayed_factor: 1 }
        } else {
            // Pipelined estimates carry their searched micro-batch count;
            // placed (DLPlacer) estimates don't, and a 1-micro-batch
            // runtime pipeline is degenerate — default to 2.
            let microbatches =
                chosen_est.and_then(|e| e.microbatches).unwrap_or(2);
            if mechanism == MpMechanism::Pipelined {
                Strategy::PipelinedHybrid {
                    stages: chosen_m,
                    microbatches,
                    replicas: n_dp,
                }
            } else {
                Strategy::Hybrid { dp_workers: n_dp, microbatches }
            }
        };

        // --- scorecard ---------------------------------------------------
        // One row per (degree, mechanism): best mechanism first per degree
        // (it is the one Eq. 5 used), the runner-up after it for analysis.
        let mut scorecard = Vec::new();
        let mut push_row = |m: usize, su_row: f64,
                            est: Option<&MpEstimate>| {
            let divides = req.devices % m == 0;
            let nd = if divides { req.devices / m } else { 0 };
            let b = nd * prof.mini_batch;
            let epochs =
                if divides { net.epochs.epochs(b as f64) } else { None };
            let speedup = if !divides {
                None
            } else if m == 1 {
                net.su_dp(req.devices)
            } else {
                // Eq. 5 with this row's own SU^M (the runner-up mechanism
                // scores lower than `net.su_hybrid` by construction).
                net.epochs
                    .efficiency_ratio(b as f64)
                    .map(|r| su_row * net.se.at(nd) * nd as f64 * r)
            };
            let step_time_s = if divides {
                Some((serial / su_row) / net.se.at(nd).max(1e-12))
            } else {
                None
            };
            let row_mechanism =
                est.map(|e| e.mechanism).unwrap_or(MpMechanism::None);
            let microbatches = est.and_then(|e| e.microbatches);
            let strategy = if m == 1 {
                if req.devices == 1 {
                    Strategy::Single
                } else {
                    Strategy::DataParallel { workers: req.devices,
                                             delayed_factor: 1 }
                }
            } else if row_mechanism == MpMechanism::Pipelined {
                Strategy::PipelinedHybrid {
                    stages: m,
                    microbatches: microbatches.unwrap_or(2),
                    replicas: nd,
                }
            } else {
                Strategy::Hybrid { dp_workers: nd,
                                   microbatches: microbatches.unwrap_or(2) }
            };
            let note = if !divides {
                format!("M={m} does not divide the {}-device budget",
                        req.devices)
            } else if epochs.is_none() {
                format!("E(B) diverges at global batch {b}")
            } else {
                String::new()
            };
            scorecard.push(CandidateScore {
                mp_degree: m,
                su_m: su_row,
                dp_workers: nd,
                global_batch: b,
                epochs,
                step_time_s,
                speedup,
                feasible: speedup.is_some(),
                mechanism: row_mechanism.as_str().to_string(),
                microbatches,
                strategy,
                note,
            });
        };
        push_row(1, 1.0, None);
        for (&m, best) in &estimates {
            push_row(m, serial / best.step_time_s, Some(best));
            if let Some(alt) = alt_estimates.get(&m) {
                push_row(m, serial / alt.step_time_s, Some(alt));
            }
        }

        // --- end-to-end speedup curve ------------------------------------
        let mut curve = Vec::new();
        let mut n = 1usize;
        while n <= req.curve_max_devices {
            let hybrid = degrees
                .iter()
                .filter_map(|&m| net.su_hybrid(n, m))
                .fold(None::<f64>, |acc, v| {
                    Some(acc.map_or(v, |a| a.max(v)))
                });
            curve.push(CurvePoint { devices: n, dp: net.su_dp(n), hybrid });
            n *= 2;
        }
        let crossover_devices = degrees
            .first()
            .and_then(|&m| net.crossover_point(m, req.curve_max_devices));

        Ok(Plan {
            model: prof.name.clone(),
            topology: req.topology.clone(),
            device_budget: req.devices,
            devices_used,
            mini_batch: prof.mini_batch,
            global_batch,
            cost_model: self.cost.name().to_string(),
            objective: req.objective,
            strategy,
            mp_degree: chosen_m,
            dp_workers: n_dp,
            mechanism: mechanism.as_str().to_string(),
            microbatches: chosen_est.and_then(|e| e.microbatches),
            predicted_step_s,
            predicted_epochs,
            predicted_speedup: chosen_score,
            crossover_devices,
            placement: chosen_est.and_then(|e| e.placement.clone()),
            pipeline_bounds: chosen_est
                .and_then(|e| e.pipeline_bounds.clone()),
            scorecard,
            curve,
        })
    }

    /// When every strategy diverges at the full budget, halve the device
    /// count until something converges (the paper's BigLSTM regime, where
    /// the best configuration uses fewer devices than are available).
    fn back_off(&self, net: &NetworkModel, budget: usize)
                -> Option<(usize, usize, f64)> {
        let mut n = budget / 2;
        while n >= 1 {
            if let Some((m, su)) = net.best_strategy(n) {
                return Some((m, n, su));
            }
            n /= 2;
        }
        None
    }
}

// ==========================================================================
// JSON (de)serialisation via util::json
// ==========================================================================

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

fn junum(x: usize) -> Json {
    Json::Num(x as f64)
}

fn jonum(x: Option<f64>) -> Json {
    x.map(Json::Num).unwrap_or(Json::Null)
}

fn jounum(x: Option<usize>) -> Json {
    x.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null)
}

pub(crate) fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(v.as_f64()?)),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    Ok(opt_f64(j, key)?.map(|v| v as usize))
}

fn opt_usize_arr(j: &Json, key: &str) -> Result<Option<Vec<usize>>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?,
        )),
    }
}

/// Serialise a [`Strategy`] to a tagged JSON object (the tag is
/// [`Strategy::kind`], shared with the sweep CSV).
pub fn strategy_to_json(s: &Strategy) -> Json {
    let kind = Json::Str(s.kind().into());
    match *s {
        Strategy::Single => jobj(vec![("kind", kind)]),
        Strategy::DataParallel { workers, delayed_factor } => jobj(vec![
            ("kind", kind),
            ("workers", junum(workers)),
            ("delayed_factor", junum(delayed_factor)),
        ]),
        Strategy::Hybrid { dp_workers, microbatches } => jobj(vec![
            ("kind", kind),
            ("dp_workers", junum(dp_workers)),
            ("microbatches", junum(microbatches)),
        ]),
        Strategy::PipelinedHybrid { stages, microbatches, replicas } => {
            jobj(vec![
                ("kind", kind),
                ("stages", junum(stages)),
                ("microbatches", junum(microbatches)),
                ("replicas", junum(replicas)),
            ])
        }
        Strategy::AsyncPs { workers, staleness } => jobj(vec![
            ("kind", kind),
            ("workers", junum(workers)),
            ("staleness", junum(staleness)),
        ]),
        Strategy::LocalSgd { workers, sync_every } => jobj(vec![
            ("kind", kind),
            ("workers", junum(workers)),
            ("sync_every", junum(sync_every)),
        ]),
    }
}

/// Parse a [`Strategy`] from its tagged JSON object.
pub fn strategy_from_json(j: &Json) -> Result<Strategy> {
    let kind = j.get("kind")?.as_str()?;
    Ok(match kind {
        "single" => Strategy::Single,
        "data-parallel" => Strategy::DataParallel {
            workers: j.get("workers")?.as_usize()?,
            delayed_factor: j.get("delayed_factor")?.as_usize()?,
        },
        "hybrid" => Strategy::Hybrid {
            dp_workers: j.get("dp_workers")?.as_usize()?,
            microbatches: j.get("microbatches")?.as_usize()?,
        },
        "pipelined-hybrid" => Strategy::PipelinedHybrid {
            stages: j.get("stages")?.as_usize()?,
            microbatches: j.get("microbatches")?.as_usize()?,
            replicas: j.get("replicas")?.as_usize()?,
        },
        "async-ps" => Strategy::AsyncPs {
            workers: j.get("workers")?.as_usize()?,
            staleness: j.get("staleness")?.as_usize()?,
        },
        "local-sgd" => Strategy::LocalSgd {
            workers: j.get("workers")?.as_usize()?,
            sync_every: j.get("sync_every")?.as_usize()?,
        },
        other => bail!("unknown strategy kind '{other}'"),
    })
}

impl CandidateScore {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("mp_degree", junum(self.mp_degree)),
            ("su_m", jnum(self.su_m)),
            ("dp_workers", junum(self.dp_workers)),
            ("global_batch", junum(self.global_batch)),
            ("epochs", jonum(self.epochs)),
            ("step_time_s", jonum(self.step_time_s)),
            ("speedup", jonum(self.speedup)),
            ("feasible", Json::Bool(self.feasible)),
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("microbatches", jounum(self.microbatches)),
            ("strategy", strategy_to_json(&self.strategy)),
            ("note", Json::Str(self.note.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(CandidateScore {
            mp_degree: j.get("mp_degree")?.as_usize()?,
            su_m: j.get("su_m")?.as_f64()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            epochs: opt_f64(j, "epochs")?,
            step_time_s: opt_f64(j, "step_time_s")?,
            speedup: opt_f64(j, "speedup")?,
            feasible: matches!(j.get("feasible")?, Json::Bool(true)),
            mechanism: j.get("mechanism")?.as_str()?.to_string(),
            microbatches: opt_usize(j, "microbatches")?,
            strategy: strategy_from_json(j.get("strategy")?)?,
            note: j.get("note")?.as_str()?.to_string(),
        })
    }
}

impl CurvePoint {
    fn to_json(&self) -> Json {
        jobj(vec![
            ("devices", junum(self.devices)),
            ("dp", jonum(self.dp)),
            ("hybrid", jonum(self.hybrid)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(CurvePoint {
            devices: j.get("devices")?.as_usize()?,
            dp: opt_f64(j, "dp")?,
            hybrid: opt_f64(j, "hybrid")?,
        })
    }
}

impl Plan {
    /// Serialise the full plan (scorecard and curve included).
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("model", Json::Str(self.model.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("device_budget", junum(self.device_budget)),
            ("devices_used", junum(self.devices_used)),
            ("mini_batch", junum(self.mini_batch)),
            ("global_batch", junum(self.global_batch)),
            ("cost_model", Json::Str(self.cost_model.clone())),
            ("objective", Json::Str(self.objective.as_str().into())),
            ("strategy", strategy_to_json(&self.strategy)),
            ("mp_degree", junum(self.mp_degree)),
            ("dp_workers", junum(self.dp_workers)),
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("microbatches", jounum(self.microbatches)),
            ("predicted_step_s", jnum(self.predicted_step_s)),
            ("predicted_epochs", jonum(self.predicted_epochs)),
            ("predicted_speedup", jnum(self.predicted_speedup)),
            ("crossover_devices",
             self.crossover_devices
                 .map(|v| Json::Num(v as f64))
                 .unwrap_or(Json::Null)),
            ("placement",
             self.placement
                 .as_ref()
                 .map(|p| Json::Arr(
                     p.iter().map(|&d| Json::Num(d as f64)).collect()))
                 .unwrap_or(Json::Null)),
            ("pipeline_bounds",
             self.pipeline_bounds
                 .as_ref()
                 .map(|p| Json::Arr(
                     p.iter().map(|&d| Json::Num(d as f64)).collect()))
                 .unwrap_or(Json::Null)),
            ("scorecard",
             Json::Arr(self.scorecard.iter().map(|c| c.to_json()).collect())),
            ("curve",
             Json::Arr(self.curve.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// Reconstruct a plan from [`Plan::to_json`] output.
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Plan {
            model: j.get("model")?.as_str()?.to_string(),
            topology: j.get("topology")?.as_str()?.to_string(),
            device_budget: j.get("device_budget")?.as_usize()?,
            devices_used: j.get("devices_used")?.as_usize()?,
            mini_batch: j.get("mini_batch")?.as_usize()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            cost_model: j.get("cost_model")?.as_str()?.to_string(),
            objective: Objective::parse(j.get("objective")?.as_str()?)?,
            strategy: strategy_from_json(j.get("strategy")?)?,
            mp_degree: j.get("mp_degree")?.as_usize()?,
            dp_workers: j.get("dp_workers")?.as_usize()?,
            mechanism: j.get("mechanism")?.as_str()?.to_string(),
            microbatches: opt_usize(j, "microbatches")?,
            predicted_step_s: j.get("predicted_step_s")?.as_f64()?,
            predicted_epochs: opt_f64(j, "predicted_epochs")?,
            predicted_speedup: j.get("predicted_speedup")?.as_f64()?,
            crossover_devices: opt_usize(j, "crossover_devices")?,
            placement: opt_usize_arr(j, "placement")?,
            pipeline_bounds: opt_usize_arr(j, "pipeline_bounds")?,
            scorecard: j
                .get("scorecard")?
                .as_arr()?
                .iter()
                .map(CandidateScore::from_json)
                .collect::<Result<Vec<_>>>()?,
            curve: j
                .get("curve")?
                .as_arr()?
                .iter()
                .map(CurvePoint::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Human-readable multi-line summary for CLIs and examples.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "plan: {} on {} (budget {} devices, objective {}, cost {})\n",
            self.model, self.topology, self.device_budget,
            self.objective.as_str(), self.cost_model));
        s.push_str(&format!(
            "  chosen: {:?} — M={} x N_dp={} ({} devices used, \
             mechanism {})\n",
            self.strategy, self.mp_degree, self.dp_workers,
            self.devices_used, self.mechanism));
        s.push_str(&format!(
            "  predicted: step {:.3} ms, epochs {}, end-to-end speedup \
             {:.2}x vs 1 device\n",
            self.predicted_step_s * 1e3,
            self.predicted_epochs
                .map(|e| format!("{e:.1}"))
                .unwrap_or_else(|| "-".into()),
            self.predicted_speedup));
        match self.crossover_devices {
            Some(x) => s.push_str(&format!(
                "  Eq. 6 crossover: hybrid overtakes DP-only at {x} \
                 devices\n")),
            None => s.push_str("  Eq. 6 crossover: none in sweep range\n"),
        }
        for c in &self.scorecard {
            s.push_str(&format!(
                "  candidate M={}: SU^M {:.3}, speedup {}{}\n",
                c.mp_degree, c.su_m,
                c.speedup
                    .map(|v| format!("{v:.2}x"))
                    .unwrap_or_else(|| "-".into()),
                if c.note.is_empty() {
                    String::new()
                } else {
                    format!(" ({})", c.note)
                }));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_only_wins_at_small_scale() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
            .unwrap();
        assert_eq!(plan.mp_degree, 1, "DP-only at 8 devices");
        assert_eq!(plan.strategy,
                   Strategy::DataParallel { workers: 8, delayed_factor: 1 });
        assert!((plan.predicted_speedup - 8.0).abs() < 1e-6,
                "flat E(B) region: SU = N, got {}", plan.predicted_speedup);
        assert_eq!(plan.devices_used, 8);
        assert_eq!(plan.global_batch, 8 * 32);
    }

    #[test]
    fn hybrid_wins_at_scale_for_gnmt() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(256))
            .unwrap();
        assert_eq!(plan.mp_degree, 2, "paper: hybrid wins at 256 GPUs");
        assert!(matches!(plan.strategy,
                         Strategy::PipelinedHybrid { stages: 2,
                                                     replicas: 128, .. }),
                "chain MP is the runtime-executable 2-stage pipeline: {:?}",
                plan.strategy);
        assert_eq!(plan.mechanism, "pipelined");
        assert!(plan.pipeline_bounds.is_some());
        assert!(plan.crossover_devices.is_some());
    }

    #[test]
    fn scorecard_considers_pipelined_hybrids_for_every_paper_network() {
        // The acceptance bar of the pipelined-search change: branchy
        // Inception included, every paper network's plan weighs at least
        // one PipelinedHybrid candidate.
        let planner = Planner::new();
        for model in ["inception-v3", "gnmt", "biglstm"] {
            let plan = planner
                .plan(&PlanRequest::new(model, "dgx1").devices(8))
                .unwrap();
            let pipelined: Vec<&CandidateScore> = plan
                .scorecard
                .iter()
                .filter(|c| matches!(c.strategy,
                                     Strategy::PipelinedHybrid { .. }))
                .collect();
            assert!(!pipelined.is_empty(),
                    "{model}: no PipelinedHybrid candidate in scorecard");
            for c in pipelined {
                assert_eq!(c.mechanism, "pipelined");
                assert!(c.microbatches.unwrap_or(0) >= 1);
                assert!(c.su_m > 0.0);
            }
        }
    }

    #[test]
    fn pipeline_only_requests_skip_the_placer() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1")
                .devices(8)
                .pipeline_only(true))
            .unwrap();
        for c in plan.scorecard.iter().filter(|c| c.mp_degree > 1) {
            assert_eq!(c.mechanism, "pipelined",
                       "pipeline_only must not place: {c:?}");
        }
    }

    #[test]
    fn infeasible_degrees_drop_out_instead_of_failing() {
        // GNMT has 11 ops: a 64-stage pipeline cannot exist.  Any search
        // mode must keep the valid M=2 candidate and drop M=64, not error
        // out — including the simulator, which refuses pipelines deeper
        // than the physical box.
        for (pipeline_only, cost) in [
            (true, None),
            (false, None),
            (false, Some(cost_by_name("simulator").unwrap())),
        ] {
            let planner = match cost {
                Some(c) => Planner::with_cost(c),
                None => Planner::new(),
            };
            let plan = planner
                .plan(&PlanRequest::new("gnmt", "dgx1")
                    .devices(8)
                    .mp_degrees(&[2, 64])
                    .pipeline_only(pipeline_only))
                .unwrap();
            assert!(plan.scorecard.iter().any(|c| c.mp_degree == 2),
                    "pipeline_only={pipeline_only}");
            assert!(plan.scorecard.iter().all(|c| c.mp_degree != 64),
                    "pipeline_only={pipeline_only}");
        }
    }

    #[test]
    fn best_mechanism_leads_each_degree_in_the_scorecard() {
        // When both mechanisms are scored for a degree, the first row is
        // the one Eq. 5 used — i.e. the lower per-worker step time / the
        // higher SU^M.
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("inception-v3", "dgx1").devices(8))
            .unwrap();
        let rows: Vec<&CandidateScore> = plan
            .scorecard
            .iter()
            .filter(|c| c.mp_degree == 2)
            .collect();
        assert_eq!(rows.len(), 2,
                   "branchy graph: placed + pipelined rows expected");
        assert!(rows[0].su_m >= rows[1].su_m,
                "best-first ordering violated: {} < {}",
                rows[0].su_m, rows[1].su_m);
    }

    #[test]
    fn biglstm_backs_off_when_everything_diverges() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("biglstm", "dgx1").devices(256))
            .unwrap();
        assert!(plan.devices_used < 256,
                "must back off below the divergence ceiling");
        assert!(plan.predicted_epochs.is_some());
    }

    #[test]
    fn single_device_budget() {
        let planner = Planner::new();
        let plan = planner
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(1))
            .unwrap();
        assert_eq!(plan.strategy, Strategy::Single);
        assert_eq!(plan.mp_degree, 1);
    }

    #[test]
    fn step_time_objective_ignores_epochs() {
        let planner = Planner::new();
        // BigLSTM at 64 devices: DP diverges statistically, but pure
        // throughput doesn't care.
        let plan = planner
            .plan(&PlanRequest::new("biglstm", "dgx1")
                .devices(64)
                .objective(Objective::StepTime))
            .unwrap();
        assert_eq!(plan.devices_used, 64);
        assert_eq!(plan.objective, Objective::StepTime);
    }

    #[test]
    fn unknown_names_error() {
        let planner = Planner::new();
        assert!(planner
            .plan(&PlanRequest::new("alexnet", "dgx1"))
            .is_err());
        assert!(planner
            .plan(&PlanRequest::new("gnmt", "ringworld"))
            .is_err());
    }

    #[test]
    fn strategy_json_round_trip() {
        for s in [
            Strategy::Single,
            Strategy::DataParallel { workers: 8, delayed_factor: 2 },
            Strategy::Hybrid { dp_workers: 4, microbatches: 8 },
            Strategy::PipelinedHybrid { stages: 4, microbatches: 8,
                                        replicas: 16 },
            Strategy::AsyncPs { workers: 3, staleness: 2 },
            Strategy::LocalSgd { workers: 4, sync_every: 16 },
        ] {
            let j = strategy_to_json(&s);
            let text = j.to_string();
            let back =
                strategy_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(s, back);
        }
    }

    #[test]
    fn objective_parse_round_trip() {
        for o in [Objective::TimeToConverge, Objective::StepTime] {
            assert_eq!(Objective::parse(o.as_str()).unwrap(), o);
        }
        assert!(Objective::parse("fastest").is_err());
    }
}
