//! Parallel scenario sweep engine: evaluate a
//! `(model × topology × device-budget × nodes × device-memory ×
//! global-batch × strategy-family)` grid of planner queries across worker
//! threads.
//!
//! The ROADMAP's scenario-diversity goal does not fit one
//! [`Planner::plan`] call at a time: the fig3/fig5 grids alone are dozens
//! of `(model, topology, batch)` points, and every point re-derives the
//! same SU^M (Eq. 5) inputs.  This module adds
//!
//! * [`parallel_map`] — a work-sharing `std::thread` pool (scoped threads +
//!   an atomic work index + a channel) with **deterministic ordering**:
//!   results land by input index, so `threads = N` produces byte-identical
//!   output to `threads = 1`;
//! * a memoising [`CostModel`] wrapper, so per-candidate cost evaluations
//!   (one DLPlacer ILP or GPipe search per `(model, batch, topology, M)`)
//!   run once per grid, not once per scenario;
//! * [`SweepSpec`] / [`run_sweep`] — the typed grid description and its
//!   evaluator, returning a [`SweepResult`] that serialises to JSON
//!   ([`SweepResult::to_json`]) and CSV ([`SweepResult::to_csv`]).
//!
//! Exposed on the CLI as the `sweep` subcommand and configurable through
//! the `[sweep]` section of a run config.
//!
//! ```
//! use hybridpar::planner::sweep::{run_sweep, StrategyFamily, SweepSpec};
//!
//! let spec = SweepSpec {
//!     models: vec!["gnmt".into()],
//!     devices: vec![8],
//!     families: vec![StrategyFamily::DpOnly],
//!     curve_max_devices: 8,
//!     threads: 1,
//!     ..Default::default()
//! };
//! let result = run_sweep(&spec).unwrap();
//! assert_eq!(result.len(), 1);
//! assert_eq!(result.results[0].plan.as_ref().unwrap().mp_degree, 1);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::cost::{cost_by_name, CostModel, MpEstimate};
use crate::cluster::HwGraph;
use crate::collective::Algorithm;
use crate::memory::{MemoryModel, ZeroMode};
use crate::models::ModelProfile;
use crate::parallel::overlap::OverlapModel;
use crate::parallel::ScalingEfficiency;
use crate::util::json::Json;

use super::{jobj, Objective, Plan, PlanMechanism, PlanRequest, Planner};

// ==========================================================================
// Work-sharing parallel evaluator
// ==========================================================================

/// Number of workers actually used for `requested` threads over `items`
/// work items (0 = one per available core, always clamped to the item
/// count and at least 1).
pub fn effective_threads(requested: usize, items: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.clamp(1, items.max(1))
}

/// Evaluate `f(i, &items[i])` for every item on a pool of scoped worker
/// threads and return the results **in input order** — the scheduling is
/// dynamic (workers pull the next index from a shared atomic counter, so a
/// slow scenario does not idle the other workers), but the output is
/// independent of thread count and interleaving.  `threads == 0` uses one
/// worker per available core; `threads == 1` degenerates to a plain serial
/// map with no thread machinery at all.
pub fn parallel_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n_workers = effective_threads(threads, items.len());
    if n_workers <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut slots: Vec<Option<R>> = Vec::new();
    slots.resize_with(items.len(), || None);
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, r) in rx.iter() {
            slots[i] = Some(r);
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("sweep worker exited before finishing its items"))
        .collect()
}

// ==========================================================================
// Memoising cost model
// ==========================================================================

/// Cache key for one per-candidate cost evaluation: the profile identity
/// (name + mini-batch), the hardware identity (name + device count +
/// chassis count + per-device memory bits — the `device_mem_gb` axis
/// rebuilds the same topology with different capacities, which changes
/// stage partitions, and the `nodes` axis rebuilds it with different
/// chassis counts), the mechanism family (structural default vs explicit
/// pipeline) and M.
///
/// The `overlap`/`compression` axes deliberately contribute **no** key
/// bits: the memoised quantity is the MP step-time estimate, which prices
/// model-parallel compute and activation traffic only.  The overlapped
/// gradient exchange is charged in `ScalingEfficiency` (which
/// [`CostModel::scaling`] rebuilds per scenario, un-memoised), so two
/// scenarios differing only in overlap share their MP estimates *and*
/// still get distinct step times — asserted by the
/// `overlap_axes_expand_the_grid` test below.
type MemoKey = (String, usize, String, usize, usize, u64, bool, usize);

/// A memoised evaluation outcome (errors stringified so the cell clones).
type StoredEstimate = std::result::Result<MpEstimate, String>;

/// Transparent memoising wrapper: identical `(model, batch, topology, M)`
/// candidate evaluations — the expensive DLPlacer ILPs and GPipe
/// micro-batch searches — are computed once per sweep and shared across
/// scenarios and worker threads.  Each key owns a [`OnceLock`] cell, so
/// concurrent workers missing on the same key block on one computation
/// instead of duplicating it; the map lock itself is only held for the
/// cheap entry lookup.  Results are bit-identical to the inner model's
/// (the inner evaluation is deterministic), so memoisation cannot perturb
/// sweep output.
struct MemoCost {
    inner: Arc<dyn CostModel>,
    cache: Mutex<HashMap<MemoKey, Arc<OnceLock<StoredEstimate>>>>,
}

impl MemoCost {
    fn new(inner: Arc<dyn CostModel>) -> Self {
        MemoCost { inner, cache: Mutex::new(HashMap::new()) }
    }

    fn cached<F>(&self, pipelined: bool, prof: &ModelProfile, hw: &HwGraph,
                 m: usize, compute: F) -> Result<MpEstimate>
    where
        F: FnOnce() -> Result<MpEstimate>,
    {
        let key = (prof.name.clone(), prof.mini_batch, hw.name.clone(),
                   hw.n_devices(), hw.node_groups().len(),
                   hw.min_device_mem().to_bits(), pipelined, m);
        let cell = self
            .cache
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::new(OnceLock::new()))
            .clone();
        cell.get_or_init(|| match compute() {
            Ok(v) => Ok(v),
            Err(e) => Err(format!("{e:#}")),
        })
        .clone()
        .map_err(|e| anyhow!(e))
    }
}

impl CostModel for MemoCost {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                    -> Result<MpEstimate> {
        self.cached(false, prof, hw, m,
                    || self.inner.mp_step_time(prof, hw, m))
    }

    fn pipelined_mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph,
                              stages: usize) -> Result<MpEstimate> {
        self.cached(true, prof, hw, stages,
                    || self.inner.pipelined_mp_step_time(prof, hw, stages))
    }

    fn scaling(&self, prof: &ModelProfile, hw: &HwGraph,
               step_compute_s: f64, devices: usize) -> ScalingEfficiency {
        self.inner.scaling(prof, hw, step_compute_s, devices)
    }

    fn op_time_params(&self) -> (f64, f64) {
        // The layer-wise search prices per-op compute with these; masking
        // the inner model's Δ(k) parameters would silently change sweeps.
        self.inner.op_time_params()
    }
}

// ==========================================================================
// Grid description
// ==========================================================================

/// One axis value of the global-batch dimension.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BatchSpec {
    /// The registry's per-model default mini-batch.
    Default,
    /// A fixed per-device mini-batch for every model.
    Fixed(usize),
    /// The paper's §4.2 epoch-count-methodology mini-batches (Inception-V3
    /// 64, GNMT 128, BigLSTM 64); other models fall back to their registry
    /// default.  This is the fig5 grid's batch axis.
    Paper,
}

impl BatchSpec {
    /// Parse an axis entry: `"default"`, `"paper"`, or an integer.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "default" => BatchSpec::Default,
            "paper" => BatchSpec::Paper,
            n => BatchSpec::Fixed(n.parse::<usize>().map_err(|_| {
                anyhow!("bad batch spec '{n}' \
                         (expected 'default', 'paper' or an integer)")
            })?),
        })
    }

    /// The per-device mini-batch override for `model` (None = registry
    /// default).  `model` is the *canonical* registry name — callers
    /// resolve aliases via
    /// [`ModelRegistry::canonical_name`](super::ModelRegistry::canonical_name)
    /// first (as [`run_sweep`] does), so the paper table is keyed off one
    /// spelling instead of mirroring the registry's alias lists.
    pub fn resolve(&self, model: &str) -> Option<usize> {
        match self {
            BatchSpec::Default => None,
            BatchSpec::Fixed(b) => Some(*b),
            BatchSpec::Paper => match model {
                "inception-v3" => Some(64),
                "gnmt" => Some(128),
                "biglstm" => Some(64),
                _ => None,
            },
        }
    }

    /// Stable axis label for JSON/CSV output.
    pub fn label(&self) -> String {
        match self {
            BatchSpec::Default => "default".into(),
            BatchSpec::Fixed(b) => b.to_string(),
            BatchSpec::Paper => "paper".into(),
        }
    }
}

/// The strategy-family axis: which slice of the candidate space a scenario
/// searches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyFamily {
    /// DP-only (M = 1): no model-parallel candidates at all.
    DpOnly,
    /// The full hybrid search: structural-default mechanisms (Table 1)
    /// *and* explicit pipelines per degree, best one wins.
    Hybrid,
    /// Pipelined hybrids only — every M > 1 candidate is a GPipe pipeline,
    /// the DLPlacer mechanism is skipped.
    Pipelined,
    /// The PaSE-style per-op configuration search
    /// ([`crate::layerwise`]): selection is driven by the mixed
    /// layer-wise candidates instead of the fixed family.
    Layerwise,
    /// Megatron-style tensor-parallel intra-layer splits
    /// ([`crate::coordinator::Strategy::TensorParallel`]): the spec's
    /// `mp_degrees` feed the TP widths and selection is driven by the
    /// tensor candidates.
    Tensor,
}

impl StrategyFamily {
    pub fn as_str(&self) -> &'static str {
        match self {
            StrategyFamily::DpOnly => "dp",
            StrategyFamily::Hybrid => "hybrid",
            StrategyFamily::Pipelined => "pipelined",
            StrategyFamily::Layerwise => "layerwise",
            StrategyFamily::Tensor => "tensor",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dp" | "dp-only" | "data-parallel" => StrategyFamily::DpOnly,
            "hybrid" | "all" => StrategyFamily::Hybrid,
            "pipelined" | "pipeline" => StrategyFamily::Pipelined,
            "layerwise" | "layer-wise" | "pase" => StrategyFamily::Layerwise,
            "tensor" | "tensor-parallel" | "tp" => StrategyFamily::Tensor,
            other => bail!("unknown strategy family '{other}' \
                            (known: dp, hybrid, pipelined, layerwise, \
                             tensor)"),
        })
    }
}

/// The sweep grid: the cartesian product of every axis, evaluated under
/// one objective and one cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub models: Vec<String>,
    pub topologies: Vec<String>,
    /// Device budgets N (projections past the physical box allowed).
    pub devices: Vec<usize>,
    /// Chassis-count axis (1 = the topology's own single-arg sizing;
    /// values > 1 require a multi-node-capable topology — single-box
    /// entries yield per-scenario errors, not a sweep failure).
    pub nodes: Vec<usize>,
    /// Per-device memory axis in GB (None = the topology's own Mem(n)) —
    /// "V100-16GB vs A100-80GB" as one grid.
    pub device_mem_gb: Vec<Option<f64>>,
    pub batches: Vec<BatchSpec>,
    pub families: Vec<StrategyFamily>,
    /// Gradient-exchange overlap axis: bucket budgets (1 = the paper's
    /// serial charge, the default).  Each value becomes
    /// [`PlanRequest::overlap_buckets`](super::PlanRequest) on the
    /// scenario's request.
    pub overlap: Vec<usize>,
    /// Gradient-compression axis: byte factors in `(0, 1]` (1.0 = off,
    /// the default).  The α latency floor is never scaled.
    pub compression: Vec<f64>,
    /// ZeRO-sharding axis: per-scenario [`ZeroMode`]s
    /// (`[ZeroMode::Off]`, the default, keeps the paper's replicated
    /// accounting).  A non-off entry overrides the spec memory model's
    /// own `zero` mode for that scenario; an `off` entry leaves it
    /// alone, so a sharded `memory` model without the axis still
    /// shards.
    pub zero: Vec<ZeroMode>,
    /// Candidate MP degrees for the hybrid/pipelined families (and the
    /// TP widths of the tensor family).
    pub mp_degrees: Vec<usize>,
    pub objective: Objective,
    /// Resolved per worker via [`cost_by_name`].
    pub cost_model: String,
    /// Footprint accounting (optimizer, recompute, …) applied to every
    /// scenario.
    pub memory: MemoryModel,
    /// Pin the collective algorithm for every scenario (None = best
    /// feasible per candidate).
    pub collective: Option<Algorithm>,
    pub curve_max_devices: usize,
    /// Worker threads (0 = one per available core).
    pub threads: usize,
}

impl Default for SweepSpec {
    /// The paper's evaluation grid: three networks on the DGX-1 at the
    /// Fig. 5 budgets, all three strategy families.
    fn default() -> Self {
        SweepSpec {
            models: vec!["inception-v3".into(), "gnmt".into(),
                         "biglstm".into()],
            topologies: vec!["dgx1".into()],
            devices: vec![8, 64, 256],
            nodes: vec![1],
            device_mem_gb: vec![None],
            batches: vec![BatchSpec::Default],
            families: vec![StrategyFamily::DpOnly, StrategyFamily::Hybrid,
                           StrategyFamily::Pipelined],
            overlap: vec![1],
            compression: vec![1.0],
            zero: vec![ZeroMode::Off],
            mp_degrees: vec![2],
            objective: Objective::TimeToConverge,
            cost_model: "analytical".into(),
            memory: MemoryModel::default(),
            collective: None,
            curve_max_devices: 256,
            threads: 0,
        }
    }
}

/// Stable label for a `device_mem_gb` axis value ("default" = the
/// topology's own capacity).
pub fn mem_gb_label(v: Option<f64>) -> String {
    v.map(|g| format!("{g}")).unwrap_or_else(|| "default".into())
}

/// Parse a `device_mem_gb` axis entry: `"default"` or a positive number
/// of GB.
pub fn parse_mem_gb(s: &str) -> Result<Option<f64>> {
    if s == "default" {
        return Ok(None);
    }
    let gb: f64 = s.parse().map_err(|_| {
        anyhow!("bad device_mem_gb '{s}' (expected 'default' or GB)")
    })?;
    if !gb.is_finite() || gb <= 0.0 {
        bail!("device_mem_gb must be a positive finite GB figure, \
               got {gb}");
    }
    Ok(Some(gb))
}

/// One grid point.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub model: String,
    pub topology: String,
    pub devices: usize,
    /// Chassis count (1 = the topology's own sizing).
    pub nodes: usize,
    /// Per-device memory override (None = topology default).
    pub device_mem_gb: Option<f64>,
    pub batch: BatchSpec,
    pub family: StrategyFamily,
    /// Overlap bucket budget (1 = serial exchange).
    pub overlap: usize,
    /// Gradient-compression byte factor (1.0 = off).
    pub compression: f64,
    /// ZeRO sharding mode for this scenario ([`ZeroMode::Off`] = leave
    /// the spec memory model's mode alone).
    pub zero: ZeroMode,
}

impl SweepSpec {
    /// Enumerate the grid in its canonical (model-major) order — the order
    /// results are reported in, independent of thread count.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        for model in &self.models {
            for topology in &self.topologies {
                for &devices in &self.devices {
                    for &nodes in &self.nodes {
                        for &device_mem_gb in &self.device_mem_gb {
                            for batch in &self.batches {
                                for &family in &self.families {
                                    for &overlap in &self.overlap {
                                        for &compression in &self.compression
                                        {
                                            for &zero in &self.zero {
                                                out.push(Scenario {
                                                    model: model.clone(),
                                                    topology:
                                                        topology.clone(),
                                                    devices,
                                                    nodes,
                                                    device_mem_gb,
                                                    batch: batch.clone(),
                                                    family,
                                                    overlap,
                                                    compression,
                                                    zero,
                                                });
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Reject structurally empty grids (an empty axis would silently
    /// evaluate nothing).  [`run_sweep`]/[`stream_sweep`] call this
    /// first; the service also calls it *before* committing a streamed
    /// 200 response head, so a malformed spec can still get a 400.
    pub fn validate(&self) -> Result<()> {
        for (axis, empty) in [
            ("models", self.models.is_empty()),
            ("topologies", self.topologies.is_empty()),
            ("devices", self.devices.is_empty()),
            ("nodes", self.nodes.is_empty()),
            ("device_mem_gb", self.device_mem_gb.is_empty()),
            ("batches", self.batches.is_empty()),
            ("families", self.families.is_empty()),
            ("overlap", self.overlap.is_empty()),
            ("compression", self.compression.is_empty()),
            ("zero", self.zero.is_empty()),
        ] {
            if empty {
                bail!("sweep axis '{axis}' is empty");
            }
        }
        // Axis values get the same loud validation as the /plan wire.
        for &buckets in &self.overlap {
            (OverlapModel { buckets, compression: 1.0 }).validate()?;
        }
        for &compression in &self.compression {
            (OverlapModel { buckets: 1, compression }).validate()?;
        }
        Ok(())
    }

    /// Wire-format keys accepted by [`SweepSpec::from_json`] (the
    /// service's `POST /sweep` body).
    pub const WIRE_KEYS: [&'static str; 17] = [
        "models", "topologies", "devices", "nodes", "device_mem_gb",
        "batches", "families", "overlap", "compression", "zero",
        "mp_degrees", "objective", "cost", "memory", "collective",
        "curve_max_devices", "threads",
    ];

    /// Parse the service wire format for a sweep: a JSON object with any
    /// subset of [`SweepSpec::WIRE_KEYS`].  Missing keys (and explicit
    /// `null`s) take the [`SweepSpec::default`] axes — the paper's
    /// evaluation grid — and unknown keys are rejected so a typoed axis
    /// cannot silently widen the grid to its default.  Axis entries
    /// mirror the CLI spellings: `batches` takes `"default"` / `"paper"`
    /// / integers, `device_mem_gb` takes `"default"` / positive GB
    /// numbers, `collective` takes `"auto"` or an algorithm name,
    /// `overlap` takes bucket budgets (validated against
    /// [`crate::parallel::overlap::MAX_BUCKETS`]) and `compression`
    /// takes byte factors in `(0, 1]`.
    /// Integer entries are strict and capped like the `/plan` wire
    /// ([`super::MAX_WIRE_DEVICES`]) — fractions and negatives are
    /// errors, never truncated.
    pub fn from_json(j: &Json) -> Result<SweepSpec> {
        for key in j.as_obj()?.keys() {
            if !SweepSpec::WIRE_KEYS.contains(&key.as_str()) {
                bail!("unknown sweep key '{key}' (known: {})",
                      SweepSpec::WIRE_KEYS.join(", "));
            }
        }
        fn strings(j: &Json, key: &str, default: Vec<String>)
                   -> Result<Vec<String>> {
            match j.opt(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect(),
            }
        }
        // One strict-integer validator for both wire surfaces
        // (crate::planner::wire_int).
        fn usizes(j: &Json, key: &str, max: usize, default: Vec<usize>)
                  -> Result<Vec<usize>> {
            match j.opt(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(|x| super::wire_int(x, key, max))
                    .collect(),
            }
        }
        let d = SweepSpec::default();
        let device_mem_gb = match j.opt("device_mem_gb") {
            None | Some(Json::Null) => d.device_mem_gb,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| match x {
                    Json::Num(g) if g.is_finite() && *g > 0.0 => {
                        Ok(Some(*g))
                    }
                    Json::Num(g) => bail!(
                        "device_mem_gb must be a positive finite GB \
                         figure, got {g}"),
                    other => parse_mem_gb(other.as_str()?),
                })
                .collect::<Result<_>>()?,
        };
        let batches = match j.opt("batches") {
            None | Some(Json::Null) => d.batches,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| match x {
                    Json::Num(_) => {
                        let b =
                            super::wire_int(x, "batches",
                                            super::MAX_WIRE_INT)?;
                        if b == 0 {
                            bail!("batches entries must be >= 1");
                        }
                        Ok(BatchSpec::Fixed(b))
                    }
                    other => BatchSpec::parse(other.as_str()?),
                })
                .collect::<Result<_>>()?,
        };
        let families = match j.opt("families") {
            None | Some(Json::Null) => d.families,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| StrategyFamily::parse(x.as_str()?))
                .collect::<Result<_>>()?,
        };
        let overlap = match j.opt("overlap") {
            None | Some(Json::Null) => d.overlap,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| {
                    let buckets =
                        super::wire_int(x, "overlap", super::MAX_WIRE_INT)?;
                    (OverlapModel { buckets, compression: 1.0 }).validate()?;
                    Ok(buckets)
                })
                .collect::<Result<_>>()?,
        };
        let compression = match j.opt("compression") {
            None | Some(Json::Null) => d.compression,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| match x {
                    Json::Num(c) => {
                        (OverlapModel { buckets: 1, compression: *c })
                            .validate()?;
                        Ok(*c)
                    }
                    _ => bail!("compression entries must be numbers \
                                in (0, 1]"),
                })
                .collect::<Result<_>>()?,
        };
        let zero = match j.opt("zero") {
            None | Some(Json::Null) => d.zero,
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|x| ZeroMode::parse(x.as_str()?))
                .collect::<Result<_>>()?,
        };
        let objective = match j.opt("objective") {
            None | Some(Json::Null) => d.objective,
            Some(v) => Objective::parse(v.as_str()?)?,
        };
        let memory = match j.opt("memory") {
            None | Some(Json::Null) => d.memory,
            Some(v) => MemoryModel::from_json(v)?,
        };
        let collective = match j.opt("collective") {
            None | Some(Json::Null) => None,
            Some(v) => match v.as_str()? {
                "auto" => None,
                other => Some(Algorithm::parse(other)?),
            },
        };
        let cost_model = match j.opt("cost") {
            None | Some(Json::Null) => d.cost_model,
            Some(v) => v.as_str()?.to_string(),
        };
        let scalar = |key: &str, default: usize| -> Result<usize> {
            match j.opt(key) {
                None | Some(Json::Null) => Ok(default),
                Some(v) => super::wire_int(v, key, super::MAX_WIRE_INT),
            }
        };
        Ok(SweepSpec {
            models: strings(j, "models", d.models)?,
            topologies: strings(j, "topologies", d.topologies)?,
            devices: usizes(j, "devices", super::MAX_WIRE_DEVICES,
                            d.devices)?,
            nodes: usizes(j, "nodes", super::MAX_WIRE_NODES, d.nodes)?,
            device_mem_gb,
            batches,
            families,
            overlap,
            compression,
            zero,
            mp_degrees: usizes(j, "mp_degrees", super::MAX_WIRE_INT,
                               d.mp_degrees)?,
            objective,
            cost_model,
            memory,
            collective,
            curve_max_devices: scalar("curve_max_devices",
                                      d.curve_max_devices)?,
            threads: scalar("threads", d.threads)?,
        })
    }

    /// Number of grid points — `scenarios().len()` without
    /// materialising them (saturating), so the service can bound a
    /// client-supplied grid *before* allocating it.
    pub fn cardinality(&self) -> usize {
        [self.models.len(), self.topologies.len(), self.devices.len(),
         self.nodes.len(), self.device_mem_gb.len(), self.batches.len(),
         self.families.len(), self.overlap.len(), self.compression.len(),
         self.zero.len()]
            .iter()
            .fold(1usize, |acc, &n| acc.saturating_mul(n))
    }
}

// ==========================================================================
// Evaluation
// ==========================================================================

/// One evaluated grid point: the scenario plus either its [`Plan`] or the
/// planner's error (an infeasible point is a result, not a sweep failure).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub plan: Option<Plan>,
    pub error: Option<String>,
}

/// The evaluated grid, in canonical scenario order.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepResult {
    pub results: Vec<ScenarioResult>,
}

/// The [`PlanRequest`] a scenario evaluates — exposed so the trace layer
/// (`sweep --trace-dir`) can rebuild per-scenario timelines from the
/// exact request the sweep planned, and tests can cross-check a grid
/// point against a direct [`Planner::plan`] call.
pub fn plan_request(planner: &Planner, spec: &SweepSpec, sc: &Scenario)
                    -> PlanRequest {
    let mut req = PlanRequest::new(&sc.model, &sc.topology)
        .devices(sc.devices)
        .objective(spec.objective)
        .memory(spec.memory.clone())
        .curve_to(spec.curve_max_devices);
    if sc.nodes != 1 {
        req = req.nodes(sc.nodes);
    }
    if let Some(a) = spec.collective {
        req = req.collective(a);
    }
    if let Some(gb) = sc.device_mem_gb {
        req = req.device_mem_gb(gb);
    }
    // Unconditional: the request defaults match the axis defaults, and
    // canonical_json always serialises both keys, so off-spellings still
    // share one service-cache entry.
    req = req.overlap_buckets(sc.overlap).compression(sc.compression);
    match sc.family {
        StrategyFamily::DpOnly => req = req.mp_degrees(&[]),
        StrategyFamily::Hybrid => req = req.mp_degrees(&spec.mp_degrees),
        StrategyFamily::Pipelined => {
            req = req.mp_degrees(&spec.mp_degrees).pipeline_only(true);
        }
        StrategyFamily::Layerwise => {
            req = req
                .mp_degrees(&spec.mp_degrees)
                .mechanism(PlanMechanism::Layerwise);
        }
        StrategyFamily::Tensor => {
            req = req
                .mp_degrees(&[])
                .tensor_degrees(&spec.mp_degrees)
                .mechanism(PlanMechanism::Tensor);
        }
    }
    // The scenario's ZeRO axis shadows the spec memory model's mode;
    // `off` (the axis default) leaves it alone, so a sharded spec-level
    // `memory` model without the axis still shards.
    if sc.zero != ZeroMode::Off {
        req.memory.zero = sc.zero;
    }
    // Batch tables are keyed off canonical model names; aliases resolve
    // through the registry (unknown models keep their spelling and fail
    // in the planner with the catalog listing).
    let canonical = planner
        .models()
        .canonical_name(&sc.model)
        .unwrap_or(&sc.model);
    if let Some(b) = sc.batch.resolve(canonical) {
        req = req.batch(b);
    }
    req
}

/// Evaluate the grid, delivering each [`ScenarioResult`] to `sink` in
/// canonical scenario order *as its ordered prefix completes* — the
/// service's `POST /sweep` streams response chunks from this, and
/// [`run_sweep`] collects it into a [`SweepResult`].  Workers share
/// scenarios dynamically (the same scoped-threads + atomic-index
/// machinery as [`parallel_map`]); a reorder buffer holds out-of-order
/// completions so the sink observes canonical order regardless of
/// thread count — concatenating the sink's inputs is byte-identical to
/// the collected result for any `threads`.  A sink error stops the
/// sweep early: no new scenarios are handed out, in-flight ones finish
/// and are discarded, and the sink's error is returned.
pub fn stream_sweep<F>(spec: &SweepSpec, sink: F) -> Result<()>
where
    F: FnMut(ScenarioResult) -> Result<()>,
{
    stream_sweep_indices(spec, None, sink)
}

/// [`stream_sweep`] over a subset of the grid: evaluate only the
/// scenarios at `indices` (positions into the canonical
/// [`scenarios`](SweepSpec::scenarios) order, strictly increasing),
/// delivering them to `sink` in that order.  `None` means the whole
/// grid.  This is the replica side of the service's sharded
/// `POST /sweep`: each daemon evaluates its consistent-hash share, and
/// because every replica emits in canonical-order-restricted-to-subset,
/// the coordinator can splice the streams back into the exact
/// single-replica byte sequence.
pub fn stream_sweep_indices<F>(spec: &SweepSpec, indices: Option<&[usize]>,
                               mut sink: F) -> Result<()>
where
    F: FnMut(ScenarioResult) -> Result<()>,
{
    spec.validate()?;
    let cost: Arc<dyn CostModel> = Arc::from(cost_by_name(&spec.cost_model)?);
    let planner = Planner::with_cost(Box::new(MemoCost::new(cost)));
    let scenarios = spec.scenarios();
    let picked: Vec<usize> = match indices {
        None => (0..scenarios.len()).collect(),
        Some(idx) => {
            if idx.windows(2).any(|w| w[0] >= w[1]) {
                bail!("shard indices must be strictly increasing");
            }
            if let Some(&out) = idx.iter().find(|&&i| i >= scenarios.len()) {
                bail!("shard index {out} is outside the {}-scenario grid",
                      scenarios.len());
            }
            idx.to_vec()
        }
    };
    let eval = |sc: &Scenario| {
        match planner.plan(&plan_request(&planner, spec, sc)) {
            Ok(plan) => (Some(plan), None),
            Err(e) => (None, Some(format!("{e:#}"))),
        }
    };
    let n_workers = effective_threads(spec.threads, picked.len());
    if n_workers <= 1 {
        for &i in &picked {
            let scenario = scenarios[i].clone();
            let (plan, error) = eval(&scenario);
            sink(ScenarioResult { scenario, plan, error })?;
        }
        return Ok(());
    }
    // `next`/`slots` index into `picked`, not the full grid, so the
    // reorder buffer stays proportional to this shard's share.
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, (Option<Plan>, Option<String>))>();
    let mut slots: Vec<Option<(Option<Plan>, Option<String>)>> = Vec::new();
    slots.resize_with(picked.len(), || None);
    let mut sink_result: Result<()> = Ok(());
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            let tx = tx.clone();
            let next = &next;
            let eval = &eval;
            let scenarios = &scenarios;
            let picked = &picked;
            scope.spawn(move || loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= picked.len() {
                    break;
                }
                let r = eval(&scenarios[picked[j]]);
                if tx.send((j, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut flushed = 0usize;
        'recv: for (j, r) in rx.iter() {
            slots[j] = Some(r);
            while flushed < slots.len() && slots[flushed].is_some() {
                let (plan, error) = slots[flushed].take().unwrap();
                let res = ScenarioResult {
                    scenario: scenarios[picked[flushed]].clone(),
                    plan,
                    error,
                };
                flushed += 1;
                if let Err(e) = sink(res) {
                    sink_result = Err(e);
                    // Exhaust the work counter so the workers stop
                    // picking up scenarios (their in-flight item still
                    // completes and is discarded with the buffer).
                    next.store(picked.len(), Ordering::Relaxed);
                    break 'recv;
                }
            }
        }
    });
    sink_result
}

/// Evaluate the grid.  Scenario errors (unknown model, infeasible point,
/// nothing-fits-in-memory) are captured per result; only a malformed spec
/// fails the sweep itself.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepResult> {
    run_sweep_observed(spec, |_, _| ())
}

/// [`run_sweep`] with a completion heartbeat: `on_done(done, total)`
/// fires after each scenario lands, in canonical order.  The callback
/// sees delivery order (not worker completion order), so `done` counts
/// monotonically from 1 to `total` for any thread count — the CLI's
/// `--progress` stderr line hangs off this without touching the
/// byte-identical stdout contract.
pub fn run_sweep_observed<F>(spec: &SweepSpec, mut on_done: F)
                             -> Result<SweepResult>
where
    F: FnMut(usize, usize),
{
    let total = spec.cardinality();
    let mut results = Vec::with_capacity(total);
    stream_sweep(spec, |r| {
        results.push(r);
        on_done(results.len(), total);
        Ok(())
    })?;
    Ok(SweepResult { results })
}

// ==========================================================================
// Serialisation
// ==========================================================================

impl ScenarioResult {
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("model", Json::Str(self.scenario.model.clone())),
            ("topology", Json::Str(self.scenario.topology.clone())),
            ("devices", Json::Num(self.scenario.devices as f64)),
            ("nodes", Json::Num(self.scenario.nodes as f64)),
            ("device_mem_gb",
             self.scenario
                 .device_mem_gb
                 .map(Json::Num)
                 .unwrap_or(Json::Null)),
            ("batch", Json::Str(self.scenario.batch.label())),
            ("family",
             Json::Str(self.scenario.family.as_str().to_string())),
            ("overlap", Json::Num(self.scenario.overlap as f64)),
            ("compression", Json::Num(self.scenario.compression)),
            ("zero", Json::Str(self.scenario.zero.as_str().to_string())),
            ("plan",
             self.plan.as_ref().map(|p| p.to_json()).unwrap_or(Json::Null)),
            ("error",
             self.error
                 .as_ref()
                 .map(|e| Json::Str(e.clone()))
                 .unwrap_or(Json::Null)),
        ])
    }
}

/// Quote a CSV field (always quoted: stable and comma/quote-safe).
fn csv_field(s: &str) -> String {
    format!("\"{}\"", s.replace('"', "\"\""))
}

impl SweepResult {
    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// Deterministic JSON document (scenario order; object keys sorted by
    /// the underlying `BTreeMap`).  `--threads N` output is byte-identical
    /// to `--threads 1`.
    pub fn to_json(&self) -> Json {
        jobj(vec![(
            "scenarios",
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        )])
    }

    /// The canonical serialised sweep document: compact JSON plus a
    /// trailing newline — the exact bytes the `sweep` CLI prints on
    /// stdout and writes with `--out-json`, and that the service's
    /// chunked `POST /sweep` response concatenates to.  One writer, so
    /// the surfaces cannot drift apart byte-wise.
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().to_string();
        s.push('\n');
        s
    }

    /// Flat CSV: one row per scenario with the headline plan fields.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "model,topology,devices,nodes,device_mem_gb,batch,family,\
             overlap,compression,zero,\
             status,strategy,mp_degree,mechanism,collective,devices_used,\
             dp_workers,microbatches,global_batch,step_time_s,epochs,\
             speedup,peak_mem_gb,error\n");
        for r in &self.results {
            let sc = &r.scenario;
            let mut cells: Vec<String> = vec![
                sc.model.clone(),
                sc.topology.clone(),
                sc.devices.to_string(),
                sc.nodes.to_string(),
                mem_gb_label(sc.device_mem_gb),
                sc.batch.label(),
                sc.family.as_str().to_string(),
                sc.overlap.to_string(),
                format!("{}", sc.compression),
                sc.zero.as_str().to_string(),
            ];
            match (&r.plan, &r.error) {
                (Some(p), _) => {
                    cells.extend([
                        "ok".to_string(),
                        p.strategy.kind().to_string(),
                        p.mp_degree.to_string(),
                        p.mechanism.clone(),
                        p.collective.clone(),
                        p.devices_used.to_string(),
                        p.dp_workers.to_string(),
                        p.microbatches
                            .map(|m| m.to_string())
                            .unwrap_or_default(),
                        p.global_batch.to_string(),
                        format!("{}", p.predicted_step_s),
                        p.predicted_epochs
                            .map(|e| format!("{e}"))
                            .unwrap_or_default(),
                        format!("{}", p.predicted_speedup),
                        p.memory
                            .map(|m| format!("{}", m.total_bytes / 1e9))
                            .unwrap_or_default(),
                        String::new(),
                    ]);
                }
                (None, err) => {
                    cells.push("error".to_string());
                    // strategy..peak_mem_gb stay blank on errored rows.
                    cells.extend((0..12).map(|_| String::new()));
                    cells.push(err.clone().unwrap_or_default());
                }
            }
            let row: Vec<String> =
                cells.iter().map(|c| csv_field(c)).collect();
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1usize, 2, 5, 0] {
            let out = parallel_map(threads, &items, |i, &x| {
                assert_eq!(i, x);
                x * x
            });
            let want: Vec<usize> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(1, 100), 1);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(7, 0), 1);
    }

    #[test]
    fn batch_specs_parse_and_resolve() {
        assert_eq!(BatchSpec::parse("default").unwrap(), BatchSpec::Default);
        assert_eq!(BatchSpec::parse("paper").unwrap(), BatchSpec::Paper);
        assert_eq!(BatchSpec::parse("64").unwrap(), BatchSpec::Fixed(64));
        assert!(BatchSpec::parse("huge").is_err());
        assert_eq!(BatchSpec::Paper.resolve("gnmt"), Some(128));
        assert_eq!(BatchSpec::Paper.resolve("inception-v3"), Some(64));
        assert_eq!(BatchSpec::Paper.resolve("biglstm"), Some(64));
        assert_eq!(BatchSpec::Paper.resolve("transformer-lm"), None);
        assert_eq!(BatchSpec::Default.resolve("gnmt"), None);
        assert_eq!(BatchSpec::Fixed(32).resolve("gnmt"), Some(32));
        assert_eq!(BatchSpec::Fixed(32).label(), "32");
    }

    #[test]
    fn families_parse() {
        assert_eq!(StrategyFamily::parse("dp").unwrap(),
                   StrategyFamily::DpOnly);
        assert_eq!(StrategyFamily::parse("hybrid").unwrap(),
                   StrategyFamily::Hybrid);
        assert_eq!(StrategyFamily::parse("pipelined").unwrap(),
                   StrategyFamily::Pipelined);
        assert_eq!(StrategyFamily::parse("pase").unwrap(),
                   StrategyFamily::Layerwise);
        assert_eq!(StrategyFamily::parse("tp").unwrap(),
                   StrategyFamily::Tensor);
        assert!(StrategyFamily::parse("magic").is_err());
        for f in [StrategyFamily::DpOnly, StrategyFamily::Hybrid,
                  StrategyFamily::Pipelined, StrategyFamily::Layerwise,
                  StrategyFamily::Tensor] {
            assert_eq!(StrategyFamily::parse(f.as_str()).unwrap(), f);
        }
    }

    #[test]
    fn scenario_order_is_model_major() {
        let spec = SweepSpec {
            models: vec!["a".into(), "b".into()],
            topologies: vec!["t".into()],
            devices: vec![1, 2],
            batches: vec![BatchSpec::Default],
            families: vec![StrategyFamily::DpOnly],
            ..Default::default()
        };
        let sc = spec.scenarios();
        assert_eq!(sc.len(), 4);
        assert_eq!((sc[0].model.as_str(), sc[0].devices), ("a", 1));
        assert_eq!((sc[1].model.as_str(), sc[1].devices), ("a", 2));
        assert_eq!((sc[2].model.as_str(), sc[2].devices), ("b", 1));
        assert_eq!((sc[3].model.as_str(), sc[3].devices), ("b", 2));
    }

    #[test]
    fn empty_axes_rejected() {
        let spec = SweepSpec { devices: vec![], ..Default::default() };
        assert!(run_sweep(&spec).is_err());
    }

    #[test]
    fn observed_sweep_counts_monotonically_to_the_cardinality() {
        let spec = SweepSpec {
            models: vec!["gnmt".into(), "inception-v3".into()],
            devices: vec![4, 8],
            families: vec![StrategyFamily::DpOnly],
            curve_max_devices: 8,
            threads: 2,
            ..Default::default()
        };
        let mut seen = Vec::new();
        let r = run_sweep_observed(&spec, |done, total| {
            seen.push((done, total));
        }).unwrap();
        assert_eq!(r.len(), spec.cardinality());
        let want: Vec<(usize, usize)> =
            (1..=spec.cardinality()).map(|d| (d, spec.cardinality())).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn errors_are_per_scenario() {
        let spec = SweepSpec {
            models: vec!["gnmt".into(), "alexnet".into()],
            devices: vec![8],
            families: vec![StrategyFamily::DpOnly],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.len(), 2);
        assert!(r.results[0].plan.is_some());
        assert!(r.results[0].error.is_none());
        assert!(r.results[1].plan.is_none());
        assert!(r.results[1].error.as_ref().unwrap().contains("alexnet"));
        // The CSV keeps the failed row with a status marker.
        let csv = r.to_csv();
        assert!(csv.contains("\"ok\""));
        assert!(csv.contains("\"error\""));
    }

    #[test]
    fn paper_batches_resolve_through_registry_aliases() {
        // "inception" is a registry alias: the paper batch table is keyed
        // off canonical names, so the alias must still get batch 64.
        let spec = SweepSpec {
            models: vec!["inception".into()],
            devices: vec![8],
            batches: vec![BatchSpec::Paper],
            families: vec![StrategyFamily::DpOnly],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.results[0].plan.as_ref().unwrap().mini_batch, 64);
    }

    #[test]
    fn families_restrict_the_search() {
        let base = SweepSpec {
            models: vec!["gnmt".into()],
            devices: vec![256],
            curve_max_devices: 256,
            threads: 1,
            ..Default::default()
        };
        let dp = run_sweep(&SweepSpec {
            families: vec![StrategyFamily::DpOnly],
            ..base.clone()
        })
        .unwrap();
        let plan = dp.results[0].plan.as_ref().unwrap();
        assert_eq!(plan.mp_degree, 1, "DP-only family must not go hybrid");
        assert!(plan.scorecard.iter().all(|c| c.mp_degree == 1));

        let pipe = run_sweep(&SweepSpec {
            families: vec![StrategyFamily::Pipelined],
            ..base
        })
        .unwrap();
        let plan = pipe.results[0].plan.as_ref().unwrap();
        assert_eq!(plan.mp_degree, 2, "paper: pipelined hybrid at 256");
        assert_eq!(plan.mechanism, "pipelined");
    }

    #[test]
    fn nodes_axis_expands_the_grid() {
        let spec = SweepSpec {
            models: vec!["gnmt".into()],
            topologies: vec!["dgx1-pod".into()],
            devices: vec![16],
            nodes: vec![1, 2, 4],
            families: vec![StrategyFamily::DpOnly],
            cost_model: "alpha-beta".into(),
            curve_max_devices: 16,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.len(), 3);
        for (i, nodes) in [1usize, 2, 4].iter().enumerate() {
            assert_eq!(r.results[i].scenario.nodes, *nodes);
            let plan = r.results[i].plan.as_ref().unwrap();
            assert_eq!(plan.nodes,
                       if *nodes == 1 { None } else { Some(*nodes) });
        }
        // More chassis for the same budget → slower fabric in the loop →
        // no faster DP step.
        let t2 = r.results[1].plan.as_ref().unwrap().predicted_step_s;
        let t4 = r.results[2].plan.as_ref().unwrap().predicted_step_s;
        assert!(t4 >= t2 - 1e-12,
                "4 chassis cannot beat 2 for a 16-worker DP: {t4} vs {t2}");
        // The axis shows up in both serialisations.
        let json = r.to_json().to_string();
        assert!(json.contains("\"nodes\":2"));
        let csv = r.to_csv();
        assert!(csv.starts_with("model,topology,devices,nodes,"));
        assert!(csv.contains("collective"), "header must carry the column");
        assert!(csv.contains("\"hierarchical\""),
                "multi-chassis DP rows must record the 2-level pricing");
        // Single-box topology × nodes > 1 is a per-scenario error.
        let bad = run_sweep(&SweepSpec {
            topologies: vec!["dgx1".into()],
            nodes: vec![2],
            models: vec!["gnmt".into()],
            devices: vec![8],
            families: vec![StrategyFamily::DpOnly],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        assert!(bad.results[0].error.is_some());
        // Empty axis is rejected.
        assert!(run_sweep(&SweepSpec { nodes: vec![], ..Default::default() })
            .is_err());
    }

    #[test]
    fn forced_collective_threads_through_the_sweep() {
        let base = SweepSpec {
            models: vec!["gnmt".into()],
            topologies: vec!["dgx1-pod".into()],
            devices: vec![32],
            nodes: vec![4],
            families: vec![StrategyFamily::DpOnly],
            cost_model: "alpha-beta".into(),
            curve_max_devices: 32,
            threads: 1,
            ..Default::default()
        };
        let auto = run_sweep(&base).unwrap();
        let plan = auto.results[0].plan.as_ref().unwrap();
        assert_eq!(plan.collective, "hierarchical",
                   "4x8 DP must price hierarchically: {plan:?}");
        let forced = run_sweep(&SweepSpec {
            collective: Some(Algorithm::Ring),
            ..base
        })
        .unwrap();
        let flat = forced.results[0].plan.as_ref().unwrap();
        assert_eq!(flat.collective, "ring");
        assert!(plan.predicted_step_s < flat.predicted_step_s,
                "hierarchical pricing must strictly beat the flat ring: \
                 {} vs {}", plan.predicted_step_s, flat.predicted_step_s);
    }

    #[test]
    fn mem_axis_labels_and_parse() {
        assert_eq!(mem_gb_label(None), "default");
        assert_eq!(mem_gb_label(Some(16.0)), "16");
        assert_eq!(mem_gb_label(Some(0.5)), "0.5");
        assert_eq!(parse_mem_gb("default").unwrap(), None);
        assert_eq!(parse_mem_gb("80").unwrap(), Some(80.0));
        assert!(parse_mem_gb("-4").is_err());
        assert!(parse_mem_gb("0").is_err());
        assert!(parse_mem_gb("big").is_err());
    }

    #[test]
    fn device_mem_axis_expands_the_grid() {
        let spec = SweepSpec {
            models: vec!["biglstm".into()],
            devices: vec![8],
            device_mem_gb: vec![Some(16.0), Some(80.0)],
            families: vec![StrategyFamily::Hybrid],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.results[0].scenario.device_mem_gb, Some(16.0));
        assert_eq!(r.results[1].scenario.device_mem_gb, Some(80.0));
        // 16 GB parts: DP cannot fit, the hybrid is forced; 80 GB parts:
        // DP fits and wins at 8 devices — one grid, both regimes (the
        // memoisation key must keep the two capacities apart).
        let small = r.results[0].plan.as_ref().unwrap();
        let big = r.results[1].plan.as_ref().unwrap();
        assert!(small.mp_degree > 1,
                "16 GB: DP infeasible, hybrid must win: {small:?}");
        assert_eq!(big.mp_degree, 1, "80 GB: DP fits and wins at 8");
        // The axis shows up in both serialisations.
        let json = r.to_json().to_string();
        assert!(json.contains("\"device_mem_gb\":16"));
        let csv = r.to_csv();
        assert!(csv.contains("device_mem_gb"));
        assert!(csv.contains("\"16\"") && csv.contains("\"80\""));
    }

    #[test]
    fn overlap_axes_expand_the_grid() {
        let base = SweepSpec {
            models: vec!["gnmt".into()],
            topologies: vec!["dgx1-pod".into()],
            devices: vec![32],
            nodes: vec![4],
            families: vec![StrategyFamily::DpOnly],
            cost_model: "alpha-beta".into(),
            curve_max_devices: 32,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&SweepSpec {
            overlap: vec![1, 8],
            compression: vec![1.0, 0.25],
            ..base.clone()
        })
        .unwrap();
        assert_eq!(r.len(), 4, "2 overlap x 2 compression grid points");
        let step = |i: usize| {
            r.results[i].plan.as_ref().unwrap().predicted_step_s
        };
        // Canonical order: overlap-major, compression innermost.
        assert_eq!((r.results[0].scenario.overlap,
                    r.results[0].scenario.compression), (1, 1.0));
        assert_eq!((r.results[3].scenario.overlap,
                    r.results[3].scenario.compression), (8, 0.25));
        // Each axis strictly helps a 4x8 DP exchange on its own, and the
        // plan echoes the scenario's settings.
        assert!(step(1) < step(0), "compression must shrink the exchange");
        assert!(step(2) < step(0), "bucketed overlap must hide exchange");
        assert!(step(3) <= step(1).min(step(2)) + 1e-15);
        for res in &r.results {
            let p = res.plan.as_ref().unwrap();
            assert_eq!(p.overlap_buckets, res.scenario.overlap);
            assert_eq!(p.compression, res.scenario.compression);
        }
        // The default-off row is the same plan a sweep without the axes
        // produces (MemoCost sharing MP estimates across overlap values
        // cannot leak overlap between scenarios).
        let plain = run_sweep(&base).unwrap();
        assert_eq!(plain.results[0].plan, r.results[0].plan);
        // Both serialisations carry the axes.
        let json = r.to_json().to_string();
        assert!(json.contains("\"overlap\":8"));
        assert!(json.contains("\"compression\":0.25"));
        let csv = r.to_csv();
        assert!(csv.contains("family,overlap,compression,zero,status"));
        assert!(csv.contains("\"8\"") && csv.contains("\"0.25\""));
        // Empty axes are rejected like every other axis.
        for bad in [
            SweepSpec { overlap: vec![], ..base.clone() },
            SweepSpec { compression: vec![], ..base.clone() },
            SweepSpec { overlap: vec![0], ..base.clone() },
            SweepSpec { compression: vec![2.0], ..base },
        ] {
            assert!(run_sweep(&bad).is_err());
        }
    }

    #[test]
    fn empty_mem_axis_rejected() {
        let spec = SweepSpec {
            device_mem_gb: vec![],
            ..Default::default()
        };
        assert!(run_sweep(&spec).is_err());
    }

    #[test]
    fn stream_sweep_delivers_canonical_order_at_any_thread_count() {
        let mut spec = SweepSpec {
            models: vec!["gnmt".into(), "inception-v3".into()],
            devices: vec![8, 64],
            families: vec![StrategyFamily::DpOnly, StrategyFamily::Hybrid],
            curve_max_devices: 64,
            threads: 1,
            ..Default::default()
        };
        let want = run_sweep(&spec).unwrap();
        for threads in [1usize, 2, 4, 0] {
            spec.threads = threads;
            let mut got = Vec::new();
            stream_sweep(&spec, |r| {
                got.push(r);
                Ok(())
            })
            .unwrap();
            assert_eq!(got.len(), want.results.len(), "threads={threads}");
            let streamed = SweepResult { results: got };
            assert_eq!(streamed.to_json().to_string(),
                       want.to_json().to_string(),
                       "threads={threads}: streamed order/content drifted");
        }
    }

    #[test]
    fn stream_sweep_sink_error_stops_early() {
        let spec = SweepSpec {
            models: vec!["gnmt".into()],
            devices: vec![8, 16, 32, 64],
            families: vec![StrategyFamily::DpOnly],
            curve_max_devices: 8,
            threads: 2,
            ..Default::default()
        };
        let mut seen = 0usize;
        let err = stream_sweep(&spec, |_| {
            seen += 1;
            if seen == 2 {
                anyhow::bail!("client went away")
            }
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("client went away"));
        assert_eq!(seen, 2, "sink must not be called after its error");
    }

    #[test]
    fn sweep_spec_wire_format_parses_and_defaults() {
        use crate::util::json::Json;
        // Empty body = the default paper grid.
        let spec = SweepSpec::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(spec, SweepSpec::default());
        // Axes parse with CLI spellings; numbers allowed where the CLI
        // takes them.
        let spec = SweepSpec::from_json(&Json::parse(
            r#"{"models":["gnmt"],"topologies":["dgx1-pod"],
                "devices":[16],"nodes":[2],"device_mem_gb":["default",80],
                "batches":["paper",64],"families":["dp"],
                "overlap":[1,8],"compression":[1.0,0.25],
                "zero":["off","zero3"],
                "mp_degrees":[2,4],"objective":"step-time",
                "cost":"alpha-beta","collective":"ring",
                "memory":{"recompute":true},"curve_max_devices":16,
                "threads":2}"#).unwrap()).unwrap();
        assert_eq!(spec.models, vec!["gnmt"]);
        assert_eq!(spec.topologies, vec!["dgx1-pod"]);
        assert_eq!(spec.devices, vec![16]);
        assert_eq!(spec.nodes, vec![2]);
        assert_eq!(spec.device_mem_gb, vec![None, Some(80.0)]);
        assert_eq!(spec.batches,
                   vec![BatchSpec::Paper, BatchSpec::Fixed(64)]);
        assert_eq!(spec.families, vec![StrategyFamily::DpOnly]);
        assert_eq!(spec.overlap, vec![1, 8]);
        assert_eq!(spec.compression, vec![1.0, 0.25]);
        assert_eq!(spec.zero, vec![ZeroMode::Off, ZeroMode::Weights]);
        assert_eq!(spec.mp_degrees, vec![2, 4]);
        assert_eq!(spec.objective, Objective::StepTime);
        assert_eq!(spec.cost_model, "alpha-beta");
        assert_eq!(spec.collective, Some(Algorithm::Ring));
        assert!(spec.memory.recompute);
        assert_eq!(spec.curve_max_devices, 16);
        assert_eq!(spec.threads, 2);
        // Unknown keys and bad entries are rejected — integers strictly
        // (no silent truncation of fractions/negatives, wire caps on
        // allocation-bearing axes).
        for bad in [r#"{"model":["gnmt"]}"#,
                    r#"{"device_mem_gb":[-4]}"#,
                    r#"{"families":["magic"]}"#,
                    r#"{"collective":"pigeon"}"#,
                    r#"{"batches":[-1]}"#,
                    r#"{"batches":[2.5]}"#,
                    r#"{"batches":[0]}"#,
                    r#"{"devices":[2.5]}"#,
                    r#"{"devices":[1000000000000000]}"#,
                    r#"{"nodes":[100000]}"#,
                    r#"{"overlap":[0]}"#,
                    r#"{"overlap":[2048]}"#,
                    r#"{"overlap":[2.5]}"#,
                    r#"{"compression":[0]}"#,
                    r#"{"compression":[1.5]}"#,
                    r#"{"compression":["lots"]}"#,
                    r#"{"zero":["stage9"]}"#,
                    r#"{"threads":-2}"#] {
            assert!(SweepSpec::from_json(&Json::parse(bad).unwrap())
                        .is_err(), "{bad}");
        }
    }

    #[test]
    fn tensor_family_and_zero_axis_sweep() {
        // The tensor family drives selection through the intra-layer
        // split, reusing mp_degrees as the TP widths.
        let tp = run_sweep(&SweepSpec {
            models: vec!["gnmt".into()],
            devices: vec![8],
            families: vec![StrategyFamily::Tensor],
            mp_degrees: vec![2],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        let plan = tp.results[0].plan.as_ref().unwrap();
        assert_eq!(plan.mechanism, "tensor");
        assert_eq!(plan.strategy.kind(), "tensor-parallel");
        assert_eq!(plan.mp_degree, 2);
        // The zero axis flips per-scenario feasibility: BigLSTM's Adam
        // state overflows 16 GB parts replicated, fits ZeRO-3-sharded
        // across the 8 DP ranks.
        let z = run_sweep(&SweepSpec {
            models: vec!["biglstm".into()],
            devices: vec![8],
            device_mem_gb: vec![Some(16.0)],
            families: vec![StrategyFamily::DpOnly],
            zero: vec![ZeroMode::Off, ZeroMode::Weights],
            curve_max_devices: 8,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(z.len(), 2);
        assert_eq!(z.results[0].scenario.zero, ZeroMode::Off);
        assert!(z.results[0].plan.is_none(),
                "replicated DP-only must overflow 16 GB parts");
        assert_eq!(z.results[1].scenario.zero, ZeroMode::Weights);
        let sharded = z.results[1].plan.as_ref().unwrap();
        assert_eq!(sharded.mp_degree, 1);
        // Both serialisations carry the axis.
        let json = z.to_json().to_string();
        assert!(json.contains("\"zero\":\"weights\""));
        let csv = z.to_csv();
        assert!(csv.contains(",zero,"));
        assert!(csv.contains("\"weights\""));
    }

    #[test]
    fn cardinality_matches_scenarios() {
        let spec = SweepSpec::default();
        assert_eq!(spec.cardinality(), spec.scenarios().len());
        let wide = SweepSpec {
            models: vec!["a".into(), "b".into()],
            devices: vec![1, 2, 3],
            nodes: vec![1, 2],
            ..Default::default()
        };
        assert_eq!(wide.cardinality(), wide.scenarios().len());
    }

    #[test]
    fn memoisation_is_transparent() {
        // A sweep over repeated budgets on the same (clamped) topology and
        // one plain planner run must agree exactly.
        let spec = SweepSpec {
            models: vec!["gnmt".into()],
            devices: vec![64, 64, 256],
            families: vec![StrategyFamily::Hybrid],
            curve_max_devices: 256,
            threads: 1,
            ..Default::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.results[0].plan, r.results[1].plan,
                   "identical scenarios must produce identical plans");
        let direct = Planner::new()
            .plan(&PlanRequest::new("gnmt", "dgx1").devices(256))
            .unwrap();
        let swept = r.results[2].plan.as_ref().unwrap();
        assert_eq!(swept.strategy, direct.strategy);
        assert!((swept.predicted_speedup - direct.predicted_speedup).abs()
                < 1e-12);
    }
}
