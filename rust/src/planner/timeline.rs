//! Plan → Perfetto timeline: replay the chosen strategy's schedule and
//! serialise it as a Chrome trace-event document.
//!
//! `plan --trace-out timeline.json` (and `sweep --trace-dir DIR`) land
//! here: [`plan_timeline`] rebuilds the schedule behind the chosen
//! candidate — the GPipe stage×microbatch unroll for pipelined plans, the
//! DLPlacer assignment for placed plans, the serial op chain for DP — runs
//! it through the discrete-event simulator under [`SimConfig::ideal`]
//! (the same idealised-link assumption the analytic estimates price), and
//! records one track per device ([`PID_DEVICES`]) plus one per network
//! resource ([`PID_NETWORK`]) on a virtual clock.  The document is a pure
//! function of the plan, so identical requests produce byte-identical
//! timelines — `tests/integration_trace.rs` byte-compares them.
//!
//! Times are scaled by the request's recompute `time_factor`, matching
//! how [`super::Planner::plan`] inflates reported step times: on an
//! SE = 1 cost model the device-track extent equals the plan's
//! `predicted_step_s` (within the simulator-vs-analytic agreement on
//! balanced chains, well under 1%).

use anyhow::{anyhow, bail, Result};

use crate::pipeline;
use crate::sim::{self, SimConfig};
use crate::trace::{TraceClock, TraceRecorder, PID_DEVICES, PID_NETWORK};
use crate::util::json::Json;

use super::cost;
use super::{Plan, PlanRequest, Planner};

/// Seconds → trace microseconds, under the recompute inflation factor.
fn us(t_s: f64, time_factor: f64) -> f64 {
    t_s * time_factor * 1e6
}

/// Render the chosen candidate's schedule as a Chrome trace-event JSON
/// document (string includes the trailing newline, same framing as
/// [`Plan::to_json_string`]).
pub fn plan_timeline(planner: &Planner, req: &PlanRequest, plan: &Plan)
                     -> Result<String> {
    let prof =
        planner.models().build(&plan.model, Some(plan.mini_batch))?;
    let mut hw = match plan.nodes {
        Some(n) if n > 1 => planner
            .topologies()
            .build_nodes(&req.topology, n, plan.device_budget)?,
        _ => planner.topologies().build(&req.topology,
                                        plan.device_budget)?,
    };
    if let Some(gb) = plan.device_mem_gb {
        hw.set_device_mem(gb * 1e9);
    }
    let tf = req.memory.time_factor();
    let rec = TraceRecorder::new(TraceClock::virtual_clock());

    let device_extent_us = match plan.mechanism.as_str() {
        "pipelined" => pipelined_tracks(&rec, &prof, &hw, plan, tf)?,
        "placed" => placed_tracks(&rec, planner, &prof, &hw, plan, tf)?,
        "tensor" | "layerwise" => coarse_tracks(&rec, planner, &prof, &hw,
                                                plan, tf)?,
        _ => serial_tracks(&rec, planner, &prof, plan, tf),
    };

    // The DP gradient exchange the step pays after compute (None under
    // SE = 1 models, where communication is priced free).
    if let Some(tail) = plan.exchange_tail_s.filter(|&t| t > 0.0) {
        let tid = hw.links.len() as u64;
        rec.track(PID_NETWORK, "network", tid, "gradient exchange");
        rec.complete(
            PID_NETWORK, tid,
            &format!("{} all-reduce x{}", plan.collective,
                     plan.dp_workers),
            device_extent_us, tail * 1e6,
            vec![("buckets".into(),
                  Json::Num(plan.overlap_buckets as f64))]);
    }
    Ok(rec.to_chrome_string())
}

/// GPipe stage×microbatch unroll, replayed through the simulator.
fn pipelined_tracks(rec: &TraceRecorder,
                    prof: &crate::models::ModelProfile,
                    hw: &crate::cluster::HwGraph, plan: &Plan, tf: f64)
                    -> Result<f64> {
    let stages = plan.mp_degree;
    let m = plan.microbatches.unwrap_or(2);
    let (p, cfg, _times) = cost::gpipe_schedule(prof, hw, stages)?;
    let (pdfg, ptimes, stage_of) = pipeline::pipeline_dfg(&p, m, &cfg);
    let devs = hw.devices();
    if devs.len() < stages {
        bail!("topology has {} devices, pipeline needs {stages}",
              devs.len());
    }
    let placement: Vec<usize> =
        stage_of.iter().map(|&st| devs[st]).collect();
    let r = sim::simulate(&pdfg, hw, &placement, &ptimes,
                          SimConfig::ideal())?;
    for st in 0..stages {
        rec.track(PID_DEVICES, "devices", devs[st] as u64,
                  &format!("gpu{} (stage {st})", devs[st]));
    }
    for i in 0..pdfg.n_ops() {
        rec.complete(
            PID_DEVICES, placement[i] as u64, &pdfg.ops[i].name,
            us(r.op_start[i], tf), us(r.op_finish[i] - r.op_start[i], tf),
            vec![("stage".into(), Json::Num(stage_of[i] as f64))]);
    }
    transfer_tracks(rec, hw, &pdfg, &r.transfers, tf);
    Ok(us(r.makespan, tf))
}

/// DLPlacer assignment, replayed op-for-op through the simulator.
fn placed_tracks(rec: &TraceRecorder, planner: &Planner,
                 prof: &crate::models::ModelProfile,
                 hw: &crate::cluster::HwGraph, plan: &Plan, tf: f64)
                 -> Result<f64> {
    let placement = plan
        .placement
        .clone()
        .ok_or_else(|| anyhow!("placed plan carries no placement"))?;
    let (fps, launch) = planner.cost().op_time_params();
    let times = prof.dfg.op_times(fps, launch);
    let r = sim::simulate(&prof.dfg, hw, &placement, &times,
                          SimConfig::ideal())?;
    let mut devs: Vec<usize> = placement.clone();
    devs.sort_unstable();
    devs.dedup();
    for &d in &devs {
        rec.track(PID_DEVICES, "devices", d as u64, &format!("gpu{d}"));
    }
    for i in 0..prof.dfg.n_ops() {
        rec.complete(PID_DEVICES, placement[i] as u64,
                     &prof.dfg.ops[i].name, us(r.op_start[i], tf),
                     us(r.op_finish[i] - r.op_start[i], tf), vec![]);
    }
    transfer_tracks(rec, hw, &prof.dfg, &r.transfers, tf);
    Ok(us(r.makespan, tf))
}

/// Tensor-parallel / layer-wise strategies have no executable DFG
/// schedule in the planner — one coarse worker-step span per rank, sized
/// from the chosen candidate's SU^M, keeps their timelines honest about
/// what the model actually priced.
fn coarse_tracks(rec: &TraceRecorder, planner: &Planner,
                 prof: &crate::models::ModelProfile,
                 hw: &crate::cluster::HwGraph, plan: &Plan, tf: f64)
                 -> Result<f64> {
    let (fps, launch) = planner.cost().op_time_params();
    let serial: f64 = prof.dfg.op_times(fps, launch).iter().sum();
    let su_m = plan
        .scorecard
        .iter()
        .find(|c| c.mp_degree == plan.mp_degree
              && c.mechanism == plan.mechanism)
        .map(|c| c.su_m)
        .unwrap_or(1.0);
    let step_worker = serial / su_m;
    let devs = hw.devices();
    for rank in 0..plan.mp_degree.min(devs.len()) {
        let d = devs[rank];
        rec.track(PID_DEVICES, "devices", d as u64,
                  &format!("gpu{d} (rank {rank})"));
        rec.complete(
            PID_DEVICES, d as u64,
            &format!("{} step (M={})", plan.mechanism, plan.mp_degree),
            0.0, us(step_worker, tf),
            vec![("su_m".into(), Json::Num(su_m))]);
    }
    Ok(us(step_worker, tf))
}

/// DP / single-device plans: the serial op chain on one representative
/// replica (every DP worker runs the identical schedule).
fn serial_tracks(rec: &TraceRecorder, planner: &Planner,
                 prof: &crate::models::ModelProfile, plan: &Plan, tf: f64)
                 -> f64 {
    let (fps, launch) = planner.cost().op_time_params();
    let times = prof.dfg.op_times(fps, launch);
    let label = if plan.dp_workers > 1 {
        format!("gpu0 (replica 0 of {})", plan.dp_workers)
    } else {
        "gpu0".to_string()
    };
    rec.track(PID_DEVICES, "devices", 0, &label);
    let mut t = 0.0f64;
    for (i, &dt) in times.iter().enumerate() {
        rec.complete(PID_DEVICES, 0, &prof.dfg.ops[i].name, us(t, tf),
                     us(dt, tf), vec![]);
        t += dt;
    }
    us(t, tf)
}

/// One network track per link that carried a transfer slice.
fn transfer_tracks(rec: &TraceRecorder, hw: &crate::cluster::HwGraph,
                   dfg: &crate::dfg::Dfg,
                   transfers: &[sim::Transfer], tf: f64) {
    for t in transfers {
        let l = &hw.links[t.link];
        rec.track(PID_NETWORK, "network", t.link as u64,
                  &format!("link{} ({}-{})", t.link, l.a, l.b));
        rec.complete(
            PID_NETWORK, t.link as u64,
            &format!("{}->{}", dfg.ops[t.src_op].name,
                     dfg.ops[t.dst_op].name),
            us(t.start_s, tf), us(t.dur_s, tf),
            vec![("bytes".into(), Json::Num(t.bytes))]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(doc: &str) -> Json {
        Json::parse(doc.trim_end()).unwrap()
    }

    #[test]
    fn pipelined_timeline_has_one_track_per_stage() {
        let planner = Planner::new();
        // 16 GB parts force BigLSTM off DP onto the 2-stage pipeline.
        let req = PlanRequest::new("biglstm", "dgx1")
            .devices(8)
            .device_mem_gb(16.0);
        let plan = planner.plan(&req).unwrap();
        assert_eq!(plan.mechanism, "pipelined");
        let doc = plan_timeline(&planner, &req, &plan).unwrap();
        let j = parse(&doc);
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // One thread_name metadata row per stage on the devices pid.
        let tracks = evs
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() == "M"
                    && e.get("name").unwrap().as_str().unwrap()
                        == "thread_name"
                    && e.get("pid").unwrap().as_usize().unwrap()
                        == PID_DEVICES as usize
            })
            .count();
        assert_eq!(tracks, plan.mp_degree);
        // Every device track carries at least one span, and the extent
        // matches the plan's predicted step time within 1% (SE = 1).
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() == "X"
                    && e.get("pid").unwrap().as_usize().unwrap()
                        == PID_DEVICES as usize
            })
            .collect();
        assert!(spans.len() >= plan.mp_degree);
        let extent_us = spans
            .iter()
            .map(|e| {
                e.get("ts").unwrap().as_f64().unwrap()
                    + e.get("dur").unwrap().as_f64().unwrap()
            })
            .fold(0.0f64, f64::max);
        let predicted_us = plan.predicted_step_s * 1e6;
        assert!(
            (extent_us - predicted_us).abs() / predicted_us < 0.01,
            "extent {extent_us} µs vs predicted {predicted_us} µs");
    }

    #[test]
    fn timelines_are_byte_identical_across_runs() {
        let planner = Planner::new();
        let req = PlanRequest::new("gnmt", "dgx1").devices(8);
        let plan = planner.plan(&req).unwrap();
        let a = plan_timeline(&planner, &req, &plan).unwrap();
        let b = plan_timeline(&planner, &req, &plan).unwrap();
        assert_eq!(a, b);
        assert!(a.ends_with('\n'));
    }

    #[test]
    fn dp_plan_renders_a_representative_replica() {
        let planner = Planner::new();
        let req = PlanRequest::new("inception-v3", "dgx1").devices(8);
        let plan = planner.plan(&req).unwrap();
        assert_eq!(plan.mp_degree, 1);
        let doc = plan_timeline(&planner, &req, &plan).unwrap();
        let j = parse(&doc);
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let n_spans = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .count();
        // One span per DFG op on the representative replica.
        assert!(n_spans >= 3);
    }
}
