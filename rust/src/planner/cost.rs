//! Pluggable cost models behind the planner.
//!
//! The paper predicts strategy performance three different ways — the
//! closed-form Eq. 1–6 projection under the conservative SE_N = 1
//! assumption (§4.3), a topology-aware α-β collective model for realistic
//! scaling efficiency (the DP gradient exchange priced as the best
//! feasible algorithm — ring, tree, or two-level hierarchical — for the
//! candidate's device set, per [`crate::collective::best_allreduce`]),
//! and "silicon" measurements (stood in for here by the discrete-event
//! simulator, Fig. 8).  [`CostModel`] makes the three interchangeable
//! behind one trait so a [`crate::planner::Planner`] prediction can be
//! cross-checked: plan with [`AnalyticalCost`], re-plan with
//! [`SimulatorCost`], and compare.
//!
//! Model-parallel mechanism selection follows the paper's Table 1: branchy
//! DFGs (Inception-V3) are partitioned with DLPlacer, chain DFGs (GNMT,
//! BigLSTM, the transformer LM) are pipelined.  The choice is made
//! structurally — a graph with any multi-successor vertex is "branchy" —
//! not by matching model names.
//!
//! In addition to that structural default, every model exposes an
//! *explicit* GPipe estimate via [`CostModel::pipelined_mp_step_time`]:
//! branchy graphs are pipelined along their topological linearisation, so
//! the planner can weigh `PipelinedHybrid` candidates (the pipelined
//! ConvNet hybrids PaSE and the Oracle paper show winning at high device
//! counts) against the placed ones instead of never seeing them.

use anyhow::Result;

use crate::cluster::HwGraph;
use crate::collective::TopoProfile;
use crate::memory::{self, MemoryEstimate, MemoryModel};
use crate::models::ModelProfile;
use crate::parallel::overlap::OverlapModel;
use crate::parallel::ScalingEfficiency;
use crate::pipeline::{self, PipeConfig};
use crate::placer::{self, PlacerOptions};
use crate::sim::{self, SimConfig};

/// How a cost model realised M-way model parallelism.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpMechanism {
    /// M = 1: one device, no model parallelism.
    None,
    /// DLPlacer op-to-device partition (branchy graphs).
    Placed,
    /// GPipe-style stage pipeline (chain graphs).
    Pipelined,
}

impl MpMechanism {
    pub fn as_str(&self) -> &'static str {
        match self {
            MpMechanism::None => "none",
            MpMechanism::Placed => "placed",
            MpMechanism::Pipelined => "pipelined",
        }
    }
}

/// A cost model's estimate for one worker running the model under M-way
/// model parallelism.
#[derive(Clone, Debug)]
pub struct MpEstimate {
    /// Predicted per-step time of the M-device worker (seconds).
    pub step_time_s: f64,
    pub mechanism: MpMechanism,
    /// Op → device assignment when `mechanism == Placed`.
    pub placement: Option<Vec<usize>>,
    /// Stage boundaries (topo positions) when `mechanism == Pipelined`.
    pub pipeline_bounds: Option<Vec<usize>>,
    /// Chosen micro-batch count when pipelined.
    pub microbatches: Option<usize>,
}

impl MpEstimate {
    fn serial(step_time_s: f64) -> Self {
        MpEstimate {
            step_time_s,
            mechanism: MpMechanism::None,
            placement: None,
            pipeline_bounds: None,
            microbatches: None,
        }
    }
}

/// A pluggable predictor of strategy performance on a concrete topology.
///
/// `Send + Sync` is part of the contract so one model can be shared across
/// the worker threads of [`crate::planner::sweep`]; every implementation
/// here is plain data (or interior-mutexed, for the sweep's memo cache).
pub trait CostModel: Send + Sync {
    /// Short identifier ("analytical", "alpha-beta", "simulator").
    fn name(&self) -> &'static str;

    /// Per-step time of one worker executing `prof` under `m`-way model
    /// parallelism on (the first `m` devices of) `hw`, using the paper's
    /// Table 1 structural mechanism choice — DLPlacer partition for
    /// branchy graphs, GPipe pipeline for chains.  `m == 1` is the
    /// single-device baseline.
    fn mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                    -> Result<MpEstimate>;

    /// Per-step time of one worker executing `prof` as a `stages`-stage
    /// GPipe pipeline over (the first `stages` devices of) `hw`,
    /// *regardless* of graph shape: branchy graphs are pipelined along
    /// their topological linearisation
    /// ([`crate::pipeline::partition_stages`]).  This is the estimate
    /// behind the planner's
    /// [`crate::coordinator::Strategy::PipelinedHybrid`] candidates — the
    /// class of pipelined ConvNet hybrids (PaSE, the Oracle paper) that a
    /// placement-only search misses.  `stages == 1` is the single-device
    /// baseline.
    fn pipelined_mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph,
                              stages: usize) -> Result<MpEstimate>;

    /// SE_N source for data parallelism over `hw`, given the per-worker
    /// compute time `step_compute_s` and the requested DP device budget
    /// `devices` (which may exceed the physical box — a projection).
    fn scaling(&self, prof: &ModelProfile, hw: &HwGraph,
               step_compute_s: f64, devices: usize) -> ScalingEfficiency;

    /// Per-device footprint of the worker layout `est` describes, under
    /// the accounting model `mem` — reported alongside the step time so
    /// [`crate::planner::Planner::plan`] can mark candidates
    /// [`crate::memory::Feasibility::Infeasible`] instead of scoring
    /// them.  Dispatches on the estimate's artifacts: stage bounds →
    /// GPipe stashing ([`crate::memory::pipelined`]), a placement →
    /// per-device sums ([`crate::memory::placed`]), neither → the whole
    /// model on one device ([`crate::memory::single_device`], the M = 1
    /// baseline every DP replica shares).
    fn memory_estimate(&self, prof: &ModelProfile, est: &MpEstimate,
                       mem: &MemoryModel) -> Result<MemoryEstimate> {
        if let Some(bounds) = &est.pipeline_bounds {
            memory::pipelined(prof, mem, bounds,
                              est.microbatches.unwrap_or(1))
        } else if let Some(assignment) = &est.placement {
            Ok(memory::placed(prof, mem, assignment))
        } else {
            Ok(memory::single_device(prof, mem))
        }
    }

    /// The (flops_per_sec, launch_overhead_s) pair this model derives
    /// per-op times Δ(k) from — exported so the layer-wise search
    /// ([`crate::layerwise::solve`]) prices op configurations with the
    /// *same* device-rate assumptions as the fixed candidates it sits
    /// next to in a scorecard.  Models that wrap another model forward to
    /// it; the default is the analytical model's blended V100 rate.
    fn op_time_params(&self) -> (f64, f64) {
        (7e12, 15e-6)
    }
}

/// Resolve a cost model by name.
pub fn cost_by_name(name: &str) -> Result<Box<dyn CostModel>> {
    Ok(match name {
        "analytical" | "eq1-6" => Box::new(AnalyticalCost::default()),
        "alpha-beta" | "ring" => Box::new(AlphaBetaCost::default()),
        "simulator" | "sim" | "silicon" => Box::new(SimulatorCost::default()),
        other => anyhow::bail!(
            "unknown cost model '{other}' \
             (known: analytical, alpha-beta, simulator)"),
    })
}

/// True iff no vertex has more than one successor (a pure layer chain).
fn is_chain(prof: &ModelProfile) -> bool {
    prof.dfg.successors().iter().all(|s| s.len() <= 1)
}

/// Stage partition with the memory-balanced objective: per-stage resident
/// bytes (the DFG's raw M(k), the same weights + activations the placer's
/// Eq. 13 rows use) capped at the smallest device memory of `hw`.  On the
/// topologies where the cap never binds this is byte-identical to the
/// unconstrained [`pipeline::partition_stages`]; when the compute-optimal
/// cut would overload a device it shifts to the best split that fits.
///
/// This is deliberately the *structural* Eq. 13 bound — identical to what
/// the placer ILP enforces for placed candidates — not the full training
/// footprint (gradients + optimizer state + stash multipliers), which the
/// planner judges separately via [`CostModel::memory_estimate`] on the
/// resulting bounds.  Cost models cannot see the accounting
/// [`MemoryModel`] (it is a per-request planner input), so the two bounds
/// can disagree: a partition can pass the raw cap and still be marked
/// infeasible by the accounting layer.  The accounting verdict is the
/// source of truth; the cap only keeps the *cut placement* from parking
/// more raw bytes on a stage than the device physically holds.
fn stage_partition(prof: &ModelProfile, hw: &HwGraph, times: &[f64],
                   stages: usize) -> Result<pipeline::Partition> {
    let op_mem: Vec<f64> =
        prof.dfg.ops.iter().map(|o| o.mem_bytes).collect();
    pipeline::partition_stages_capped(&prof.dfg, times, stages, &op_mem,
                                      hw.min_device_mem())
}

/// Inter-stage link (bandwidth, latency) between the first two devices of
/// `hw` — NVLink on a DGX-1, the NVSwitch fabric on a DGX-2.
fn stage_link(hw: &HwGraph) -> (f64, f64) {
    let devs = hw.devices();
    if devs.len() >= 2 {
        if let Ok((_, path)) = hw.route(devs[0], devs[1], 1.0) {
            let bw = path
                .iter()
                .map(|&li| hw.links[li].bandwidth)
                .fold(f64::INFINITY, f64::min);
            let lat: f64 =
                path.iter().map(|&li| hw.links[li].latency).sum();
            if bw.is_finite() && bw > 0.0 {
                return (bw, lat);
            }
        }
    }
    (25e9, 1.3e-6) // NVLink defaults
}

/// Rebuild the GPipe schedule behind a pipelined candidate so the trace
/// layer ([`crate::planner::timeline`]) can replay it through the
/// simulator: the memory-capped stage partition, the pipeline timing
/// knobs, and the per-op Δ(k) times.  These are exactly the artifacts
/// every cost model's pipelined estimate is built from — all three share
/// the analytical Δ(k) derivation — so a timeline rendered from them
/// shows the same schedule the estimate priced.
pub fn gpipe_schedule(prof: &ModelProfile, hw: &HwGraph, stages: usize)
                      -> Result<(pipeline::Partition, PipeConfig, Vec<f64>)>
{
    let a = AnalyticalCost::default();
    let times =
        prof.dfg.op_times(a.flops_per_sec, a.launch_overhead_s);
    let cfg = a.pipe_cfg(prof, hw);
    let p = stage_partition(prof, hw, &times, stages)?;
    Ok((p, cfg, times))
}

// ==========================================================================
// Analytical (Eq. 1–6, SE = 1)
// ==========================================================================

/// The paper's analytical framework: DLPlacer / pipeline analytics for
/// SU^M, perfect scaling efficiency (§4.3's conservative assumption).
///
/// **Validity domain** — closed-form Eq. 1–6 projections.  SE_N = 1 means
/// DP communication is free, so DP-side predictions are *upper* bounds
/// (the paper argues this minimises the projected hybrid benefit).  MP
/// predictions assume fully-overlapped stage transfers and DLPlacer's
/// idealised communication (paper §6 assumptions); against the
/// discrete-event simulator they agree within ~15% on DGX-1-class
/// topologies (the Fig. 8 tolerance, enforced by
/// `tests/integration_planner.rs`).  Projections beyond the physical box
/// are exact under the model, not measurements.
#[derive(Clone, Debug)]
pub struct AnalyticalCost {
    /// Sustained device throughput used to derive Δ(k) from FLOPs.
    pub flops_per_sec: f64,
    /// Per-kernel launch overhead added to every Δ(k).
    pub launch_overhead_s: f64,
    /// Micro-batch search ceiling for pipelined MP.
    pub max_microbatches: usize,
    pub placer: PlacerOptions,
}

impl Default for AnalyticalCost {
    fn default() -> Self {
        AnalyticalCost {
            flops_per_sec: 7e12,    // blended sustained V100 rate
            launch_overhead_s: 15e-6,
            max_microbatches: 16,
            placer: PlacerOptions::default(),
        }
    }
}

impl AnalyticalCost {
    /// Pipeline timing knobs for `prof` running on `hw`'s stage link.
    fn pipe_cfg(&self, prof: &ModelProfile, hw: &HwGraph) -> PipeConfig {
        let (bw, lat) = stage_link(hw);
        PipeConfig {
            mini_batch: prof.mini_batch,
            saturation_batch: prof.pipe_saturation,
            link_bandwidth: bw,
            link_latency: lat,
            ..Default::default()
        }
    }

    /// Overlap-aware GPipe estimate: partition (any DAG, topo-linearised,
    /// per-stage resident bytes capped at the device's Mem(n) so the
    /// partition itself is memory-balanced), search the micro-batch
    /// count, report the analytic schedule time.
    fn pipelined_estimate(&self, prof: &ModelProfile, hw: &HwGraph,
                          stages: usize) -> Result<MpEstimate> {
        let times = prof.dfg.op_times(self.flops_per_sec,
                                      self.launch_overhead_s);
        if stages <= 1 {
            return Ok(MpEstimate::serial(times.iter().sum()));
        }
        let cfg = self.pipe_cfg(prof, hw);
        let p = stage_partition(prof, hw, &times, stages)?;
        let (m, t, _su) =
            pipeline::best_microbatches(&p, self.max_microbatches, cfg);
        Ok(MpEstimate {
            step_time_s: t,
            mechanism: MpMechanism::Pipelined,
            placement: None,
            pipeline_bounds: Some(p.bounds),
            microbatches: Some(m),
        })
    }

    fn estimate(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                -> Result<MpEstimate> {
        let times = prof.dfg.op_times(self.flops_per_sec,
                                      self.launch_overhead_s);
        let serial: f64 = times.iter().sum();
        if m <= 1 {
            return Ok(MpEstimate::serial(serial));
        }
        if is_chain(prof) {
            self.pipelined_estimate(prof, hw, m)
        } else {
            let opts = PlacerOptions {
                max_devices: m,
                ..self.placer.clone()
            };
            let p = placer::place(&prof.dfg, hw, &times, &opts)?;
            Ok(MpEstimate {
                step_time_s: p.predicted_time,
                mechanism: MpMechanism::Placed,
                placement: Some(p.assignment),
                pipeline_bounds: None,
                microbatches: None,
            })
        }
    }
}

impl CostModel for AnalyticalCost {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                    -> Result<MpEstimate> {
        self.estimate(prof, hw, m)
    }

    fn pipelined_mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph,
                              stages: usize) -> Result<MpEstimate> {
        self.pipelined_estimate(prof, hw, stages)
    }

    fn scaling(&self, _prof: &ModelProfile, _hw: &HwGraph,
               _step_compute_s: f64, _devices: usize) -> ScalingEfficiency {
        ScalingEfficiency::Perfect
    }

    fn op_time_params(&self) -> (f64, f64) {
        (self.flops_per_sec, self.launch_overhead_s)
    }
}

// ==========================================================================
// α-β collective model
// ==========================================================================

/// Same MP analytics as [`AnalyticalCost`], but SE_N comes from α-β
/// collective pricing over the topology's chassis shape
/// ([`TopoProfile`]): every DP/hybrid gradient exchange is priced as the
/// best feasible algorithm for the candidate's device set — flat chunked
/// ring, binary tree, or two-level hierarchical all-reduce (intra-node
/// reduce-scatter / inter-node rings / intra-node allgather) — instead of
/// assuming a flat ring across the slow inter-node fabric.
///
/// **Validity domain** — inherits the analytical MP model (same
/// tolerances); the SE_N term assumes bandwidth-optimal chunked
/// collectives over store-and-forward link paths, exact for exchanges
/// that fit the physical box and conservative (NIC-path effective
/// bandwidth) once a projection spills across nodes.  By default the
/// exchange is charged serially after the step (the paper's assumption);
/// `PlanRequest::{overlap_buckets, compression}` switch SE_N to the
/// bucketed comm/compute-overlap charge of
/// [`crate::parallel::overlap::overlapped_step`], which hides each
/// bucket's all-reduce under the remaining backward time and prices only
/// the exposed tail (compression scales bytes, never the α latency
/// floor).  `PlanRequest::collective` can pin one algorithm for
/// ablations (`--collective ring` recovers the old flat-ring pricing).
#[derive(Clone, Debug)]
pub struct AlphaBetaCost {
    pub inner: AnalyticalCost,
    /// Per-step software overhead added to every hop's wire latency.
    pub alpha: f64,
}

impl Default for AlphaBetaCost {
    fn default() -> Self {
        AlphaBetaCost { inner: AnalyticalCost::default(), alpha: 5e-6 }
    }
}

impl CostModel for AlphaBetaCost {
    fn name(&self) -> &'static str {
        "alpha-beta"
    }

    fn mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                    -> Result<MpEstimate> {
        self.inner.estimate(prof, hw, m)
    }

    fn pipelined_mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph,
                              stages: usize) -> Result<MpEstimate> {
        self.inner.pipelined_estimate(prof, hw, stages)
    }

    fn scaling(&self, prof: &ModelProfile, hw: &HwGraph,
               step_compute_s: f64, devices: usize) -> ScalingEfficiency {
        ScalingEfficiency::Collective {
            step_compute_s,
            grad_bytes: prof.grad_bytes,
            alpha: self.alpha,
            topo: TopoProfile::for_budget(hw, devices),
            force: None,
            overlap: OverlapModel::default(),
        }
    }

    fn op_time_params(&self) -> (f64, f64) {
        self.inner.op_time_params()
    }
}

// ==========================================================================
// Discrete-event simulator ("silicon")
// ==========================================================================

/// Predicts MP step time by *executing* the DFG on the discrete-event
/// simulator — link contention and per-transfer software overhead included
/// (the effects the ILP ignores, Fig. 8).
///
/// Branchy graphs are placed (DLPlacer) and simulated as one step.  Chains
/// — and any graph queried through [`CostModel::pipelined_mp_step_time`] —
/// are unrolled into their stage × micro-batch GPipe schedule
/// ([`crate::pipeline::pipeline_dfg`]) and *that* graph is simulated, so
/// micro-batch overlap is fully visible to the discrete-event model and
/// the analytic [`crate::pipeline::gpipe_time`] bound can be cross-checked
/// against an executed schedule.
///
/// **Validity domain** — the most detailed model here: serialised link
/// contention and per-transfer software overhead, but still simulation,
/// not silicon.  On a balanced partition with ideal links the pipelined
/// makespan equals the analytic `(m + S - 1) × bottleneck` bound exactly;
/// with the default contention/overhead knobs it tracks the analytical
/// model within ~15% (placed, Fig. 8 tolerance) / ~20% (pipelined) on
/// DGX-class topologies.  Requires the topology to physically hold the
/// requested stages/devices — it will not extrapolate past the box.
#[derive(Clone, Debug)]
pub struct SimulatorCost {
    /// Supplies Δ(k) derivation, placer options and the α-β SE model.
    pub inner: AlphaBetaCost,
    pub sim: SimConfig,
}

impl Default for SimulatorCost {
    fn default() -> Self {
        SimulatorCost {
            inner: AlphaBetaCost::default(),
            sim: SimConfig::default(),
        }
    }
}

impl CostModel for SimulatorCost {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph, m: usize)
                    -> Result<MpEstimate> {
        let a = &self.inner.inner;
        let times = prof.dfg.op_times(a.flops_per_sec, a.launch_overhead_s);
        if m <= 1 {
            return Ok(MpEstimate::serial(times.iter().sum()));
        }
        if is_chain(prof) {
            // Chains pipeline (Table 1); the unrolled GPipe DAG makes
            // micro-batch overlap visible to the discrete-event model.
            return self.pipelined_mp_step_time(prof, hw, m);
        }
        let opts = PlacerOptions { max_devices: m, ..a.placer.clone() };
        let p = placer::place(&prof.dfg, hw, &times, &opts)?;
        let r = sim::simulate(&prof.dfg, hw, &p.assignment, &times,
                              self.sim)?;
        Ok(MpEstimate {
            step_time_s: r.makespan,
            mechanism: MpMechanism::Placed,
            placement: Some(p.assignment),
            pipeline_bounds: None,
            microbatches: None,
        })
    }

    fn pipelined_mp_step_time(&self, prof: &ModelProfile, hw: &HwGraph,
                              stages: usize) -> Result<MpEstimate> {
        let a = &self.inner.inner;
        let times = prof.dfg.op_times(a.flops_per_sec, a.launch_overhead_s);
        if stages <= 1 {
            return Ok(MpEstimate::serial(times.iter().sum()));
        }
        let devs = hw.devices();
        if devs.len() < stages {
            anyhow::bail!(
                "a {stages}-stage pipeline needs {stages} devices, \
                 '{}' has {}", hw.name, devs.len());
        }
        let cfg = a.pipe_cfg(prof, hw);
        let p = stage_partition(prof, hw, &times, stages)?;
        // Micro-batch count from the analytic search; the *time* from
        // executing the unrolled schedule under contention + overhead.
        let (m, _analytic, _su) =
            pipeline::best_microbatches(&p, a.max_microbatches, cfg);
        let (pdfg, ptimes, stage_of) = pipeline::pipeline_dfg(&p, m, &cfg);
        let placement: Vec<usize> =
            stage_of.iter().map(|&st| devs[st]).collect();
        let r = sim::simulate(&pdfg, hw, &placement, &ptimes, self.sim)?;
        Ok(MpEstimate {
            step_time_s: r.makespan,
            mechanism: MpMechanism::Pipelined,
            placement: None,
            pipeline_bounds: Some(p.bounds),
            microbatches: Some(m),
        })
    }

    fn scaling(&self, prof: &ModelProfile, hw: &HwGraph,
               step_compute_s: f64, devices: usize) -> ScalingEfficiency {
        self.inner.scaling(prof, hw, step_compute_s, devices)
    }

    fn op_time_params(&self) -> (f64, f64) {
        self.inner.op_time_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::models;

    #[test]
    fn chain_detection() {
        assert!(is_chain(&models::gnmt(128)));
        assert!(is_chain(&models::biglstm(64)));
        assert!(!is_chain(&models::inception_v3(32)));
    }

    #[test]
    fn op_time_params_forward_through_wrappers() {
        // The layer-wise search prices ops with the same Δ(k) derivation
        // as the model it rides along with — wrappers must forward.
        let tweaked = AnalyticalCost {
            flops_per_sec: 9e12,
            launch_overhead_s: 1e-6,
            ..Default::default()
        };
        assert_eq!(tweaked.op_time_params(), (9e12, 1e-6));
        let ab = AlphaBetaCost { inner: tweaked.clone(), alpha: 5e-6 };
        assert_eq!(ab.op_time_params(), (9e12, 1e-6));
        let sim = SimulatorCost { inner: ab, ..Default::default() };
        assert_eq!(sim.op_time_params(), (9e12, 1e-6));
        assert_eq!(AnalyticalCost::default().op_time_params(),
                   (7e12, 15e-6));
    }

    #[test]
    fn serial_estimate_matches_op_times() {
        let c = AnalyticalCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(2);
        let est = c.mp_step_time(&prof, &hw, 1).unwrap();
        let serial: f64 =
            prof.dfg.op_times(7e12, 15e-6).iter().sum();
        assert!((est.step_time_s - serial).abs() < 1e-12);
        assert_eq!(est.mechanism, MpMechanism::None);
    }

    #[test]
    fn chain_mp_is_pipelined_and_faster() {
        let c = AnalyticalCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let one = c.mp_step_time(&prof, &hw, 1).unwrap().step_time_s;
        let est = c.mp_step_time(&prof, &hw, 2).unwrap();
        assert_eq!(est.mechanism, MpMechanism::Pipelined);
        assert!(est.step_time_s < one, "pipelining must help");
        assert!(est.microbatches.unwrap() >= 2);
    }

    #[test]
    fn branchy_mp_is_placed() {
        let c = AnalyticalCost::default();
        let prof = models::inception_v3(32);
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let est = c.mp_step_time(&prof, &hw, 2).unwrap();
        assert_eq!(est.mechanism, MpMechanism::Placed);
        let assign = est.placement.unwrap();
        assert_eq!(assign.len(), prof.dfg.n_ops());
        assert!(assign.iter().any(|&d| d != assign[0]),
                "placement must use both devices");
    }

    #[test]
    fn stage_link_is_nvlink_on_dgx1() {
        let (bw, lat) = stage_link(&cluster::dgx1(4));
        assert!((bw - 25e9).abs() < 1.0);
        assert!((lat - 1.3e-6).abs() < 1e-12);
    }

    #[test]
    fn cost_by_name_resolves() {
        assert_eq!(cost_by_name("analytical").unwrap().name(), "analytical");
        assert_eq!(cost_by_name("ring").unwrap().name(), "alpha-beta");
        assert_eq!(cost_by_name("sim").unwrap().name(), "simulator");
        assert!(cost_by_name("oracle").is_err());
    }

    #[test]
    fn simulator_pipelines_chains_with_visible_overlap() {
        // The fixed comment of record: GPipe micro-batch overlap used to be
        // invisible to the discrete-event model (chains were placed); the
        // unrolled schedule now executes for real and must beat serial.
        let s = SimulatorCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let serial = s.mp_step_time(&prof, &hw, 1).unwrap().step_time_s;
        let est = s.mp_step_time(&prof, &hw, 2).unwrap();
        assert_eq!(est.mechanism, MpMechanism::Pipelined);
        assert!(est.microbatches.unwrap() >= 2);
        assert!(est.pipeline_bounds.is_some());
        assert!(est.step_time_s < serial,
                "overlap must show: {} vs serial {serial}",
                est.step_time_s);
    }

    #[test]
    fn simulator_tracks_analytic_gpipe_bound() {
        let a = AnalyticalCost::default();
        let s = SimulatorCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let ae = a.pipelined_mp_step_time(&prof, &hw, 2).unwrap();
        let se = s.pipelined_mp_step_time(&prof, &hw, 2).unwrap();
        assert_eq!(ae.microbatches, se.microbatches);
        assert_eq!(ae.pipeline_bounds, se.pipeline_bounds);
        let gap = (ae.step_time_s - se.step_time_s).abs() / se.step_time_s;
        assert!(gap < 0.20,
                "analytic {} vs simulated {} (gap {:.1}%)",
                ae.step_time_s, se.step_time_s, gap * 100.0);
    }

    #[test]
    fn branchy_graphs_get_explicit_pipelined_estimates() {
        // Inception is placed by default, but the explicit pipelined
        // estimate must exist (topo linearisation) for PipelinedHybrid
        // candidates — and stay a *valid* pipeline (bounds monotone).
        let c = AnalyticalCost::default();
        let prof = models::inception_v3(32);
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let est = c.pipelined_mp_step_time(&prof, &hw, 2).unwrap();
        assert_eq!(est.mechanism, MpMechanism::Pipelined);
        let bounds = est.pipeline_bounds.unwrap();
        assert_eq!(bounds.len(), 3);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let serial = c.mp_step_time(&prof, &hw, 1).unwrap().step_time_s;
        assert!(est.step_time_s < serial, "pipelining must help inception");
    }

    #[test]
    fn simulator_rejects_pipelines_deeper_than_the_box() {
        let s = SimulatorCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(2);
        assert!(s.pipelined_mp_step_time(&prof, &hw, 4).is_err());
    }

    #[test]
    fn memory_estimate_dispatches_on_mechanism() {
        use crate::memory::MemoryModel;
        let c = AnalyticalCost::default();
        let mm = MemoryModel::default();
        let hw = cluster::dgx1_mem(2, cluster::V100_32G_MEM);

        // M = 1: the whole model on one device.
        let prof = models::biglstm(64);
        let serial = c.mp_step_time(&prof, &hw, 1).unwrap();
        let m1 = c.memory_estimate(&prof, &serial, &mm).unwrap();
        let direct = crate::memory::single_device(&prof, &mm);
        assert_eq!(m1, direct);

        // Pipelined: stage bounds drive the estimate, peak below serial.
        let pipe = c.pipelined_mp_step_time(&prof, &hw, 2).unwrap();
        let mp = c.memory_estimate(&prof, &pipe, &mm).unwrap();
        assert!(mp.total_bytes < m1.total_bytes,
                "2 stages must shrink the peak: {} vs {}",
                mp.total_bytes, m1.total_bytes);

        // Placed: inception's DLPlacer assignment spreads weights.
        let inc = models::inception_v3(32);
        let placed = c.mp_step_time(&inc, &hw, 2).unwrap();
        assert_eq!(placed.mechanism, MpMechanism::Placed);
        let mplaced = c.memory_estimate(&inc, &placed, &mm).unwrap();
        let whole = crate::memory::single_device(&inc, &mm);
        assert!(mplaced.total_bytes <= whole.total_bytes + 1.0);
    }

    #[test]
    fn stage_partition_caps_at_device_memory() {
        // A topology with devices too small for the compute-optimal cut
        // must shift the boundary; identical to unconstrained on roomy
        // devices.
        let c = AnalyticalCost::default();
        let prof = models::biglstm(64);
        let roomy = cluster::dgx1_mem(2, cluster::V100_32G_MEM);
        let e32 = c.pipelined_mp_step_time(&prof, &roomy, 2).unwrap();
        // 3.3 GB parts cannot hold the compute-optimal second stage
        // (lstm1 + the 3.25 GB softmax ≈ 3.55 GB): the cut must shift to
        // the softmax-only stage, trading balance for footprint.
        let tiny = cluster::dgx1_mem(2, 3.3e9);
        let e33 = c.pipelined_mp_step_time(&prof, &tiny, 2).unwrap();
        assert_ne!(e32.pipeline_bounds, e33.pipeline_bounds,
                   "cap must move the cut on 3.3 GB parts");
        assert!(e33.step_time_s >= e32.step_time_s - 1e-12,
                "memory-feasible cut cannot beat the unconstrained one");
        // And devices too small for any split error loudly.
        let hopeless = cluster::dgx1_mem(2, 1e9);
        assert!(c.pipelined_mp_step_time(&prof, &hopeless, 2).is_err());
    }

    #[test]
    fn alpha_beta_scaling_decays() {
        let c = AlphaBetaCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(8);
        let se = c.scaling(&prof, &hw, 0.1, 8);
        assert!(se.at(8) < 1.0);
        assert!(se.at(8) > 0.0);
    }

    #[test]
    fn projection_beyond_box_uses_conservative_bandwidth() {
        // A 256-device exchange does not fit the 8-GPU DGX-1: pricing
        // must spill over the slow NIC path, not stay on NVLink.
        let c = AlphaBetaCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(8);
        let inside = c.scaling(&prof, &hw, 0.1, 8);
        let beyond = c.scaling(&prof, &hw, 0.1, 256);
        assert!(beyond.at(256) < inside.at(256),
                "spilled exchange must see slower fabric: {} vs {}",
                beyond.at(256), inside.at(256));
        // Simulator delegates to the same model.
        let s = SimulatorCost::default();
        let ss = s.scaling(&prof, &hw, 0.1, 256);
        assert!((ss.at(256) - beyond.at(256)).abs() < 1e-12);
    }

    #[test]
    fn overlap_threads_through_alpha_beta_scaling() {
        // The planner applies PlanRequest::{overlap_buckets, compression}
        // via with_overlap on whatever scaling() returned — the default
        // construction must be overlap-off and the override must help.
        let c = AlphaBetaCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::multi_node(4, 8);
        let off = c.scaling(&prof, &hw, 0.1, 32);
        let on = off.clone().with_overlap(
            OverlapModel { buckets: 8, compression: 0.25 });
        assert!(on.at(32) > off.at(32),
                "overlap+compression must raise SE: {} vs {}",
                on.at(32), off.at(32));
        // Defaults are the identity — the fig5 floors depend on this.
        let same = off.clone().with_overlap(OverlapModel::default());
        assert_eq!(off.at(32), same.at(32));
    }

    #[test]
    fn multi_node_scaling_prices_the_hierarchical_collective() {
        use crate::collective::Algorithm;
        let c = AlphaBetaCost::default();
        let prof = models::gnmt(128);
        let hw = cluster::multi_node(4, 8);
        let se = c.scaling(&prof, &hw, 0.1, 32);
        assert_eq!(se.collective_algorithm(32),
                   Some(Algorithm::Hierarchical),
                   "multi-node DP must not be priced as a flat ring");
        let flat = se.clone().with_forced(Some(Algorithm::Ring));
        assert!(se.at(32) > flat.at(32),
                "hierarchical pricing must strictly beat flat-ring: \
                 {} vs {}", se.at(32), flat.at(32));
        // Single-box pricing keeps the ring (nothing to gain in-box).
        let box8 = cluster::dgx1(8);
        let se_box = c.scaling(&prof, &box8, 0.1, 8);
        assert_eq!(se_box.collective_algorithm(8), Some(Algorithm::Ring));
    }
}
