//! Model and topology registries: the planner's name → builder catalogs.
//!
//! Before the planner existed, every entry point (`main.rs`, each example,
//! each bench) re-wired the same string-match literals — `"inception" =>
//! models::inception_v3(32)` and friends — with the per-model default batch
//! sizes duplicated at every call site.  The registries centralise that
//! knowledge: one place owns the catalog of networks (with the paper's
//! per-GPU mini-batches as defaults) and one place owns the topology
//! builders, and callers resolve by name or alias.
//!
//! Both registries are extensible at runtime so downstream users can add
//! their own networks/clusters without forking the crate.
//!
//! The registries are also the name resolution layer for the sweep
//! engine: every `models` / `topologies` axis entry of a
//! [`crate::planner::sweep::SweepSpec`] resolves here, so an unknown name
//! surfaces as a per-scenario error listing the catalog.

use anyhow::{bail, Result};

use crate::cluster::{self, HwGraph};
use crate::models::{self, ModelProfile};

/// One registered network: canonical name, accepted aliases, the paper's
/// default per-GPU mini-batch, and a builder parameterised by mini-batch.
#[derive(Clone)]
pub struct ModelEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Default per-device mini-batch (the size `main.rs` and the examples
    /// used to hard-code at every call site).
    pub default_batch: usize,
    pub build: fn(usize) -> ModelProfile,
}

/// Catalog of networks the planner can reason about.
#[derive(Clone, Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

fn build_transformer(b: usize) -> ModelProfile {
    // Mirrors the AOT-compiled python/compile/model.py configuration used
    // by `main.rs` (4 layers, d_model 128, d_ff 512, vocab 512, seq 64).
    models::transformer_lm(4, 128.0, 512.0, 512.0, 64.0, b)
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    /// The built-in catalog: the paper's three evaluation networks with
    /// their §4 per-GPU mini-batches, plus this repo's transformer LM.
    pub fn builtin() -> Self {
        let mut r = ModelRegistry::new();
        r.register(ModelEntry {
            name: "inception-v3",
            aliases: &["inception", "inceptionv3"],
            default_batch: 32,
            build: models::inception_v3,
        });
        r.register(ModelEntry {
            name: "gnmt",
            aliases: &[],
            default_batch: 128,
            build: models::gnmt,
        });
        r.register(ModelEntry {
            name: "biglstm",
            aliases: &["big-lstm"],
            default_batch: 64,
            build: models::biglstm,
        });
        r.register(ModelEntry {
            name: "transformer-lm",
            aliases: &["transformer"],
            default_batch: 8,
            build: build_transformer,
        });
        // 70B/100B-class entries: infeasible under every replicated-state
        // candidate at 80 GB/device — the scenarios that need the
        // TensorParallel × ZeRO axes (docs/3d-parallelism.md).
        r.register(ModelEntry {
            name: "transformer-70b",
            aliases: &["70b", "transformer70b"],
            default_batch: 4,
            build: models::transformer_70b,
        });
        r.register(ModelEntry {
            name: "transformer-100b",
            aliases: &["100b", "transformer100b"],
            default_batch: 4,
            build: models::transformer_100b,
        });
        r
    }

    /// Add (or shadow) an entry.  Later registrations win on name clashes.
    pub fn register(&mut self, entry: ModelEntry) {
        self.entries.push(entry);
    }

    /// Canonical names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// The raw catalog, registration order (the service's `GET /models`
    /// listing; later duplicates shadow earlier ones at lookup time).
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    fn find(&self, name: &str) -> Option<&ModelEntry> {
        // Reverse scan so later registrations shadow earlier ones.
        self.entries
            .iter()
            .rev()
            .find(|e| e.name == name || e.aliases.contains(&name))
    }

    /// Canonical name for `name` (resolving aliases), if registered.
    /// Lets callers key per-model tables off one spelling instead of
    /// re-implementing alias matching (see `sweep::BatchSpec::Paper`).
    pub fn canonical_name(&self, name: &str) -> Option<&'static str> {
        self.find(name).map(|e| e.name)
    }

    /// Default mini-batch for a registered model.
    pub fn default_batch(&self, name: &str) -> Result<usize> {
        match self.find(name) {
            Some(e) => Ok(e.default_batch),
            None => bail!("unknown model '{name}' (known: {})",
                          self.names().join(", ")),
        }
    }

    /// Build a profile by name/alias, with an optional mini-batch override.
    pub fn build(&self, name: &str, batch: Option<usize>)
                 -> Result<ModelProfile> {
        match self.find(name) {
            Some(e) => Ok((e.build)(batch.unwrap_or(e.default_batch))),
            None => bail!("unknown model '{name}' (known: {})",
                          self.names().join(", ")),
        }
    }
}

/// One registered topology: builder parameterised by device budget.
#[derive(Clone)]
pub struct TopologyEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Largest device count the physical system offers; requests beyond it
    /// are projections (the paper projects to 256 GPUs from an 8-GPU box).
    pub max_devices: usize,
    pub build: fn(usize) -> HwGraph,
    /// Multi-node builder `(nodes, device budget) → graph` for entries
    /// that can span chassis (`dgx1-pod`, `cloud-25gbe`, `multinode`) —
    /// the planner's `--nodes` axis.  `None` marks a single-box system:
    /// requests with more than one node are rejected.
    pub build_pod: Option<fn(usize, usize) -> HwGraph>,
}

/// Catalog of hardware topologies.
#[derive(Clone, Default)]
pub struct TopologyRegistry {
    entries: Vec<TopologyEntry>,
}

fn build_dgx1(n: usize) -> HwGraph {
    // 32 GB V100s so BigLSTM fits (the paper's §4.1 system).
    cluster::dgx1_mem(n.clamp(1, 8), cluster::V100_32G_MEM)
}

fn build_dgx2(n: usize) -> HwGraph {
    cluster::dgx2(n.clamp(1, 16))
}

fn build_dgx_a100(n: usize) -> HwGraph {
    cluster::dgx_a100(n.clamp(1, 8))
}

fn build_multinode(n: usize) -> HwGraph {
    cluster::multi_node(n.div_ceil(4).max(1), 4)
}

fn build_multinode_pod(nodes: usize, _devices: usize) -> HwGraph {
    cluster::multi_node(nodes.max(1), 4)
}

fn build_dgx1_pod(n: usize) -> HwGraph {
    cluster::dgx1_pod(n.div_ceil(8).max(1))
}

fn build_dgx1_pod_nodes(nodes: usize, _devices: usize) -> HwGraph {
    cluster::dgx1_pod(nodes.max(1))
}

fn build_cloud(n: usize) -> HwGraph {
    cluster::cloud_25gbe(n.div_ceil(8).max(1))
}

fn build_cloud_nodes(nodes: usize, _devices: usize) -> HwGraph {
    cluster::cloud_25gbe(nodes.max(1))
}

impl TopologyRegistry {
    pub fn new() -> Self {
        TopologyRegistry::default()
    }

    /// Built-in catalog: the paper's DGX-1 testbed, a 16-GPU NVSwitch
    /// DGX-2-style system (a scenario the paper did not evaluate), an
    /// 8-GPU A100-80GB box (the memory-feasibility counterpart to the
    /// 16 GB V100), the IB-switched multi-node scale-out its projections
    /// assume, plus the pod systems of the collective-selection layer:
    /// `dgx1-pod` (N × 8 V100-32GB cube-mesh chassis over InfiniBand)
    /// and `cloud-25gbe` (N × 8 V100-16GB instances over 25 GbE).
    pub fn builtin() -> Self {
        let mut r = TopologyRegistry::new();
        r.register(TopologyEntry {
            name: "dgx1",
            aliases: &["dgx-1"],
            max_devices: 8,
            build: build_dgx1,
            build_pod: None,
        });
        r.register(TopologyEntry {
            name: "dgx2",
            aliases: &["dgx-2", "nvswitch"],
            max_devices: 16,
            build: build_dgx2,
            build_pod: None,
        });
        r.register(TopologyEntry {
            name: "dgx-a100",
            aliases: &["a100", "dgxa100"],
            max_devices: 8,
            build: build_dgx_a100,
            build_pod: None,
        });
        r.register(TopologyEntry {
            name: "multinode",
            aliases: &["multi-node", "cluster"],
            max_devices: usize::MAX,
            build: build_multinode,
            build_pod: Some(build_multinode_pod),
        });
        r.register(TopologyEntry {
            name: "dgx1-pod",
            aliases: &["pod", "dgx1pod"],
            max_devices: usize::MAX,
            build: build_dgx1_pod,
            build_pod: Some(build_dgx1_pod_nodes),
        });
        r.register(TopologyEntry {
            name: "cloud-25gbe",
            aliases: &["cloud", "25gbe"],
            max_devices: usize::MAX,
            build: build_cloud,
            build_pod: Some(build_cloud_nodes),
        });
        r
    }

    pub fn register(&mut self, entry: TopologyEntry) {
        self.entries.push(entry);
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// The raw catalog, registration order (the service's
    /// `GET /topologies` listing).
    pub fn entries(&self) -> &[TopologyEntry] {
        &self.entries
    }

    fn find(&self, name: &str) -> Option<&TopologyEntry> {
        self.entries
            .iter()
            .rev()
            .find(|e| e.name == name || e.aliases.contains(&name))
    }

    /// Build a hardware graph sized for `devices` (clamped to the
    /// topology's physical maximum — the planner treats larger requests as
    /// scale-out projections).
    pub fn build(&self, name: &str, devices: usize) -> Result<HwGraph> {
        match self.find(name) {
            Some(e) => Ok((e.build)(devices)),
            None => bail!("unknown topology '{name}' (known: {})",
                          self.names().join(", ")),
        }
    }

    /// Physical device ceiling of a topology.
    pub fn max_devices(&self, name: &str) -> Result<usize> {
        match self.find(name) {
            Some(e) => Ok(e.max_devices),
            None => bail!("unknown topology '{name}' (known: {})",
                          self.names().join(", ")),
        }
    }

    /// Build a hardware graph spanning `nodes` chassis (the `--nodes`
    /// axis).  Single-box topologies accept only `nodes ≤ 1` (falling
    /// back to the plain builder); multi-node-capable entries size by
    /// chassis count.
    pub fn build_nodes(&self, name: &str, nodes: usize, devices: usize)
                       -> Result<HwGraph> {
        let Some(e) = self.find(name) else {
            bail!("unknown topology '{name}' (known: {})",
                  self.names().join(", "));
        };
        match e.build_pod {
            Some(f) => Ok(f(nodes.max(1), devices)),
            None if nodes <= 1 => Ok((e.build)(devices)),
            None => {
                let multi: Vec<&str> = self
                    .entries
                    .iter()
                    .filter(|t| t.build_pod.is_some())
                    .map(|t| t.name)
                    .collect();
                bail!("topology '{}' is a single box and cannot span {} \
                       nodes (multi-node capable: {})",
                      e.name, nodes, multi.join(", "))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_aliases_resolve() {
        let r = ModelRegistry::builtin();
        for name in ["inception", "inception-v3", "inceptionv3"] {
            let p = r.build(name, None).unwrap();
            assert_eq!(p.name, "inception-v3");
            assert_eq!(p.mini_batch, 32, "default batch deduplicated");
        }
        assert_eq!(r.build("gnmt", None).unwrap().mini_batch, 128);
        assert_eq!(r.build("biglstm", None).unwrap().mini_batch, 64);
        assert_eq!(r.build("transformer", None).unwrap().name,
                   "transformer-lm");
        let p70 = r.build("70b", None).unwrap();
        assert_eq!(p70.name, "transformer-70b");
        assert_eq!(p70.mini_batch, 4);
        assert_eq!(r.build("100b", None).unwrap().name, "transformer-100b");
    }

    #[test]
    fn canonical_name_resolves_aliases() {
        let r = ModelRegistry::builtin();
        assert_eq!(r.canonical_name("inception"), Some("inception-v3"));
        assert_eq!(r.canonical_name("inception-v3"), Some("inception-v3"));
        assert_eq!(r.canonical_name("big-lstm"), Some("biglstm"));
        assert_eq!(r.canonical_name("alexnet"), None);
    }

    #[test]
    fn batch_override_wins() {
        let r = ModelRegistry::builtin();
        assert_eq!(r.build("inception", Some(64)).unwrap().mini_batch, 64);
    }

    #[test]
    fn unknown_model_lists_catalog() {
        let r = ModelRegistry::builtin();
        let err = r.build("alexnet", None).unwrap_err().to_string();
        assert!(err.contains("inception-v3"), "{err}");
    }

    #[test]
    fn later_registration_shadows() {
        let mut r = ModelRegistry::builtin();
        r.register(ModelEntry {
            name: "inception-v3",
            aliases: &[],
            default_batch: 99,
            build: models::inception_v3,
        });
        assert_eq!(r.build("inception-v3", None).unwrap().mini_batch, 99);
    }

    #[test]
    fn topologies_resolve_and_clamp() {
        let r = TopologyRegistry::builtin();
        assert_eq!(r.build("dgx1", 256).unwrap().n_devices(), 8);
        assert_eq!(r.build("dgx2", 16).unwrap().n_devices(), 16);
        assert!(r.build("multinode", 8).unwrap().n_devices() >= 8);
        assert!(r.build("ringworld", 4).is_err());
        assert_eq!(r.max_devices("dgx2").unwrap(), 16);
    }

    #[test]
    fn pod_topologies_resolve_and_span_nodes() {
        let r = TopologyRegistry::builtin();
        // Single-arg sizing derives the chassis count from the budget.
        assert_eq!(r.build("dgx1-pod", 32).unwrap().n_devices(), 32);
        assert_eq!(r.build("cloud", 16).unwrap().n_devices(), 16);
        // Explicit --nodes sizing.
        let pod = r.build_nodes("dgx1-pod", 4, 32).unwrap();
        assert_eq!(pod.n_devices(), 32);
        assert_eq!(pod.node_groups().len(), 4);
        assert!((pod.min_device_mem() - cluster::V100_32G_MEM).abs() < 1.0);
        let cloud = r.build_nodes("25gbe", 2, 16).unwrap();
        assert_eq!(cloud.node_groups().len(), 2);
        let mn = r.build_nodes("multinode", 3, 12).unwrap();
        assert_eq!(mn.n_devices(), 12);
        // Single-box entries reject nodes > 1, accept nodes <= 1.
        assert!(r.build_nodes("dgx1", 2, 16).is_err());
        assert_eq!(r.build_nodes("dgx1", 1, 8).unwrap().n_devices(), 8);
        assert!(r.build_nodes("ringworld", 2, 8).is_err());
        let err = r.build_nodes("dgx2", 4, 64).unwrap_err().to_string();
        assert!(err.contains("dgx1-pod"),
                "error must list multi-node-capable entries: {err}");
    }

    #[test]
    fn dgx_a100_registered_with_80gb_parts() {
        let r = TopologyRegistry::builtin();
        for name in ["dgx-a100", "a100", "dgxa100"] {
            let hw = r.build(name, 8).unwrap();
            assert_eq!(hw.n_devices(), 8);
            assert!((hw.min_device_mem() - cluster::A100_80G_MEM).abs()
                    < 1.0);
        }
        assert_eq!(r.max_devices("a100").unwrap(), 8);
    }
}
