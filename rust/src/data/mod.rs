//! Synthetic training data (ImageNet / WMT'16 / 1B-word stand-ins).
//!
//! The token stream is a first-order Markov chain with Zipf-distributed
//! transition tables: it has real learnable structure (bigram statistics),
//! so cross-entropy on it decreases with training and — crucially for the
//! Fig. 4 analog — *how fast* it decreases depends on optimization quality,
//! which is what the batch-size sweep measures.  Deterministic per seed so
//! every simulated DP worker can slice the same corpus reproducibly.

use crate::util::rng::Rng;

/// Markov-chain token stream generator.
#[derive(Clone, Debug)]
pub struct TokenStream {
    vocab: usize,
    /// transition[v] = candidate next tokens for v (top-K Zipf heads).
    transition: Vec<Vec<u32>>,
    rng: Rng,
    state: u32,
    /// Tokens generated so far (drives epoch accounting).
    pub tokens_emitted: u64,
}

impl TokenStream {
    /// Build a stream over `vocab` tokens with `branching` successors per
    /// token, seeded deterministically.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Self {
        let mut table_rng = Rng::new(seed ^ 0xD1F);
        let transition = (0..vocab)
            .map(|_| {
                (0..branching.max(1))
                    .map(|_| table_rng.below(vocab as u64) as u32)
                    .collect()
            })
            .collect();
        TokenStream {
            vocab,
            transition,
            rng: Rng::new(seed),
            state: 0,
            tokens_emitted: 0,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Next token: mostly follow the Markov table (learnable), sometimes
    /// jump uniformly (irreducible noise floor).
    pub fn next_token(&mut self) -> u32 {
        self.tokens_emitted += 1;
        let t = if self.rng.f64() < 0.9 {
            let succ = &self.transition[self.state as usize];
            // Zipf-ish: earlier successors more likely.
            let w: Vec<f64> =
                (0..succ.len()).map(|i| 1.0 / (i + 1) as f64).collect();
            succ[self.rng.weighted(&w)]
        } else {
            self.rng.below(self.vocab as u64) as u32
        };
        self.state = t;
        t
    }

    /// Fill an (batch, seq+1) i32 buffer; returns (tokens, targets) where
    /// targets are tokens shifted by one.
    pub fn next_batch(&mut self, batch: usize, seq: usize)
                      -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token() as i32;
            for _ in 0..seq {
                let next = self.next_token() as i32;
                tokens.push(prev);
                targets.push(next);
                prev = next;
            }
        }
        (tokens, targets)
    }
}

/// Dataset abstraction with epoch accounting: `epoch_tokens` tokens form
/// one epoch (the S term in C = T·S·E is epoch_tokens / global batch
/// tokens).
#[derive(Clone, Debug)]
pub struct Corpus {
    pub stream: TokenStream,
    pub epoch_tokens: u64,
}

impl Corpus {
    pub fn new(vocab: usize, epoch_tokens: u64, seed: u64) -> Self {
        Corpus { stream: TokenStream::new(vocab, 8, seed), epoch_tokens }
    }

    /// Steps per epoch at a given global batch (in sequences) and seq len —
    /// the paper's S_N = |dataset| / global_batch.
    pub fn steps_per_epoch(&self, global_batch: usize, seq: usize) -> u64 {
        self.epoch_tokens / (global_batch as u64 * seq as u64)
    }

    /// Epochs completed after emitting this many tokens.
    pub fn epochs_done(&self) -> f64 {
        self.stream.tokens_emitted as f64 / self.epoch_tokens as f64
    }
}

/// Synthetic image batch (Inception-analog completeness): deterministic
/// Gaussian NCHW tensor with class-dependent mean so it's classifiable.
pub fn image_batch(batch: usize, chw: (usize, usize, usize), classes: usize,
                   seed: u64) -> (Vec<f32>, Vec<i32>) {
    let (c, h, w) = chw;
    let mut rng = Rng::new(seed);
    let mut pixels = Vec::with_capacity(batch * c * h * w);
    let mut labels = Vec::with_capacity(batch);
    for _ in 0..batch {
        let class = rng.below(classes as u64) as i32;
        labels.push(class);
        let mean = (class as f32 / classes as f32) - 0.5;
        for _ in 0..c * h * w {
            pixels.push(mean + 0.25 * rng.normal() as f32);
        }
    }
    (pixels, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = TokenStream::new(100, 4, 7);
        let mut b = TokenStream::new(100, 4, 7);
        for _ in 0..500 {
            assert_eq!(a.next_token(), b.next_token());
        }
        let mut c = TokenStream::new(100, 4, 8);
        let same = (0..200).filter(|_| a.next_token() == c.next_token()).count();
        assert!(same < 50, "different seeds should diverge");
    }

    #[test]
    fn tokens_in_vocab() {
        let mut s = TokenStream::new(37, 4, 1);
        for _ in 0..2000 {
            assert!((s.next_token() as usize) < 37);
        }
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut s = TokenStream::new(64, 4, 3);
        let (tok, tgt) = s.next_batch(4, 16);
        assert_eq!(tok.len(), 64);
        assert_eq!(tgt.len(), 64);
        // Within each row, targets are the next token.
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(tgt[row * 16 + i], tok[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn stream_has_bigram_structure() {
        // Markov stream must be far from uniform: measure repeat mass of
        // the top successor.
        let mut s = TokenStream::new(256, 4, 5);
        let mut follows = std::collections::HashMap::new();
        let mut prev = s.next_token();
        for _ in 0..20_000 {
            let t = s.next_token();
            *follows.entry((prev, t)).or_insert(0usize) += 1;
            prev = t;
        }
        // Unique bigrams should be much fewer than uniform would give.
        assert!(follows.len() < 6000,
                "bigrams {} suggests no structure", follows.len());
    }

    #[test]
    fn corpus_accounting() {
        let c = Corpus::new(128, 10_000, 0);
        assert_eq!(c.steps_per_epoch(8, 25), 50);
        let mut c2 = c.clone();
        c2.stream.next_batch(8, 25);
        assert!(c2.epochs_done() > 0.019 && c2.epochs_done() < 0.022);
    }

    #[test]
    fn image_batch_classes() {
        let (px, lb) = image_batch(16, (3, 8, 8), 10, 2);
        assert_eq!(px.len(), 16 * 3 * 8 * 8);
        assert_eq!(lb.len(), 16);
        assert!(lb.iter().all(|&l| l >= 0 && l < 10));
    }
}
