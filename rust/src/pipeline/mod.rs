//! Pipeline parallelism (GPipe-style, paper §2 & §4.4).
//!
//! The paper implements 2-way MP for GNMT and BigLSTM by pipelining:
//! partition the layer chain into stages, split the mini-batch into
//! micro-batches, and overlap stages on different devices.  This module
//!
//! * partitions a chain DFG into balanced stages ([`partition_chain`]),
//!   and — for the planner's `PipelinedHybrid` candidates — any DAG along
//!   its topological linearisation ([`partition_stages`]),
//! * computes the GPipe schedule time analytically ([`gpipe_time`]) —
//!   fill/drain bubble included — with per-microbatch kernel overhead (the
//!   paper's observed pipeline-speedup killer for fused RNN kernels, §4.4),
//! * searches the best micro-batch count ([`best_microbatches`]),
//! * converts it into the per-step MP speedup SU^M used in Eq. 5, and
//! * unrolls the schedule into an executable stage×micro-batch DFG
//!   ([`pipeline_dfg`]) so the discrete-event simulator ([`crate::sim`])
//!   can *execute* the overlapped schedule instead of guessing at it.

use anyhow::{bail, Result};

use crate::dfg::Dfg;

/// A stage partition of a chain: `bounds[i]..bounds[i+1]` are the op
/// indices (in topo order) of stage i.
#[derive(Clone, Debug)]
pub struct Partition {
    pub bounds: Vec<usize>,
    /// Seconds of compute per stage for a FULL mini-batch.
    pub stage_times: Vec<f64>,
    /// Activation bytes crossing each stage boundary.
    pub cut_bytes: Vec<f64>,
}

impl Partition {
    pub fn n_stages(&self) -> usize {
        self.stage_times.len()
    }
}

/// Balanced contiguous partition of a chain DFG into `n_stages`, minimising
/// the max stage time (DP over prefix sums — optimal for contiguous
/// partitions).  Requires a pure chain (each op one successor); use
/// [`partition_stages`] for arbitrary DAGs.
pub fn partition_chain(dfg: &Dfg, times: &[f64], n_stages: usize)
                       -> Result<Partition> {
    let order = dfg.topo_order()?;
    let n = order.len();
    // Verify chain-ness in topo order.
    let succ = dfg.successors();
    for (i, &v) in order.iter().enumerate() {
        if i + 1 < n && !(succ[v].len() == 1 && succ[v][0] == order[i + 1]) {
            bail!("DFG '{}' is not a chain at op {}", dfg.name, v);
        }
    }
    partition_stages(dfg, times, n_stages)
}

/// Balanced contiguous partition of `dfg`'s topological linearisation into
/// `n_stages`, minimising the max stage time (DP over prefix sums — optimal
/// among contiguous partitions of that linearisation).
///
/// For a pure chain the linearisation *is* the chain, so this equals
/// [`partition_chain`].  For branchy DAGs it is the pipeline-parallel
/// relaxation behind the planner's `PipelinedHybrid` candidates (the
/// PaSE-style pipelined ConvNet hybrids): every edge runs forward in topo
/// order, so each stage depends only on earlier stages and the GPipe
/// schedule stays valid.  `cut_bytes[i]` aggregates *every* edge crossing
/// boundary `i`; an edge that skips stages is charged at each boundary it
/// crosses, modelling the traffic of a linear device chain.
pub fn partition_stages(dfg: &Dfg, times: &[f64], n_stages: usize)
                        -> Result<Partition> {
    partition_stages_capped(dfg, times, n_stages, &[], f64::INFINITY)
}

/// [`partition_stages`] with a **memory-balanced objective**: minimise the
/// max stage time *subject to* every stage's resident bytes
/// (`Σ op_mem[k]` over its ops) staying under `mem_cap` — the per-device
/// Mem(n) bound of paper Eq. 13, applied to pipeline stages.  When the
/// unconstrained optimum would overload a device, the DP shifts the cut to
/// the best split that fits (possibly a worse compute bottleneck — the
/// footprint/speed trade the memory-feasibility layer makes explicit),
/// and errors only when *no* contiguous `n_stages`-way split fits.
///
/// An infinite `mem_cap` (or an empty `op_mem`) disables the constraint
/// and recovers [`partition_stages`] exactly.
pub fn partition_stages_capped(dfg: &Dfg, times: &[f64], n_stages: usize,
                               op_mem: &[f64], mem_cap: f64)
                               -> Result<Partition> {
    let order = dfg.topo_order()?;
    let n = order.len();
    if n_stages == 0 || n_stages > n {
        bail!("bad stage count {n_stages} for {n} ops");
    }
    let capped = mem_cap.is_finite() && !op_mem.is_empty();
    if capped && op_mem.len() != dfg.n_ops() {
        bail!("op_mem has {} entries for {} ops", op_mem.len(),
              dfg.n_ops());
    }
    let t: Vec<f64> = order.iter().map(|&v| times[v]).collect();
    let prefix: Vec<f64> = std::iter::once(0.0)
        .chain(t.iter().scan(0.0, |acc, &x| {
            *acc += x;
            Some(*acc)
        }))
        .collect();
    // Memory prefix over the same linearisation (only when constrained).
    let mem_prefix: Vec<f64> = if capped {
        std::iter::once(0.0)
            .chain(order.iter().scan(0.0, |acc, &v| {
                *acc += op_mem[v];
                Some(*acc)
            }))
            .collect()
    } else {
        Vec::new()
    };
    // dp[s][i] = min over j of max(dp[s-1][j], sum t[j..i]), restricted to
    // segments j..i whose memory fits the cap.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; n_stages + 1];
    let mut cut = vec![vec![0usize; n + 1]; n_stages + 1];
    dp[0][0] = 0.0;
    for s in 1..=n_stages {
        for i in s..=n {
            for j in (s - 1)..i {
                if capped && mem_prefix[i] - mem_prefix[j] > mem_cap {
                    continue;
                }
                let seg = prefix[i] - prefix[j];
                let v = dp[s - 1][j].max(seg);
                if v < dp[s][i] {
                    dp[s][i] = v;
                    cut[s][i] = j;
                }
            }
        }
    }
    if dp[n_stages][n].is_infinite() {
        bail!("no {n_stages}-stage partition of '{}' fits {:.2} GB per \
               stage", dfg.name, mem_cap / 1e9);
    }
    let mut bounds = vec![n];
    let mut i = n;
    for s in (1..=n_stages).rev() {
        i = cut[s][i];
        bounds.push(i);
    }
    bounds.reverse();
    let stage_times: Vec<f64> = bounds
        .windows(2)
        .map(|w| prefix[w[1]] - prefix[w[0]])
        .collect();
    // Topo position of each op, for the boundary-crossing test.
    let mut pos = vec![0usize; dfg.n_ops()];
    for (p, &v) in order.iter().enumerate() {
        pos[v] = p;
    }
    let cut_bytes: Vec<f64> = bounds[1..bounds.len() - 1]
        .iter()
        .map(|&bi| {
            dfg.edges
                .iter()
                .filter(|e| pos[e.src] < bi && pos[e.dst] >= bi)
                .map(|e| e.bytes)
                .sum()
        })
        .collect();
    Ok(Partition { bounds, stage_times, cut_bytes })
}

/// Pipeline timing knobs.
#[derive(Clone, Copy, Debug)]
pub struct PipeConfig {
    /// Per-microbatch per-stage kernel launch overhead (paper §4.4:
    /// "splitting beyond 2-way provides marginal speedup because of kernel
    /// overheads and pipeline imbalance").
    pub kernel_overhead_s: f64,
    /// Link bandwidth between adjacent stages (bytes/s).
    pub link_bandwidth: f64,
    /// Link latency per transfer.
    pub link_latency: f64,
    /// Mini-batch size the stage times were profiled at.
    pub mini_batch: usize,
    /// GEMM-utilization saturation batch: device utilization at batch x is
    /// x/(x+saturation).  Microbatching below this loses efficiency — the
    /// reason the paper's fused-RNN pipelines top out at ~1.15-1.22x
    /// instead of the ideal GPipe bound.  0 disables the model.
    pub saturation_batch: f64,
}

impl Default for PipeConfig {
    fn default() -> Self {
        PipeConfig {
            kernel_overhead_s: 50e-6,
            link_bandwidth: 25e9, // NVLink
            link_latency: 1.3e-6,
            mini_batch: 64,
            saturation_batch: 16.0,
        }
    }
}

/// Device utilization at batch size x (saturating).
fn util(x: f64, sat: f64) -> f64 {
    if sat <= 0.0 {
        1.0
    } else {
        x / (x + sat)
    }
}

/// Compute-time inflation factor when splitting the mini-batch m ways.
pub fn microbatch_inflation(cfg: &PipeConfig, m: usize) -> f64 {
    if cfg.saturation_batch <= 0.0 || cfg.mini_batch == 0 {
        return 1.0;
    }
    let b = cfg.mini_batch as f64;
    util(b, cfg.saturation_batch) / util(b / m as f64, cfg.saturation_batch)
}

/// GPipe step time for a partition with `m` micro-batches.
///
/// Each stage's per-microbatch time is `stage/m + overhead`; the pipeline
/// completes in `(m + S - 1) · max_stage_micro` plus the boundary transfer
/// costs on the critical path (each boundary crossed once per microbatch,
/// overlapped except fill/drain).
pub fn gpipe_time(p: &Partition, m: usize, cfg: PipeConfig) -> f64 {
    assert!(m >= 1);
    let s = p.n_stages();
    let inflate = microbatch_inflation(&cfg, m);
    let micro: Vec<f64> = p
        .stage_times
        .iter()
        .map(|&t| t * inflate / m as f64 + cfg.kernel_overhead_s)
        .collect();
    let bottleneck = micro.iter().fold(0.0f64, |a, &b| a.max(b));
    let xfer: f64 = p
        .cut_bytes
        .iter()
        .map(|&bts| bts / m as f64 / cfg.link_bandwidth + cfg.link_latency)
        .sum();
    (m + s - 1) as f64 * bottleneck + (s as f64 - 1.0).max(0.0) * 0.0
        + xfer * (m as f64).min(s as f64) // fill-phase transfers not hidden
}

/// Single-device step time for the same work (no pipeline, no overhead).
pub fn serial_time(p: &Partition) -> f64 {
    p.stage_times.iter().sum()
}

/// Unroll a partition's GPipe schedule into an *executable* DFG: one op
/// per (stage, micro-batch) cell, adjacent-stage data edges carrying
/// `cut_bytes / m`, and zero-byte same-stage ordering edges enforcing the
/// in-order micro-batch schedule.  Returns the graph, the per-op times
/// (stage compute split `m` ways, micro-batch inflation and kernel
/// overhead included — the same Δ terms [`gpipe_time`] uses), and each
/// op's stage index.  Mapping stage → device gives a placement that
/// [`crate::sim::simulate`] can execute, which is how GPipe micro-batch
/// overlap is made visible to the discrete-event cost model: on a balanced
/// partition with ideal links the simulated makespan equals the analytic
/// `(m + S - 1) × bottleneck` bound exactly.
pub fn pipeline_dfg(p: &Partition, m: usize, cfg: &PipeConfig)
                    -> (Dfg, Vec<f64>, Vec<usize>) {
    assert!(m >= 1);
    let s = p.n_stages();
    let inflate = microbatch_inflation(cfg, m);
    let mut g = Dfg::new("pipeline-unrolled");
    let mut times = Vec::with_capacity(s * m);
    let mut stage_of = Vec::with_capacity(s * m);
    for micro in 0..m {
        for st in 0..s {
            // Op id = micro * s + st (micro-batch-major insertion order).
            let out_b = if st + 1 < s {
                p.cut_bytes[st] / m as f64
            } else {
                0.0
            };
            let id = g.add_op(&format!("s{st}u{micro}"), 0.0, out_b, 0.0);
            times.push(p.stage_times[st] * inflate / m as f64
                       + cfg.kernel_overhead_s);
            stage_of.push(st);
            if st > 0 {
                // Activations flow to the next stage, split m ways.
                g.add_edge_bytes(id - 1, id, p.cut_bytes[st - 1] / m as f64);
            }
            if micro > 0 {
                // In-order micro-batch schedule on each stage's device.
                g.add_edge_bytes(id - s, id, 0.0);
            }
        }
    }
    (g, times, stage_of)
}

/// Best micro-batch count in [1, max_m]: returns (m, step_time, speedup).
/// Micro-batch count is bounded by the mini-batch size (can't split finer
/// than one sample).
pub fn best_microbatches(p: &Partition, max_m: usize, cfg: PipeConfig)
                         -> (usize, f64, f64) {
    let serial = serial_time(p);
    let mut best = (1, gpipe_time(p, 1, cfg));
    for m in 2..=max_m.max(1) {
        let t = gpipe_time(p, m, cfg);
        if t < best.1 {
            best = (m, t);
        }
    }
    (best.0, best.1, serial / best.1)
}

/// End-to-end MP speedup for pipelining a chain DFG over `n_stages`
/// devices: partitions, searches micro-batches, returns (speedup, detail).
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub partition: Partition,
    pub microbatches: usize,
    pub step_time: f64,
    pub speedup: f64,
}

pub fn pipeline_speedup(dfg: &Dfg, times: &[f64], n_stages: usize,
                        max_micro: usize, cfg: PipeConfig)
                        -> Result<PipelineResult> {
    let p = partition_chain(dfg, times, n_stages)?;
    let (m, t, su) = best_microbatches(&p, max_micro, cfg);
    Ok(PipelineResult {
        partition: p,
        microbatches: m,
        step_time: t,
        speedup: su,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(times: &[f64]) -> (Dfg, Vec<f64>) {
        let mut g = Dfg::new("chain");
        let mut prev = None;
        for (i, _t) in times.iter().enumerate() {
            let op = g.add_op(&format!("op{i}"), 1e9, 1e6, 1.0);
            if let Some(p) = prev {
                g.add_edge(p, op);
            }
            prev = Some(op);
        }
        (g, times.to_vec())
    }

    #[test]
    fn partition_balances() {
        let (g, t) = chain(&[1.0, 1.0, 1.0, 1.0]);
        let p = partition_chain(&g, &t, 2).unwrap();
        assert_eq!(p.stage_times, vec![2.0, 2.0]);
        assert_eq!(p.bounds, vec![0, 2, 4]);
    }

    #[test]
    fn partition_handles_imbalance() {
        // One huge op forces an imbalanced optimum.
        let (g, t) = chain(&[1.0, 10.0, 1.0, 1.0]);
        let p = partition_chain(&g, &t, 2).unwrap();
        let max = p.stage_times.iter().cloned().fold(0.0, f64::max);
        assert!((max - 11.0).abs() < 1e-9 || (max - 10.0).abs() < 1e-9);
        // Optimal contiguous split: [1,10] | [1,1] -> max 11, or
        // [1] [10,1,1] -> 12; DP must find 11.
        assert!((max - 11.0).abs() < 1e-9, "max {max}");
    }

    #[test]
    fn rejects_non_chain() {
        let mut g = Dfg::new("d");
        let a = g.add_op("a", 1.0, 1.0, 1.0);
        let b = g.add_op("b", 1.0, 1.0, 1.0);
        let c = g.add_op("c", 1.0, 1.0, 1.0);
        g.add_edge(a, b);
        g.add_edge(a, c);
        assert!(partition_chain(&g, &[1.0; 3], 2).is_err());
    }

    #[test]
    fn gpipe_bubble_math() {
        // Perfectly balanced 2 stages, no overheads: speedup = m*S/(m+S-1).
        let (g, t) = chain(&[1.0, 1.0]);
        let p = partition_chain(&g, &t, 2).unwrap();
        let cfg = PipeConfig {
            kernel_overhead_s: 0.0,
            link_bandwidth: 1e18,
            link_latency: 0.0,
            mini_batch: 0,
            saturation_batch: 0.0,
        };
        for m in [1usize, 2, 4, 8] {
            let tm = gpipe_time(&p, m, cfg);
            let want = (m + 1) as f64 * (1.0 / m as f64);
            assert!((tm - want).abs() < 1e-9, "m={m}: {tm} vs {want}");
        }
        // m=4: speedup = 2/(5/4) = 1.6.
        let (_, _, su) = best_microbatches(&p, 4, cfg);
        assert!(su > 1.59 && su < 1.78, "su={su}");
    }

    #[test]
    fn kernel_overhead_limits_speedup() {
        let (g, t) = chain(&[0.01, 0.01]);
        let p = partition_chain(&g, &t, 2).unwrap();
        let free = PipeConfig { kernel_overhead_s: 0.0, ..Default::default() };
        let costly = PipeConfig {
            kernel_overhead_s: 2e-3,
            ..Default::default()
        };
        let (_, _, su_free) = best_microbatches(&p, 16, free);
        let (_, _, su_costly) = best_microbatches(&p, 16, costly);
        assert!(su_costly < su_free);
        assert!(su_costly < 1.4, "overhead should cap speedup: {su_costly}");
    }

    #[test]
    fn more_stages_do_not_reduce_bottleneck_below_largest_op() {
        let (g, t) = chain(&[5.0, 1.0, 1.0, 1.0]);
        let p2 = partition_chain(&g, &t, 2).unwrap();
        let p4 = partition_chain(&g, &t, 4).unwrap();
        let m2 = p2.stage_times.iter().cloned().fold(0.0, f64::max);
        let m4 = p4.stage_times.iter().cloned().fold(0.0, f64::max);
        assert!(m4 <= m2 + 1e-12);
        assert!(m4 >= 5.0 - 1e-12, "can't split the big op");
    }

    #[test]
    fn pipeline_speedup_end_to_end() {
        let (g, t) = chain(&[0.1, 0.1, 0.1, 0.1]);
        let r = pipeline_speedup(&g, &t, 2, 8,
                                 PipeConfig::default()).unwrap();
        // With the default utilization model the speedup sits in the
        // paper's observed 1.1-1.5x band for 2-stage RNN pipelines.
        assert!(r.speedup > 1.05 && r.speedup < 1.6, "su={}", r.speedup);
        assert!(r.microbatches >= 2);
    }

    #[test]
    fn microbatch_inflation_monotone() {
        let cfg = PipeConfig { mini_batch: 128, saturation_batch: 16.0,
                               ..Default::default() };
        let mut prev = 0.99;
        for m in [1usize, 2, 4, 8, 16] {
            let f = microbatch_inflation(&cfg, m);
            assert!(f >= prev, "inflation must grow with m");
            prev = f;
        }
        assert!((microbatch_inflation(&cfg, 1) - 1.0).abs() < 1e-12);
        let off = PipeConfig { saturation_batch: 0.0, ..cfg };
        assert_eq!(microbatch_inflation(&off, 8), 1.0);
    }

    #[test]
    fn cut_bytes_recorded() {
        let (g, t) = chain(&[1.0, 1.0, 1.0, 1.0]);
        let p = partition_chain(&g, &t, 2).unwrap();
        assert_eq!(p.cut_bytes.len(), 1);
        assert!((p.cut_bytes[0] - 1e6).abs() < 1.0);
    }

    #[test]
    fn partition_stages_equals_chain_partition_on_chains() {
        let (g, t) = chain(&[1.0, 3.0, 2.0, 1.0, 1.0]);
        for stages in [1usize, 2, 3] {
            let a = partition_chain(&g, &t, stages).unwrap();
            let b = partition_stages(&g, &t, stages).unwrap();
            assert_eq!(a.bounds, b.bounds);
            assert_eq!(a.stage_times, b.stage_times);
            assert_eq!(a.cut_bytes, b.cut_bytes);
        }
    }

    #[test]
    fn partition_stages_linearises_branchy_graphs() {
        // Diamond a -> {b, c} -> d: partition_chain rejects it,
        // partition_stages pipelines its topo linearisation and charges
        // every boundary-crossing edge into cut_bytes.
        let mut g = Dfg::new("d");
        let a = g.add_op("a", 1.0, 4e6, 1.0);
        let b = g.add_op("b", 1.0, 4e6, 1.0);
        let c = g.add_op("c", 1.0, 4e6, 1.0);
        let d = g.add_op("d", 1.0, 4e6, 1.0);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        let times = [1.0, 2.0, 2.0, 1.0];
        assert!(partition_chain(&g, &times, 2).is_err());
        let p = partition_stages(&g, &times, 2).unwrap();
        assert_eq!(p.n_stages(), 2);
        let max = p.stage_times.iter().cloned().fold(0.0, f64::max);
        assert!((max - 3.0).abs() < 1e-9, "balanced split, got {max}");
        // The 2|2 split cuts exactly two of the four edges (a->first-half
        // op's sibling and the sibling->d edge), 4 MB each.
        assert_eq!(p.cut_bytes.len(), 1);
        assert!((p.cut_bytes[0] - 8e6).abs() < 1.0, "{}", p.cut_bytes[0]);
    }

    #[test]
    fn uncapped_partition_equals_partition_stages() {
        let (g, t) = chain(&[1.0, 3.0, 2.0, 1.0, 1.0]);
        let mems = vec![1e9; 5];
        for stages in [1usize, 2, 3] {
            let a = partition_stages(&g, &t, stages).unwrap();
            let b = partition_stages_capped(&g, &t, stages, &mems,
                                            f64::INFINITY)
                .unwrap();
            let c = partition_stages_capped(&g, &t, stages, &[], 1.0)
                .unwrap();
            assert_eq!(a.bounds, b.bounds);
            assert_eq!(a.bounds, c.bounds, "empty op_mem disables the cap");
        }
    }

    #[test]
    fn memory_cap_moves_the_cut() {
        // Compute-optimal 2-way split of [1,1,1,1] is 2|2, but op 0+1
        // together blow a 1.5 GB cap: the DP must shift to 1|3 even
        // though the bottleneck worsens from 2.0 to 3.0.
        let (g, t) = chain(&[1.0, 1.0, 1.0, 1.0]);
        let mems = vec![1e9, 1e9, 0.2e9, 0.2e9];
        let free = partition_stages_capped(&g, &t, 2, &mems, f64::INFINITY)
            .unwrap();
        assert_eq!(free.bounds, vec![0, 2, 4]);
        let capped =
            partition_stages_capped(&g, &t, 2, &mems, 1.5e9).unwrap();
        assert_eq!(capped.bounds, vec![0, 1, 4],
                   "cap must force the lighter first stage");
        let max = capped.stage_times.iter().cloned().fold(0.0, f64::max);
        assert!((max - 3.0).abs() < 1e-9,
                "trades compute balance for footprint");
        // Per-stage memory respects the cap.
        assert!(mems[..1].iter().sum::<f64>() <= 1.5e9);
        assert!(mems[1..].iter().sum::<f64>() <= 1.5e9);
    }

    #[test]
    fn impossible_memory_cap_errors() {
        let (g, t) = chain(&[1.0, 1.0]);
        let mems = vec![2e9, 2e9];
        assert!(partition_stages_capped(&g, &t, 2, &mems, 1e9).is_err());
        assert!(partition_stages_capped(&g, &t, 1, &mems, 3e9).is_err(),
                "single stage cannot fit 4 GB in 3 GB");
        assert!(partition_stages_capped(&g, &t, 2, &mems[..1], 1e9)
                    .is_err(),
                "op_mem length mismatch must be loud");
    }

    #[test]
    fn pipeline_dfg_matches_gpipe_time_under_ideal_links() {
        use crate::cluster::dgx1;
        use crate::sim::{simulate, SimConfig};
        let (g, t) = chain(&[1.0, 1.0, 1.0, 1.0]);
        let p = partition_chain(&g, &t, 2).unwrap();
        let cfg = PipeConfig {
            kernel_overhead_s: 0.0,
            link_bandwidth: 1e18,
            link_latency: 0.0,
            mini_batch: 0,
            saturation_batch: 0.0,
        };
        let hw = dgx1(2);
        let devs = hw.devices();
        for m in [1usize, 2, 4, 8] {
            let (pdfg, ptimes, stage_of) = pipeline_dfg(&p, m, &cfg);
            assert_eq!(pdfg.n_ops(), 2 * m);
            let placement: Vec<usize> =
                stage_of.iter().map(|&s| devs[s]).collect();
            let r = simulate(&pdfg, &hw, &placement, &ptimes,
                             SimConfig::ideal())
                .unwrap();
            let analytic = gpipe_time(&p, m, cfg);
            // Identical up to the (tiny) NVLink transfer of the 1 MB / m
            // boundary activations the analytic xfer term also carries.
            assert!((r.makespan - analytic).abs() < 1e-3,
                    "m={m}: sim {} vs analytic {analytic}", r.makespan);
        }
    }

    #[test]
    fn pipeline_dfg_schedule_is_legal_and_ordered() {
        let (g, t) = chain(&[0.5, 1.0, 0.25, 0.25]);
        let p = partition_chain(&g, &t, 2).unwrap();
        let cfg = PipeConfig::default();
        let (pdfg, ptimes, stage_of) = pipeline_dfg(&p, 4, &cfg);
        assert_eq!(ptimes.len(), 8);
        assert_eq!(stage_of, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // Ordering edges + data edges: (m-1)*s + (s-1)*m = 3*2 + 1*4.
        assert_eq!(pdfg.edges.len(), 10);
        assert!(pdfg.topo_order().is_ok());
    }
}
