//! Analytic DFG builders for the paper's evaluation networks.
//!
//! The paper derives DLPlacer's inputs analytically — "given the
//! input/output tensor sizes of a convolution operation, we calculate the
//! number of FLOPs required, and based on advertised compute capability of
//! NVIDIA's V100, we calculate the operations' expected execution time"
//! (§6, Inception-V3 case study).  This module does exactly that for
//! Inception-V3, GNMT and BigLSTM, producing op-level [`Dfg`]s whose node
//! weights (FLOPs), edge weights (activation bytes) and memory footprints
//! come from the published architectures.
//!
//! FLOPs below are *training* FLOPs (forward + backward ≈ 3× forward) for
//! one mini-batch, since the placement target is a full training step.

use crate::dfg::Dfg;
use crate::statistical::EpochModel;

/// A network profile: DFG + the training-relevant scalars the framework
/// needs (paper Table: per-GPU mini-batch, gradient size for all-reduce).
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: String,
    pub dfg: Dfg,
    /// Per-device mini-batch the paper uses.
    pub mini_batch: usize,
    /// Total parameter bytes (f32) — the all-reduce payload.
    pub grad_bytes: f64,
    /// Epoch-count model calibrated from the paper's Fig. 4.
    pub epochs: EpochModel,
    /// MP strategy used in the paper's Table 1.
    pub mp_strategy: &'static str,
    /// GEMM-utilization saturation batch for pipeline microbatching (see
    /// pipeline::PipeConfig::saturation_batch).  Wider layers saturate the
    /// device at smaller per-microbatch sizes.
    pub pipe_saturation: f64,
}

/// Backward ≈ 2× forward FLOPs; training step ≈ 3× forward.
pub const TRAIN_FACTOR: f64 = 3.0;

fn conv_flops(cin: f64, cout: f64, k: f64, h: f64, w: f64, batch: f64)
              -> f64 {
    2.0 * cin * cout * k * k * h * w * batch * TRAIN_FACTOR
}

fn act_bytes(c: f64, h: f64, w: f64, batch: f64) -> f64 {
    c * h * w * batch * 4.0
}

// ==========================================================================
// Inception-V3 (Szegedy et al. 2015) — branch-level DFG
// ==========================================================================

/// One inception block description.
struct Block {
    name: String,
    cin: f64,
    /// branch name -> conv stack [(k, cin, cout); ...]; k=0 marks a
    /// FLOP-free op (pooling).
    branches: Vec<(&'static str, Vec<(f64, f64, f64)>)>,
    h_out: f64,
    w_out: f64,
}

/// Build the Inception-V3 DFG at branch granularity for mini-batch `b`.
///
/// Architecture follows Szegedy'15: stem convs, 3×Inception-A (35×35),
/// grid reduction, 4×Inception-B (17×17), grid reduction, 2×Inception-C
/// (8×8), global pool + FC.  Branch channel counts are the published ones;
/// FLOPs from the conv formula; 1×7/7×1 factorised convs use an effective
/// k = √14 ≈ 2.65 per conv pair half.
pub fn inception_v3(b: usize) -> ModelProfile {
    let bf = b as f64;
    let mut g = Dfg::new("inception-v3");

    let stem1 = g.add_op(
        "stem/conv1-3",
        conv_flops(3.0, 32.0, 3.0, 149.0, 149.0, bf)
            + conv_flops(32.0, 32.0, 3.0, 147.0, 147.0, bf)
            + conv_flops(32.0, 64.0, 3.0, 147.0, 147.0, bf),
        act_bytes(64.0, 73.0, 73.0, bf),
        120e6,
    );
    let stem2 = g.add_op(
        "stem/conv4-5",
        conv_flops(64.0, 80.0, 1.0, 73.0, 73.0, bf)
            + conv_flops(80.0, 192.0, 3.0, 71.0, 71.0, bf),
        act_bytes(192.0, 35.0, 35.0, bf),
        80e6,
    );
    g.add_edge(stem1, stem2);
    let mut prev = stem2;
    let mut prev_bytes = act_bytes(192.0, 35.0, 35.0, bf);

    let mut blocks: Vec<Block> = Vec::new();
    for (i, cin) in [192.0, 256.0, 288.0].into_iter().enumerate() {
        blocks.push(Block {
            name: format!("mixed{}a", i),
            cin,
            branches: vec![
                ("b1x1", vec![(1.0, cin, 64.0)]),
                ("b5x5", vec![(1.0, cin, 48.0), (5.0, 48.0, 64.0)]),
                ("b3x3dbl", vec![(1.0, cin, 64.0), (3.0, 64.0, 96.0),
                                 (3.0, 96.0, 96.0)]),
                ("bpool", vec![(1.0, cin, if i == 0 { 32.0 } else { 64.0 })]),
            ],
            h_out: 35.0,
            w_out: 35.0,
        });
    }
    blocks.push(Block {
        name: "reduxA".into(),
        cin: 288.0,
        branches: vec![
            ("b3x3s2", vec![(3.0, 288.0, 384.0)]),
            ("b3x3dbl", vec![(1.0, 288.0, 64.0), (3.0, 64.0, 96.0),
                             (3.0, 96.0, 96.0)]),
            ("bpool", vec![(0.0, 288.0, 288.0)]),
        ],
        h_out: 17.0,
        w_out: 17.0,
    });
    for (i, c7) in [128.0, 160.0, 160.0, 192.0].into_iter().enumerate() {
        blocks.push(Block {
            name: format!("mixed{}b", i),
            cin: 768.0,
            branches: vec![
                ("b1x1", vec![(1.0, 768.0, 192.0)]),
                ("b7x7", vec![(1.0, 768.0, c7), (2.65, c7, c7),
                              (2.65, c7, 192.0)]),
                ("b7x7dbl", vec![(1.0, 768.0, c7), (2.65, c7, c7),
                                 (2.65, c7, c7), (2.65, c7, c7),
                                 (2.65, c7, 192.0)]),
                ("bpool", vec![(1.0, 768.0, 192.0)]),
            ],
            h_out: 17.0,
            w_out: 17.0,
        });
    }
    blocks.push(Block {
        name: "reduxB".into(),
        cin: 768.0,
        branches: vec![
            ("b3x3", vec![(1.0, 768.0, 192.0), (3.0, 192.0, 320.0)]),
            ("b7x7x3", vec![(1.0, 768.0, 192.0), (2.65, 192.0, 192.0),
                            (2.65, 192.0, 192.0), (3.0, 192.0, 192.0)]),
            ("bpool", vec![(0.0, 768.0, 768.0)]),
        ],
        h_out: 8.0,
        w_out: 8.0,
    });
    for (i, cin) in [1280.0, 2048.0].into_iter().enumerate() {
        blocks.push(Block {
            name: format!("mixed{}c", i),
            cin,
            branches: vec![
                ("b1x1", vec![(1.0, cin, 320.0)]),
                ("b3x3", vec![(1.0, cin, 384.0), (1.73, 384.0, 768.0)]),
                ("b3x3dbl", vec![(1.0, cin, 448.0), (3.0, 448.0, 384.0),
                                 (1.73, 384.0, 768.0)]),
                ("bpool", vec![(1.0, cin, 192.0)]),
            ],
            h_out: 8.0,
            w_out: 8.0,
        });
    }

    for blk in &blocks {
        let mut branch_outs = Vec::new();
        let mut cat_c = 0.0;
        for (bname, convs) in &blk.branches {
            let mut flops = 0.0;
            let mut cout = blk.cin;
            for &(k, cin, co) in convs {
                if k > 0.0 {
                    flops += conv_flops(cin, co, k, blk.h_out, blk.w_out, bf);
                }
                cout = co;
            }
            cat_c += cout;
            let out_b = act_bytes(cout, blk.h_out, blk.w_out, bf);
            let weight_bytes: f64 = convs
                .iter()
                .map(|&(k, cin, co)| if k > 0.0 { k * k * cin * co * 4.0 }
                     else { 0.0 })
                .sum();
            let op = g.add_op(&format!("{}/{}", blk.name, bname), flops,
                              out_b, weight_bytes + out_b);
            g.add_edge_bytes(prev, op, prev_bytes);
            branch_outs.push((op, out_b));
        }
        let cat_b = act_bytes(cat_c, blk.h_out, blk.w_out, bf);
        let cat = g.add_op(&format!("{}/concat", blk.name), 1e6 * bf, cat_b,
                           cat_b);
        for (op, ob) in branch_outs {
            g.add_edge_bytes(op, cat, ob);
        }
        prev = cat;
        prev_bytes = cat_b;
    }

    let head = g.add_op(
        "head/pool+fc",
        2.0 * 2048.0 * 1000.0 * bf * TRAIN_FACTOR,
        1000.0 * bf * 4.0,
        2048.0 * 1000.0 * 4.0,
    );
    g.add_edge_bytes(prev, head, act_bytes(2048.0, 1.0, 1.0, bf));

    ModelProfile {
        name: "inception-v3".into(),
        dfg: g,
        mini_batch: b,
        grad_bytes: 23.8e6 * 4.0, // 23.8M params
        epochs: EpochModel::inception_v3(),
        pipe_saturation: 8.0,
        mp_strategy: "Partitioned w/ DLPlacer",
    }
}

// ==========================================================================
// GNMT (Wu et al. 2016; paper §4: 4+4 LSTM layers of 1024) — layer chain
// ==========================================================================

/// LSTM layer training FLOPs for input d, hidden h, seq s, batch b.
fn lstm_flops(d: f64, h: f64, s: f64, b: f64) -> f64 {
    2.0 * (d + h) * 4.0 * h * s * b * TRAIN_FACTOR
}

/// GNMT profile: 4 encoder + 4 decoder LSTM layers (1024 wide), attention,
/// softmax over 32k vocab; seq len 40, mini-batch 128 (paper §4.2).
pub fn gnmt(b: usize) -> ModelProfile {
    let bf = b as f64;
    let (h, s, vocab) = (1024.0, 40.0, 32_000.0);
    let mut g = Dfg::new("gnmt");
    let emb = g.add_op("embed", 2.0 * h * s * bf * TRAIN_FACTOR,
                       act_bytes(h, s, 1.0, bf), vocab * h * 4.0);
    let mut prev = emb;
    for i in 0..4 {
        let op = g.add_op(&format!("enc{}", i), lstm_flops(h, h, s, bf),
                          act_bytes(h, s, 1.0, bf),
                          (h + h) * 4.0 * h * 4.0 + act_bytes(h, s, 1.0, bf));
        g.add_edge(prev, op);
        prev = op;
    }
    let attn = g.add_op("attention", 2.0 * s * s * h * bf * TRAIN_FACTOR,
                        act_bytes(h, s, 1.0, bf),
                        act_bytes(h, s, 1.0, bf) * 2.0);
    g.add_edge(prev, attn);
    prev = attn;
    for i in 0..4 {
        let din = if i == 0 { 2.0 * h } else { h };
        let op = g.add_op(&format!("dec{}", i), lstm_flops(din, h, s, bf),
                          act_bytes(h, s, 1.0, bf),
                          (din + h) * 4.0 * h * 4.0
                              + act_bytes(h, s, 1.0, bf));
        g.add_edge(prev, op);
        prev = op;
    }
    let softmax = g.add_op("softmax",
                           2.0 * h * vocab * s * bf * TRAIN_FACTOR,
                           vocab * bf * 4.0, h * vocab * 4.0);
    g.add_edge(prev, softmax);

    ModelProfile {
        name: "gnmt".into(),
        dfg: g,
        mini_batch: b,
        grad_bytes: 160e6 * 4.0, // ~160M params
        epochs: EpochModel::gnmt(),
        pipe_saturation: 16.0,
        mp_strategy: "Pipeline Parallelism",
    }
}

// ==========================================================================
// BigLSTM (Jozefowicz et al. 2016) — embedding, 2×8192 LSTM, big softmax
// ==========================================================================

/// BigLSTM: input embedding 1024, 2 LSTM layers with hidden 8192 (projected
/// to 1024), softmax projection 1024 → 800k vocab (sampled in training);
/// seq 20, mini-batch 64.  Needed the 32 GB V100 in the paper (§4.1).
pub fn biglstm(b: usize) -> ModelProfile {
    let bf = b as f64;
    let (e, h, proj, s, vocab) = (1024.0, 8192.0, 1024.0, 20.0, 793_470.0);
    let mut g = Dfg::new("biglstm");
    let emb = g.add_op("embed", 2.0 * e * s * bf * TRAIN_FACTOR,
                       act_bytes(e, s, 1.0, bf), vocab * e * 4.0 * 0.1);
    let l1 = g.add_op("lstm0",
                      lstm_flops(e, h, s, bf)
                          + 2.0 * h * proj * s * bf * TRAIN_FACTOR,
                      act_bytes(proj, s, 1.0, bf),
                      (e + proj) * 4.0 * h * 4.0 + h * proj * 4.0);
    g.add_edge(emb, l1);
    let l2 = g.add_op("lstm1",
                      lstm_flops(proj, h, s, bf)
                          + 2.0 * h * proj * s * bf * TRAIN_FACTOR,
                      act_bytes(proj, s, 1.0, bf),
                      (proj + proj) * 4.0 * h * 4.0 + h * proj * 4.0);
    g.add_edge(l1, l2);
    // Sampled softmax (≈10% of vocab columns touched per step).
    let softmax = g.add_op("softmax",
                           2.0 * proj * vocab * 0.1 * s * bf * TRAIN_FACTOR,
                           vocab * 0.1 * bf * 4.0,
                           proj * vocab * 4.0); // full 3.2 GB projection resident
    g.add_edge(l2, softmax);

    ModelProfile {
        name: "biglstm".into(),
        dfg: g,
        mini_batch: b,
        grad_bytes: 850e6,
        epochs: EpochModel::biglstm(),
        pipe_saturation: 4.0,
        mp_strategy: "Pipeline Parallelism",
    }
}

/// Our end-to-end transformer LM (mirrors python/compile/model.py) as a
/// DFG for placement/pipeline experiments at matching granularity.
pub fn transformer_lm(n_layers: usize, d_model: f64, d_ff: f64, vocab: f64,
                      seq: f64, b: usize) -> ModelProfile {
    let bf = b as f64;
    let mut g = Dfg::new("transformer-lm");
    let emb = g.add_op("embed", 2.0 * d_model * seq * bf * TRAIN_FACTOR,
                       d_model * seq * bf * 4.0, vocab * d_model * 4.0);
    let mut prev = emb;
    for i in 0..n_layers {
        let attn_flops = (4.0 * 2.0 * d_model * d_model * seq
                          + 2.0 * 2.0 * seq * seq * d_model)
            * bf
            * TRAIN_FACTOR;
        let mlp_flops = 2.0 * 2.0 * d_model * d_ff * seq * bf * TRAIN_FACTOR;
        let op = g.add_op(&format!("layer{}", i), attn_flops + mlp_flops,
                          d_model * seq * bf * 4.0,
                          (4.0 * d_model * d_model
                           + 2.0 * d_model * d_ff) * 4.0);
        g.add_edge(prev, op);
        prev = op;
    }
    let head = g.add_op("unembed+xent",
                        2.0 * d_model * vocab * seq * bf * TRAIN_FACTOR,
                        vocab * bf * 4.0, d_model * vocab * 4.0);
    g.add_edge(prev, head);
    let params = vocab * d_model * 2.0
        + n_layers as f64 * (4.0 * d_model * d_model + 2.0 * d_model * d_ff);
    ModelProfile {
        name: "transformer-lm".into(),
        dfg: g,
        mini_batch: b,
        grad_bytes: params * 4.0,
        epochs: EpochModel::fig3_example(),
        pipe_saturation: 8.0,
        mp_strategy: "Pipeline Parallelism",
    }
}

/// A 70B-class transformer (88 × d_model 8192, d_ff 32768, 32k vocab,
/// seq 4096 — ≈71B params, ≈286 GB of f32 weights).  Under Adam the
/// replicated training state alone is ≈1.1 TB: infeasible on any 80 GB
/// part without tensor parallelism × ZeRO sharding, which is exactly why
/// it seeds the registry (see `docs/3d-parallelism.md`).
pub fn transformer_70b(b: usize) -> ModelProfile {
    let mut p = transformer_lm(88, 8192.0, 32768.0, 32_000.0, 4096.0, b);
    p.name = "transformer-70b".into();
    p
}

/// A 100B-class transformer (80 × d_model 10240, d_ff 40960, 32k vocab,
/// seq 4096 — ≈101B params).  Even further past single-device
/// feasibility than [`transformer_70b`]; exists so sweeps have a second
/// point on the 3D-parallelism frontier.
pub fn transformer_100b(b: usize) -> ModelProfile {
    let mut p = transformer_lm(80, 10240.0, 40960.0, 32_000.0, 4096.0, b);
    p.name = "transformer-100b".into();
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inception_flops_in_published_range() {
        // Published ~5.7 GMAC/image forward = ~11.4 GFLOP at 2 FLOP/MAC.
        let p = inception_v3(32);
        let per_image = p.dfg.total_flops() / 32.0 / TRAIN_FACTOR;
        assert!(per_image > 6e9 && per_image < 16e9,
                "fwd GFLOP/img = {}", per_image / 1e9);
    }

    #[test]
    fn inception_has_branch_parallelism() {
        let p = inception_v3(32);
        let times = p.dfg.op_times(7e12, 0.0);
        let par = p.dfg.parallelism(&times).unwrap();
        // Paper: DLPlacer fully exploits it with 2 GPUs, marginal beyond
        // (Fig. 8) — inherent parallelism should be modest.
        assert!(par > 1.15 && par < 3.0, "parallelism {par}");
    }

    #[test]
    fn inception_graph_is_dag_with_blocks() {
        let p = inception_v3(32);
        assert!(p.dfg.topo_order().is_ok());
        assert!(p.dfg.n_ops() > 40, "branch-level graph expected");
        let concats = p
            .dfg
            .ops
            .iter()
            .filter(|o| o.name.contains("concat"))
            .count();
        assert_eq!(concats, 11, "11 inception blocks");
    }

    #[test]
    fn gnmt_is_sequential_chain() {
        let p = gnmt(128);
        let times = p.dfg.op_times(7e12, 0.0);
        let par = p.dfg.parallelism(&times).unwrap();
        assert!(par < 1.05, "GNMT chain has no branch parallelism: {par}");
        assert_eq!(p.dfg.n_ops(), 1 + 4 + 1 + 4 + 1);
    }

    #[test]
    fn biglstm_softmax_is_large() {
        let p = biglstm(64);
        let sm = &p.dfg.ops[p.dfg.n_ops() - 1];
        assert!(sm.name.contains("softmax"));
        // Sampled softmax (10% of 800k vocab) is still a headline cost.
        assert!(sm.flops > 0.08 * p.dfg.total_flops(),
                "softmax share {}", sm.flops / p.dfg.total_flops());
    }

    #[test]
    fn biglstm_is_memory_hungry() {
        // Paper: BigLSTM needed the 32 GB V100s.
        let p = biglstm(64);
        assert!(p.dfg.total_mem() > 2e9);
    }

    #[test]
    fn transformer_profile_scales_with_layers() {
        let small = transformer_lm(4, 128.0, 512.0, 512.0, 64.0, 8);
        let large = transformer_lm(8, 128.0, 512.0, 512.0, 64.0, 8);
        assert!(large.dfg.total_flops() > 1.5 * small.dfg.total_flops());
        assert_eq!(large.dfg.n_ops(), 10);
    }

    #[test]
    fn large_transformers_have_headline_param_counts() {
        let p70 = transformer_70b(4);
        let params70 = p70.grad_bytes / 4.0;
        assert!(params70 > 65e9 && params70 < 80e9,
                "70B-class: {params70:e}");
        assert_eq!(p70.name, "transformer-70b");
        let p100 = transformer_100b(4);
        let params100 = p100.grad_bytes / 4.0;
        assert!(params100 > 95e9 && params100 < 110e9,
                "100B-class: {params100:e}");
        assert_eq!(p100.name, "transformer-100b");
        // f32 weights alone overflow an 80 GB part many times over.
        assert!(p70.grad_bytes > 3.0 * 80e9);
    }
}
