//! Tiny CLI argument parser (clap unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I,
                                                 flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        out.options.insert(stripped.to_string(),
                                           it.next().unwrap());
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args after the subcommand position.
    pub fn from_env(skip: usize, flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(skip), flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(argv(&["train", "--steps", "100",
                                   "--lr=0.5", "--verbose", "pos2"]),
                            &["verbose"]);
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_option() {
        let a = Args::parse(argv(&["--dry-run", "--n", "4"]), &[]);
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv(&["--x"]), &[]);
        assert!(a.has_flag("x"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(&[]), &[]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("m", "d"), "d");
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv(&["--n", "xyz"]), &[]);
        assert!(a.get_usize("n", 0).is_err());
    }
}
