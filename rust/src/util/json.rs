//! Minimal JSON parser/writer (serde_json unavailable offline).
//!
//! Covers the full JSON grammar; used to read `artifacts/meta.json` and to
//! emit experiment/metric records.  Not performance-critical — parsing
//! happens once at startup.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Object field access with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// `get` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // Copy raw UTF-8 bytes through.
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()
            .map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => esc(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(),
                   Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b").unwrap().as_str().unwrap(),
            "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"o":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn real_meta_json_shape() {
        let src = r#"{"artifacts":{"grad_step":{"file":"grad_step.hlo.txt",
            "inputs":[{"shape":[8,64],"dtype":"int32"}],
            "outputs":[{"shape":[],"dtype":"float32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        let gs = v.get("artifacts").unwrap().get("grad_step").unwrap();
        assert_eq!(gs.get("file").unwrap().as_str().unwrap(),
                   "grad_step.hlo.txt");
        let shape = gs.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap()[1].as_usize().unwrap(), 64);
    }
}
