//! Small self-contained utilities: deterministic RNG, JSON parsing/writing,
//! CLI argument parsing, and formatting helpers.
//!
//! These exist because the build environment is offline (no crates.io);
//! each is a minimal, tested stand-in for the usual ecosystem crate.

pub mod rng;
pub mod json;
pub mod cli;

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i < U.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{} {}", b, U[0])
    } else {
        format!("{:.2} {}", v, U[i])
    }
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0); // nearest rank rounds up
    }

    #[test]
    fn stats_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
