//! Deterministic xoshiro256++ PRNG (rand crate unavailable offline).
//!
//! Used for synthetic data generation, property-test case generation, and
//! anywhere the coordinator needs reproducible randomness.  Seeding goes
//! through SplitMix64 per the xoshiro authors' recommendation.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed via SplitMix64 state expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        if n == 0 {
            return 0;
        }
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
