//! The paper's analytical framework (§3): end-to-end training-time
//! decomposition and the DP-vs-hybrid crossover.
//!
//! * Eq. 1: `C = T × S × E`
//! * Eq. 3: `SU_N = SE_N × N × E1/EN` (N-way DP speedup over 1 device)
//! * Eq. 5: `SU^M_N = SU^M × SE_N × N × E1/EN` (hybrid: N DP workers, each
//!   M-way model parallel)
//! * Eq. 6: hybrid beats (M·N)-way DP iff
//!   `SU^M > M × SE_{MN}/SE_N × E_N/E_{MN}`
//!
//! Scaling efficiency SE_N can be the paper's conservative SE=1 assumption
//! (§4.3) or derived from the α-β ring all-reduce model over a concrete
//! hardware topology.

use anyhow::Result;

use crate::collective::{best_allreduce_on, ring_cost, Algorithm,
                        TopoProfile};
use crate::statistical::EpochModel;

pub mod overlap;

use overlap::{overlapped_step, OverlapBreakdown, OverlapModel};

/// Where SE_N comes from.
#[derive(Clone, Debug)]
pub enum ScalingEfficiency {
    /// SE_N = 1 for all N — the paper's conservative assumption that
    /// *minimises* the projected benefit of hybrid parallelization (§4.3).
    Perfect,
    /// SE_N = T_compute / (T_compute + ring_allreduce(N, bytes)) with an
    /// α-β ring cost over the bottleneck bandwidth — the flat-ring model
    /// that mis-prices multi-node exchanges; kept for ablations against
    /// [`ScalingEfficiency::Collective`].
    RingAllReduce {
        /// Per-step compute time of one worker (seconds).
        step_compute_s: f64,
        /// Gradient payload per worker (bytes).
        grad_bytes: f64,
        /// Latency per ring hop (seconds).
        alpha: f64,
        /// Bottleneck bandwidth of the ring (bytes/s).
        beta_bw: f64,
    },
    /// SE_N from topology-aware collective selection:
    /// `SE_N = T_c / (T_c + cost(best feasible all-reduce at N))`, the
    /// per-N algorithm picked by [`best_allreduce_on`] over the
    /// topology's [`TopoProfile`] (ring / tree / two-level hierarchical)
    /// — or pinned by `force` (the planner's `--collective` override).
    Collective {
        /// Per-step compute time of one worker (seconds).
        step_compute_s: f64,
        /// Gradient payload per worker (bytes).
        grad_bytes: f64,
        /// Per-step software overhead added to every hop's wire latency.
        alpha: f64,
        /// Chassis shape + intra/inter α-β path profiles.
        topo: TopoProfile,
        /// `Some(a)` prices every N with algorithm `a` instead of the
        /// cheapest one.
        force: Option<Algorithm>,
        /// Bucketed-overlap/compression axes.  The default (`buckets=1`,
        /// `compression=1.0`) charges the serial exchange verbatim, so
        /// pre-overlap numbers are bit-for-bit stable.
        overlap: OverlapModel,
    },
}

impl ScalingEfficiency {
    /// SE_N ∈ (0, 1] for N one-device DP workers.
    pub fn at(&self, n: usize) -> f64 {
        self.at_mp(n, 1)
    }

    /// SE_N for `n` DP ranks that each span `width` devices (M-way model
    /// parallelism).  Only the collective model cares: wider ranks pack
    /// fewer per chassis ([`TopoProfile::for_worker_width`]), so a
    /// hybrid's gradient exchange crosses the slow inter-node fabric at
    /// smaller N than a plain DP exchange would.
    pub fn at_mp(&self, n: usize, width: usize) -> f64 {
        match self {
            ScalingEfficiency::Perfect => 1.0,
            ScalingEfficiency::RingAllReduce {
                step_compute_s,
                grad_bytes,
                alpha,
                beta_bw,
            } => {
                if n <= 1 {
                    return 1.0;
                }
                let comm = ring_cost(n, *grad_bytes, *alpha, *beta_bw);
                step_compute_s / (step_compute_s + comm)
            }
            ScalingEfficiency::Collective {
                step_compute_s,
                grad_bytes,
                alpha,
                topo,
                force,
                overlap,
            } => {
                if n <= 1 {
                    return 1.0;
                }
                let topo = topo.for_worker_width(width);
                if overlap.is_off() {
                    // Legacy serial charge, kept verbatim so the default
                    // path is bit-for-bit identical to pre-overlap
                    // planners.
                    let comm = match force {
                        Some(a) => topo.cost(*a, n, *grad_bytes, *alpha),
                        None => {
                            best_allreduce_on(n, *grad_bytes, &topo, *alpha)
                                .cost_s
                        }
                    };
                    return step_compute_s / (step_compute_s + comm);
                }
                let price = |bytes: f64| match force {
                    Some(a) => topo.cost(*a, n, bytes, *alpha),
                    None => {
                        best_allreduce_on(n, bytes, &topo, *alpha).cost_s
                    }
                };
                let bd = overlapped_step(*step_compute_s, *grad_bytes,
                                         overlap, price);
                step_compute_s / bd.step_s
            }
        }
    }

    /// What the overlapped exchange charged at `(n, width)`: the step,
    /// its exposed tail, the serial exchange at the same compression and
    /// the schedule the simulator needs to replay it.  `None` under SE
    /// models that do not price collectives, and for `n ≤ 1` (nothing to
    /// exchange).  With overlap off this is the serial charge expressed
    /// as a one-bucket schedule (`tail == exchange`).
    pub fn exchange_breakdown_mp(&self, n: usize, width: usize)
                                 -> Option<OverlapBreakdown> {
        if n <= 1 {
            return None;
        }
        let ScalingEfficiency::Collective {
            step_compute_s, grad_bytes, alpha, topo, force, overlap,
        } = self
        else {
            return None;
        };
        let topo = topo.for_worker_width(width);
        let price = |bytes: f64| match force {
            Some(a) => topo.cost(*a, n, bytes, *alpha),
            None => best_allreduce_on(n, bytes, &topo, *alpha).cost_s,
        };
        Some(overlapped_step(*step_compute_s, *grad_bytes, overlap, price))
    }

    /// The algorithm pricing an `n`-worker exchange under this SE model:
    /// `None` under the paper's SE = 1 assumption (communication is free,
    /// nothing is priced) and for `n ≤ 1`.
    pub fn collective_algorithm(&self, n: usize) -> Option<Algorithm> {
        self.collective_algorithm_mp(n, 1)
    }

    /// [`ScalingEfficiency::collective_algorithm`] for ranks spanning
    /// `width` devices each (see [`ScalingEfficiency::at_mp`]).
    pub fn collective_algorithm_mp(&self, n: usize, width: usize)
                                   -> Option<Algorithm> {
        if n <= 1 {
            return None;
        }
        match self {
            ScalingEfficiency::Perfect => None,
            ScalingEfficiency::RingAllReduce { .. } => Some(Algorithm::Ring),
            ScalingEfficiency::Collective {
                grad_bytes, alpha, topo, force, ..
            } => Some(force.unwrap_or_else(|| {
                let topo = topo.for_worker_width(width);
                best_allreduce_on(n, *grad_bytes, &topo, *alpha).algorithm
            })),
        }
    }

    /// Pin the collective algorithm (no-op on SE models that do not price
    /// collectives) — the `PlanRequest::collective` override.
    pub fn with_forced(mut self, algorithm: Option<Algorithm>) -> Self {
        if let ScalingEfficiency::Collective { ref mut force, .. } = self {
            if algorithm.is_some() {
                *force = algorithm;
            }
        }
        self
    }

    /// Set the overlap/compression axes (no-op on SE models that do not
    /// price collectives: `Perfect` charges no exchange so there is
    /// nothing to hide, and the flat-ring ablation is kept serial on
    /// purpose) — the `PlanRequest::{overlap_buckets, compression}`
    /// override, mirroring [`ScalingEfficiency::with_forced`].
    pub fn with_overlap(mut self, model: OverlapModel) -> Self {
        if let ScalingEfficiency::Collective { ref mut overlap, .. } = self {
            *overlap = model;
        }
        self
    }
}

/// The per-network inputs of the projection.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub name: String,
    /// E(B) calibration.
    pub epochs: EpochModel,
    /// Per-device mini-batch size (global batch = N_dp × mini_batch).
    pub mini_batch: usize,
    /// SE_N source.
    pub se: ScalingEfficiency,
    /// Measured/simulated MP speedups: (M, SU^M) pairs, e.g. (2, 1.32).
    pub mp_speedups: Vec<(usize, f64)>,
}

impl NetworkModel {
    /// SU^M for a given M (1 → 1.0).
    pub fn su_m(&self, m: usize) -> Option<f64> {
        if m == 1 {
            return Some(1.0);
        }
        self.mp_speedups
            .iter()
            .find(|&&(mm, _)| mm == m)
            .map(|&(_, su)| su)
    }

    /// Eq. 3: DP-only speedup with N devices (None if E(B) diverges).
    pub fn su_dp(&self, n: usize) -> Option<f64> {
        let b = (n * self.mini_batch) as f64;
        let e_ratio = self.epochs.efficiency_ratio(b)?;
        Some(self.se.at(n) * n as f64 * e_ratio)
    }

    /// Eq. 5: hybrid speedup using `total` devices as (total/M) DP workers
    /// of M-way MP each.  None if M doesn't divide total, no SU^M is known,
    /// or E(B) diverges.  SE sees the M-device worker width: wider ranks
    /// pack fewer per chassis, so their exchange crosses nodes sooner
    /// ([`ScalingEfficiency::at_mp`]).
    pub fn su_hybrid(&self, total: usize, m: usize) -> Option<f64> {
        if m == 0 || total % m != 0 {
            return None;
        }
        let n_dp = total / m;
        let su_m = self.su_m(m)?;
        let b = (n_dp * self.mini_batch) as f64;
        let e_ratio = self.epochs.efficiency_ratio(b)?;
        Some(su_m * self.se.at_mp(n_dp, m) * n_dp as f64 * e_ratio)
    }

    /// Best strategy at `total` devices over M ∈ {1} ∪ available SU^M.
    /// Returns (m, speedup); m=1 means DP-only.
    pub fn best_strategy(&self, total: usize) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        let mut ms: Vec<usize> = vec![1];
        ms.extend(self.mp_speedups.iter().map(|&(m, _)| m));
        for m in ms {
            if let Some(su) = self.su_hybrid(total, m) {
                if best.map_or(true, |(_, b)| su > b) {
                    best = Some((m, su));
                }
            }
        }
        best
    }

    /// Eq. 6 right-hand side at (N, M): the threshold SU^M must exceed for
    /// the hybrid at M·N devices to beat DP-only at M·N devices.  The
    /// hybrid side's SE sees the M-device worker width, mirroring
    /// [`NetworkModel::su_hybrid`] so the Eq. 6 identity holds exactly.
    pub fn crossover_threshold(&self, n: usize, m: usize) -> Option<f64> {
        let se_n = self.se.at_mp(n, m);
        let se_mn = self.se.at(m * n);
        let b_n = (n * self.mini_batch) as f64;
        let b_mn = (m * n * self.mini_batch) as f64;
        let e_n = self.epochs.epochs(b_n)?;
        let e_mn = self.epochs.epochs(b_mn)?;
        Some(m as f64 * (se_mn / se_n) * (e_n / e_mn))
    }

    /// Smallest total device count (power-of-two sweep up to `max_total`)
    /// at which the M-way hybrid beats DP-only at the same device count —
    /// the paper's "tipping point".
    pub fn crossover_point(&self, m: usize, max_total: usize)
                           -> Option<usize> {
        let mut total = m.max(2);
        while total <= max_total {
            let hybrid = self.su_hybrid(total, m);
            let dp = self.su_dp(total);
            match (hybrid, dp) {
                (Some(h), Some(d)) if h > d => return Some(total),
                (Some(_h), None) => return Some(total), // DP diverged
                _ => {}
            }
            total *= 2;
        }
        None
    }
}

/// A (device_count, speedup) series for plotting/benching a figure.
pub fn speedup_series(net: &NetworkModel, m: usize, totals: &[usize])
                      -> Vec<(usize, Option<f64>)> {
    totals
        .iter()
        .map(|&t| {
            let su = if m == 1 { net.su_dp(t) } else { net.su_hybrid(t, m) };
            (t, su)
        })
        .collect()
}

/// Verify Eq. 6 algebraically for a configuration: the hybrid wins iff
/// SU^M exceeds the crossover threshold.  Used by property tests.
pub fn eq6_consistent(net: &NetworkModel, n: usize, m: usize) -> Result<bool> {
    let (Some(su_m), Some(thresh)) =
        (net.su_m(m), net.crossover_threshold(n, m))
    else {
        return Ok(true); // vacuous when undefined
    };
    let total = n * m;
    let (Some(hybrid), Some(dp)) = (net.su_hybrid(total, m), net.su_dp(total))
    else {
        return Ok(true);
    };
    // Eq. 6: hybrid > dp  <=>  su_m > thresh  (up to fp tolerance).
    let lhs = hybrid > dp;
    let rhs = su_m > thresh;
    Ok(lhs == rhs
        || (hybrid - dp).abs() < 1e-9
        || (su_m - thresh).abs() < 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig3_net() -> NetworkModel {
        NetworkModel {
            name: "fig3".into(),
            epochs: EpochModel::fig3_example(),
            mini_batch: 1, // Fig. 3 x-axis is devices = global batch
            se: ScalingEfficiency::Perfect,
            mp_speedups: vec![(2, 1.45), (4, 1.65)],
        }
    }

    #[test]
    fn dp_speedup_linear_while_epochs_flat() {
        let net = fig3_net();
        // E(B) flat to 32 devices -> SU_N == N.
        for n in [1usize, 2, 8, 32] {
            let su = net.su_dp(n).unwrap();
            assert!((su - n as f64).abs() < 1e-9, "n={n} su={su}");
        }
        // Past 32: sublinear.
        assert!(net.su_dp(64).unwrap() < 64.0);
    }

    #[test]
    fn fig3_crossover_at_64_devices() {
        // Paper's Fig. 3 narrative: 32-way DP x 2-way MP beats 64-way DP.
        let net = fig3_net();
        let dp64 = net.su_dp(64).unwrap();
        let hy64 = net.su_hybrid(64, 2).unwrap();
        assert!(hy64 > dp64, "hybrid {hy64} must beat dp {dp64}");
        // And 2-way hybrid beats 4-way hybrid at 128 (paper: "not as good").
        let hy128_2 = net.su_hybrid(128, 2).unwrap();
        let hy128_4 = net.su_hybrid(128, 4).unwrap();
        assert!(hy128_2 > hy128_4,
                "2-way {hy128_2} should beat 4-way {hy128_4}");
    }

    #[test]
    fn hybrid_requires_divisibility() {
        let net = fig3_net();
        assert!(net.su_hybrid(6, 4).is_none());
        assert!(net.su_hybrid(8, 4).is_some());
    }

    #[test]
    fn best_strategy_switches_at_scale() {
        let net = fig3_net();
        let (m_small, _) = net.best_strategy(8).unwrap();
        assert_eq!(m_small, 1, "DP-only wins at small N");
        let (m_large, _) = net.best_strategy(256).unwrap();
        assert!(m_large > 1, "hybrid wins at scale");
    }

    #[test]
    fn crossover_point_detected() {
        let net = fig3_net();
        let x = net.crossover_point(2, 1024).unwrap();
        assert!(x == 64, "crossover at {x}");
    }

    #[test]
    fn eq6_holds_across_grid() {
        let net = fig3_net();
        for n in [1usize, 2, 4, 8, 16, 32, 64, 128] {
            for m in [2usize, 4] {
                assert!(eq6_consistent(&net, n, m).unwrap(),
                        "Eq.6 violated at n={n} m={m}");
            }
        }
    }

    #[test]
    fn ring_se_decreases_with_n() {
        let se = ScalingEfficiency::RingAllReduce {
            step_compute_s: 0.1,
            grad_bytes: 100e6,
            alpha: 5e-6,
            beta_bw: 25e9,
        };
        let mut prev = 1.0 + 1e-12;
        for n in [1usize, 2, 4, 16, 64, 256] {
            let s = se.at(n);
            assert!(s <= prev);
            assert!(s > 0.0 && s <= 1.0);
            prev = s;
        }
    }

    #[test]
    fn perfect_se_maximises_dp_and_minimises_hybrid_benefit() {
        // With real SE the hybrid advantage grows (paper §5 note).
        let mut net = fig3_net();
        let dp_perfect = net.su_dp(256).unwrap();
        let hy_perfect = net.su_hybrid(256, 2).unwrap();
        net.se = ScalingEfficiency::RingAllReduce {
            step_compute_s: 0.05,
            grad_bytes: 400e6,
            alpha: 5e-6,
            beta_bw: 12e9,
        };
        let dp_real = net.su_dp(256).unwrap();
        let hy_real = net.su_hybrid(256, 2).unwrap();
        assert!(dp_real < dp_perfect);
        assert!(hy_real / dp_real > hy_perfect / dp_perfect,
                "hybrid advantage should grow with real SE");
    }

    #[test]
    fn collective_se_beats_flat_ring_across_nodes() {
        use crate::cluster::multi_node;
        let topo = TopoProfile::of(&multi_node(4, 8));
        let se = ScalingEfficiency::Collective {
            step_compute_s: 0.1,
            grad_bytes: 640e6,
            alpha: 5e-6,
            topo: topo.clone(),
            force: None,
            overlap: OverlapModel::default(),
        };
        assert_eq!(se.at(1), 1.0);
        assert!(se.collective_algorithm(1).is_none());
        assert_eq!(se.collective_algorithm(32),
                   Some(Algorithm::Hierarchical));
        let ring = se.clone().with_forced(Some(Algorithm::Ring));
        assert_eq!(ring.collective_algorithm(32), Some(Algorithm::Ring));
        assert!(se.at(32) > ring.at(32),
                "best collective must strictly beat the forced flat ring");
        // Monotone decay, bounded.
        let mut prev = 1.0 + 1e-12;
        for n in [1usize, 2, 8, 32, 128] {
            let s = se.at(n);
            assert!(s > 0.0 && s <= 1.0 && s <= prev);
            prev = s;
        }
        // Forcing is a no-op on non-collective SE models.
        let p = ScalingEfficiency::Perfect
            .with_forced(Some(Algorithm::Tree));
        assert!(matches!(p, ScalingEfficiency::Perfect));
        assert!(p.collective_algorithm(8).is_none());
    }

    #[test]
    fn wider_workers_cross_nodes_sooner() {
        use crate::cluster::multi_node;
        // 4×8 pod: 4 DP ranks of one device each fit half a chassis and
        // exchange over NVLink; 4 ranks of 8 devices each occupy one
        // chassis apiece, so every hop crosses InfiniBand.
        let se = ScalingEfficiency::Collective {
            step_compute_s: 0.1,
            grad_bytes: 640e6,
            alpha: 5e-6,
            topo: TopoProfile::of(&multi_node(4, 8)),
            force: None,
            overlap: OverlapModel::default(),
        };
        assert!(se.at_mp(4, 1) > se.at_mp(4, 8),
                "8-wide ranks must pay the inter-node fabric: {} vs {}",
                se.at_mp(4, 1), se.at_mp(4, 8));
        // Width 1 is the plain DP pricing.
        assert_eq!(se.at(16), se.at_mp(16, 1));
        // SE is monotone non-increasing in worker width.
        let mut prev = f64::INFINITY;
        for w in [1usize, 2, 4, 8] {
            let s = se.at_mp(4, w);
            assert!(s <= prev + 1e-15, "width {w}: {s} > {prev}");
            prev = s;
        }
        // And the recorded algorithm follows the widened shape: one
        // 8-wide rank per chassis leaves nothing intra-node, so the
        // two-level scheme degenerates and the ring wins outright.
        assert_eq!(se.collective_algorithm_mp(4, 8),
                   Some(Algorithm::Ring));
        assert_eq!(se.collective_algorithm_mp(16, 2),
                   Some(Algorithm::Hierarchical));
    }

    #[test]
    fn overlap_raises_se_and_defaults_stay_serial() {
        use crate::cluster::multi_node;
        let base = ScalingEfficiency::Collective {
            step_compute_s: 0.1,
            grad_bytes: 640e6,
            alpha: 5e-6,
            topo: TopoProfile::of(&multi_node(4, 8)),
            force: None,
            overlap: OverlapModel::default(),
        };
        // with_overlap(default) is the identity charge.
        let same = base.clone().with_overlap(OverlapModel::default());
        assert_eq!(base.at(32), same.at(32));
        // Buckets alone strictly help whenever the exchange is nonzero.
        let bucketed = base.clone()
            .with_overlap(OverlapModel { buckets: 8, compression: 1.0 });
        assert!(bucketed.at(32) > base.at(32),
                "bucketed overlap must raise SE: {} vs {}",
                bucketed.at(32), base.at(32));
        assert!(bucketed.at(32) <= 1.0);
        // Compression on top helps again, and never past perfect.
        let compressed = base.clone()
            .with_overlap(OverlapModel { buckets: 8, compression: 0.25 });
        assert!(compressed.at(32) > bucketed.at(32));
        assert!(compressed.at(32) <= 1.0);
        // Breakdown: tail == exchange when off, tail < exchange when on.
        let off = base.exchange_breakdown_mp(32, 1).unwrap();
        assert!((off.tail_s - off.exchange_s).abs() < 1e-15);
        let on = bucketed.exchange_breakdown_mp(32, 1).unwrap();
        assert!(on.tail_s < on.exchange_s);
        assert!(on.buckets_used >= 2 && on.buckets_used <= 8);
        // No breakdown where nothing is exchanged.
        assert!(base.exchange_breakdown_mp(1, 1).is_none());
        assert!(ScalingEfficiency::Perfect
            .exchange_breakdown_mp(8, 1).is_none());
        // with_overlap is a no-op on non-collective SE models.
        let p = ScalingEfficiency::Perfect
            .with_overlap(OverlapModel { buckets: 4, compression: 0.5 });
        assert!(matches!(p, ScalingEfficiency::Perfect));
    }

    #[test]
    fn diverged_epochs_kill_dp() {
        let mut net = fig3_net();
        net.epochs = EpochModel::biglstm();
        net.mini_batch = 64;
        // BigLSTM: no convergence beyond batch 2048 = 32 devices.
        assert!(net.su_dp(64).is_none());
        // Hybrid with M=2 at 64 devices => 32 DP workers: still fine.
        assert!(net.su_hybrid(64, 2).is_some());
    }
}
