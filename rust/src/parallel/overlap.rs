//! Comm/compute overlap for the gradient exchange (ROADMAP item 4).
//!
//! The paper's Eq. 1–6 charge the all-reduce serially after the backward
//! pass.  Production DP stacks instead split the gradient into buckets and
//! launch each bucket's all-reduce as soon as its backward slice finishes,
//! hiding exchange under the remaining backward compute; only the
//! un-hidden tail lands on the step time.  Scale-out studies (PAPERS.md,
//! Intel arXiv 1801.08030) add gradient compression on top, which shrinks
//! the bandwidth term of every bucket but — like
//! [`crate::collective::compress::ring_cost_bf16`] — must leave the α
//! latency terms alone (a latency floor: quantization does not shorten
//! wire hops or software launch overhead).
//!
//! # The analytic model
//!
//! Let `C` be the per-step compute time and `w = BACKWARD_FRACTION × C`
//! the hiding window (gradients only become ready during the backward
//! pass; with the repo-wide fwd:bwd = 1:2 split of
//! [`crate::models::TRAIN_FACTOR`], that window is the last two thirds of
//! the step).  With `k` equal buckets of a payload `B` (already
//! compression-scaled), bucket `i` becomes ready at
//! `r_i = (C − w) + i·w/k` and costs `c_k = price(B/k)` on the wire.  The
//! collectives run back-to-back on one network resource, so the finish
//! time follows the pipeline recursion `f_i = max(f_{i−1}, r_i) + c_k`,
//! whose closed form is
//!
//! ```text
//! T_k = max( C + c_k,  (C − w) + w/k + k·c_k )
//! ```
//!
//! — either the last bucket's all-reduce is the only exposed piece
//! (well-hidden regime) or the wire is saturated from the first bucket's
//! ready time onwards (bandwidth-bound regime).
//!
//! `buckets` is a **cap**, not an exact count: real frameworks auto-tune
//! the bucket size, so the model charges `min over k ∈ 1..=buckets` of
//! `T_k`.  That keeps the overlapped step monotone non-increasing in the
//! bucket budget even though the α term of `k·c_k` grows with `k`
//! (asserted by property tests), and makes `buckets = 1` reproduce the
//! serial charge `C + price(B)` exactly — which is why the default
//! [`OverlapModel`] is bit-for-bit identical to the pre-overlap planner.
//!
//! The closed form is cross-checked end-to-end against
//! [`crate::sim::simulate`] *executing* the bucket pipeline as a DFG
//! (`tests/integration_overlap.rs`).

use anyhow::{bail, Result};

/// Fraction of the per-step compute during which gradients become ready
/// for exchange: the backward share of fwd + bwd, with the repo-wide
/// fwd:bwd = 1:2 cost split (`models::TRAIN_FACTOR` = 3 = 1 fwd + 2 fwd
/// of backward).
pub const BACKWARD_FRACTION: f64 = 2.0 / 3.0;

/// Hard cap on the bucket budget accepted from any surface (CLI, config,
/// wire).  Far above any real framework default (PyTorch DDP buckets a
/// multi-GB model into dozens of buckets, not hundreds).
pub const MAX_BUCKETS: usize = 1024;

/// The overlap/compression axes threaded through the planner, the sweep
/// engine and the service wire format.  `Default` is overlap **off**:
/// one bucket, no compression — the paper's serial-exchange charge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapModel {
    /// Maximum number of gradient buckets the runtime may split the
    /// exchange into (the model minimises over `1..=buckets`).  `1` =
    /// serial exchange after the step (the paper's assumption).
    pub buckets: usize,
    /// Factor applied to the gradient payload's **bytes** (bandwidth
    /// term) before pricing, in `(0, 1]`.  α/latency terms are never
    /// scaled — the latency floor.  `1.0` = no compression.
    pub compression: f64,
}

impl Default for OverlapModel {
    fn default() -> Self {
        OverlapModel { buckets: 1, compression: 1.0 }
    }
}

impl OverlapModel {
    /// True when the model charges exactly the legacy serial exchange
    /// (the planner then runs the pre-overlap arithmetic verbatim, so
    /// defaults are bit-for-bit stable).
    pub fn is_off(&self) -> bool {
        self.buckets <= 1 && self.compression == 1.0
    }

    /// Loud validation shared by the CLI, the `[overlap]` config section
    /// and the wire parsers.
    pub fn validate(&self) -> Result<()> {
        if self.buckets == 0 {
            bail!("overlap buckets must be >= 1 (1 = overlap off)");
        }
        if self.buckets > MAX_BUCKETS {
            bail!("overlap buckets {} exceeds the cap {MAX_BUCKETS}",
                  self.buckets);
        }
        if !self.compression.is_finite()
            || self.compression <= 0.0
            || self.compression > 1.0
        {
            bail!("compression must be a finite factor in (0, 1], got {}",
                  self.compression);
        }
        Ok(())
    }
}

/// What the overlap model charged for one step, for scorecards, docs and
/// the simulator cross-check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapBreakdown {
    /// The overlapped step time `min_k T_k` (seconds).
    pub step_s: f64,
    /// The exposed exchange tail `step_s − compute` (seconds) — the only
    /// part of the exchange the step actually pays.
    pub tail_s: f64,
    /// The *serial* exchange `price(B)` at the same compression
    /// (seconds): what a one-bucket charge would pay.  The sandwich bound
    /// `max(compute, exchange) ≤ step ≤ compute + exchange` is stated
    /// against this value.
    pub exchange_s: f64,
    /// The arg-min bucket count `k ∈ 1..=buckets`.
    pub buckets_used: usize,
    /// The hiding window `BACKWARD_FRACTION × compute` (seconds).
    pub window_s: f64,
    /// The per-bucket wire cost `price(B / buckets_used)` (seconds) —
    /// with `window_s` and `buckets_used`, everything the simulator
    /// needs to *execute* the same schedule.
    pub bucket_cost_s: f64,
}

/// Price one overlapped gradient exchange.
///
/// * `compute_s` — the worker's per-step compute time (fwd + bwd).
/// * `grad_bytes` — the uncompressed gradient payload.
/// * `price(bytes)` — all-reduce cost for a payload of `bytes` over the
///   caller's topology/algorithm (the `best_allreduce` / `TopoProfile`
///   layer; must be affine non-decreasing in `bytes` with a non-negative
///   latency intercept, which every ring/tree/hierarchical α-β cost is —
///   that affinity is what makes the sandwich bound below hold).
///
/// Guarantees (property-tested in `tests/properties.rs`):
/// * `max(compute_s, exchange_s) ≤ step_s ≤ compute_s + exchange_s`;
/// * `step_s` is monotone non-increasing in `model.buckets`;
/// * `buckets = 1, compression = 1.0` gives
///   `step_s == compute_s + price(grad_bytes)` exactly.
pub fn overlapped_step<F>(compute_s: f64, grad_bytes: f64,
                          model: &OverlapModel, price: F)
                          -> OverlapBreakdown
where
    F: Fn(f64) -> f64,
{
    let bytes = grad_bytes * model.compression;
    let exchange_s = price(bytes);
    let window_s = BACKWARD_FRACTION * compute_s;
    let buckets = model.buckets.clamp(1, MAX_BUCKETS);

    let mut best_step = f64::INFINITY;
    let mut best_k = 1usize;
    let mut best_c = exchange_s;
    for k in 1..=buckets {
        let c_k = price(bytes / k as f64);
        let hidden = compute_s + c_k;
        let saturated =
            (compute_s - window_s) + window_s / k as f64 + k as f64 * c_k;
        let t_k = hidden.max(saturated);
        if t_k < best_step {
            best_step = t_k;
            best_k = k;
            best_c = c_k;
        }
    }
    OverlapBreakdown {
        step_s: best_step,
        tail_s: best_step - compute_s,
        exchange_s,
        buckets_used: best_k,
        window_s,
        bucket_cost_s: best_c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn affine_price(alpha: f64, inv_bw: f64) -> impl Fn(f64) -> f64 {
        move |bytes| alpha + bytes * inv_bw
    }

    #[test]
    fn default_is_off_and_serial() {
        let m = OverlapModel::default();
        assert!(m.is_off());
        let price = affine_price(10e-6, 1.0 / 10e9);
        let bd = overlapped_step(0.1, 640e6, &m, &price);
        assert_eq!(bd.step_s, 0.1 + price(640e6), "k=1 must be serial");
        assert_eq!(bd.buckets_used, 1);
        assert_eq!(bd.exchange_s, price(640e6));
    }

    #[test]
    fn sandwich_bound_and_monotone_in_buckets() {
        let price = affine_price(50e-6, 1.0 / 1.24e9);
        let compute = 0.3;
        let mut prev = f64::INFINITY;
        for buckets in [1usize, 2, 4, 8, 16, 64, 256] {
            let m = OverlapModel { buckets, compression: 1.0 };
            let bd = overlapped_step(compute, 640e6, &m, &price);
            assert!(bd.step_s <= prev + 1e-15,
                    "buckets {buckets}: {} > {prev}", bd.step_s);
            assert!(bd.step_s >= compute.max(bd.exchange_s) - 1e-12);
            assert!(bd.step_s <= compute + bd.exchange_s + 1e-12);
            prev = bd.step_s;
        }
    }

    #[test]
    fn compression_scales_bytes_not_latency() {
        let alpha = 1e-3; // dominant latency so the floor is visible
        let price = affine_price(alpha, 1.0 / 25e9);
        let m = OverlapModel { buckets: 1, compression: 0.25 };
        let bd = overlapped_step(0.05, 100e6, &m, &price);
        // bytes shrink 4x, alpha survives untouched.
        assert!((bd.exchange_s - (alpha + 25e6 / 25e9)).abs() < 1e-15);
        // Compression can never make the exchange cheaper than alpha.
        assert!(bd.exchange_s >= alpha);
    }

    #[test]
    fn bandwidth_bound_regime_saturates_the_wire() {
        // Exchange far bigger than compute: buckets cannot hide it; the
        // step tends to (C - w) + w/k + E, strictly above exchange alone.
        let price = affine_price(1e-6, 1.0 / 1e9);
        let compute = 0.01;
        let m = OverlapModel { buckets: 8, compression: 1.0 };
        let bd = overlapped_step(compute, 1e9, &m, &price);
        assert!(bd.step_s >= bd.exchange_s);
        assert!(bd.step_s < compute + bd.exchange_s,
                "some of the exchange must still hide under compute");
    }

    #[test]
    fn validation_rejects_bad_axes() {
        assert!(OverlapModel { buckets: 0, compression: 1.0 }
            .validate().is_err());
        assert!(OverlapModel { buckets: MAX_BUCKETS + 1, compression: 1.0 }
            .validate().is_err());
        assert!(OverlapModel { buckets: 1, compression: 0.0 }
            .validate().is_err());
        assert!(OverlapModel { buckets: 1, compression: 1.5 }
            .validate().is_err());
        assert!(OverlapModel { buckets: 1, compression: f64::NAN }
            .validate().is_err());
        assert!(OverlapModel { buckets: 8, compression: 0.25 }
            .validate().is_ok());
    }
}
