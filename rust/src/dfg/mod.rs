//! Dataflow-graph IR (paper §6, "Inputs: Computation DFG").
//!
//! A model is a DAG of compute operations.  Each vertex `k` carries the
//! paper's node weights — expected execution time Δ(k) (derived from FLOPs
//! and device throughput, or profiled) and memory footprint M(k) — and each
//! edge carries D(e), the bytes moved between dependent operations.
//!
//! The DFG is consumed by [`crate::placer`] (DLPlacer ILP), by
//! [`crate::sim`] (discrete-event "silicon" execution), and by
//! [`crate::pipeline`] (stage partitioning).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// Operation vertex: the paper's `k ∈ K` with Δ(k) and M(k).
#[derive(Clone, Debug)]
pub struct Op {
    pub name: String,
    /// Floating-point operations in this op (fwd+bwd combined unless the
    /// graph models passes separately).
    pub flops: f64,
    /// Output activation bytes produced (D(e) source value for out-edges).
    pub out_bytes: f64,
    /// Resident memory footprint M(k): weights + activations, bytes.
    pub mem_bytes: f64,
}

/// Dependency edge `e_{k1,k2}` with D(e) bytes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// The compute DFG: vertices `K`, edges `E`.
#[derive(Clone, Debug, Default)]
pub struct Dfg {
    pub name: String,
    pub ops: Vec<Op>,
    pub edges: Vec<Edge>,
}

impl Dfg {
    pub fn new(name: &str) -> Self {
        Dfg { name: name.to_string(), ..Default::default() }
    }

    /// Add an op, returning its index.
    pub fn add_op(&mut self, name: &str, flops: f64, out_bytes: f64,
                  mem_bytes: f64) -> usize {
        self.ops.push(Op {
            name: name.to_string(),
            flops,
            out_bytes,
            mem_bytes,
        });
        self.ops.len() - 1
    }

    /// Add a dependency edge carrying the source op's output bytes.
    pub fn add_edge(&mut self, src: usize, dst: usize) {
        let bytes = self.ops[src].out_bytes;
        self.edges.push(Edge { src, dst, bytes });
    }

    /// Add an edge with explicit byte count.
    pub fn add_edge_bytes(&mut self, src: usize, dst: usize, bytes: f64) {
        self.edges.push(Edge { src, dst, bytes });
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Adjacency: successors of each vertex.
    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succ = vec![Vec::new(); self.ops.len()];
        for e in &self.edges {
            succ[e.src].push(e.dst);
        }
        succ
    }

    /// Adjacency: predecessors of each vertex.
    pub fn predecessors(&self) -> Vec<Vec<usize>> {
        let mut pred = vec![Vec::new(); self.ops.len()];
        for e in &self.edges {
            pred[e.dst].push(e.src);
        }
        pred
    }

    /// Kahn topological order; error on cycles.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let succ = self.successors();
        let mut indeg = vec![0usize; self.ops.len()];
        for e in &self.edges {
            indeg[e.dst] += 1;
        }
        let mut queue: Vec<usize> =
            (0..self.ops.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.ops.len());
        while let Some(v) = queue.pop() {
            order.push(v);
            for &s in &succ[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if order.len() != self.ops.len() {
            bail!("DFG '{}' contains a cycle", self.name);
        }
        Ok(order)
    }

    /// Per-op execution time Δ(k) at `flops_per_sec` sustained throughput,
    /// with a fixed per-kernel launch overhead (paper §6 notes kernel
    /// overheads limit fine-grained splitting).
    pub fn op_times(&self, flops_per_sec: f64, launch_overhead_s: f64)
                    -> Vec<f64> {
        self.ops
            .iter()
            .map(|o| o.flops / flops_per_sec + launch_overhead_s)
            .collect()
    }

    /// Critical-path length through the DAG under given op times and zero
    /// communication cost: the single-device-free lower bound on step time,
    /// and the quantity DLPlacer tries to keep on one device (§6 case study).
    pub fn critical_path(&self, times: &[f64]) -> Result<f64> {
        let order = self.topo_order()?;
        let pred = self.predecessors();
        let mut finish = vec![0.0f64; self.ops.len()];
        for &v in &order {
            let start = pred[v]
                .iter()
                .map(|&p| finish[p])
                .fold(0.0f64, f64::max);
            finish[v] = start + times[v];
        }
        Ok(finish.iter().fold(0.0f64, |a, &b| a.max(b)))
    }

    /// Sum of all op times: the serial (one device, no overlap) step time.
    pub fn serial_time(&self, times: &[f64]) -> f64 {
        times.iter().sum()
    }

    /// Total FLOPs in the graph.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Total memory footprint.
    pub fn total_mem(&self) -> f64 {
        self.ops.iter().map(|o| o.mem_bytes).sum()
    }

    /// Maximum theoretical MP speedup = serial / critical-path (paper §2:
    /// "the amount of parallelism that exists in today's models is often
    /// limited").
    pub fn parallelism(&self, times: &[f64]) -> Result<f64> {
        let cp = self.critical_path(times)?;
        if cp == 0.0 {
            return Ok(1.0);
        }
        Ok(self.serial_time(times) / cp)
    }

    /// Graphviz DOT export (Fig. 7-style placement visualisation when a
    /// device assignment is provided).
    pub fn to_dot(&self, placement: Option<&[usize]>) -> String {
        const COLORS: [&str; 8] = ["lightblue", "lightsalmon", "palegreen",
                                   "plum", "khaki", "lightcyan", "pink",
                                   "wheat"];
        let mut s = String::new();
        let _ = writeln!(s, "digraph \"{}\" {{", self.name);
        let _ = writeln!(s, "  rankdir=TB; node [style=filled];");
        for (i, op) in self.ops.iter().enumerate() {
            let color = placement
                .map(|p| COLORS[p[i] % COLORS.len()])
                .unwrap_or("white");
            let _ = writeln!(
                s,
                "  n{} [label=\"{}\\n{:.1} MFLOP\", fillcolor={}];",
                i, op.name, op.flops / 1e6, color);
        }
        for e in &self.edges {
            let _ = writeln!(s, "  n{} -> n{} [label=\"{:.0}KB\"];",
                             e.src, e.dst, e.bytes / 1e3);
        }
        s.push_str("}\n");
        s
    }

    /// Group ops by a name prefix up to the first '/' — used to coarsen
    /// op-level graphs to block level for the ILP (the paper places at
    /// "tensorflow operation" granularity but coarsens Inception to blocks).
    pub fn coarsen_by_prefix(&self) -> Dfg {
        let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, op) in self.ops.iter().enumerate() {
            let key = op.name.split('/').next().unwrap_or(&op.name).to_string();
            groups.entry(key).or_default().push(i);
        }
        let mut out = Dfg::new(&format!("{}/coarse", self.name));
        let mut op_to_group = vec![0usize; self.ops.len()];
        for (gi, (name, members)) in groups.iter().enumerate() {
            let flops = members.iter().map(|&i| self.ops[i].flops).sum();
            let mem = members.iter().map(|&i| self.ops[i].mem_bytes).sum();
            let out_b = members.iter().map(|&i| self.ops[i].out_bytes).sum();
            out.add_op(name, flops, out_b, mem);
            for &m in members {
                op_to_group[m] = gi;
            }
        }
        let mut seen: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        for e in &self.edges {
            let (a, b) = (op_to_group[e.src], op_to_group[e.dst]);
            if a != b {
                *seen.entry((a, b)).or_insert(0.0) += e.bytes;
            }
        }
        for ((a, b), bytes) in seen {
            out.add_edge_bytes(a, b, bytes);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diamond: a -> {b, c} -> d.
    fn diamond() -> Dfg {
        let mut g = Dfg::new("diamond");
        let a = g.add_op("a", 1e9, 4e6, 1e6);
        let b = g.add_op("b", 2e9, 4e6, 1e6);
        let c = g.add_op("c", 2e9, 4e6, 1e6);
        let d = g.add_op("d", 1e9, 4e6, 1e6);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn topo_is_valid() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for e in &g.edges {
            assert!(pos[e.src] < pos[e.dst]);
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dfg::new("cyc");
        let a = g.add_op("a", 1.0, 1.0, 1.0);
        let b = g.add_op("b", 1.0, 1.0, 1.0);
        g.add_edge(a, b);
        g.add_edge(b, a);
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn critical_path_diamond() {
        let g = diamond();
        // 1 GFLOP/s device, no overhead: times = [1, 2, 2, 1].
        let times = g.op_times(1e9, 0.0);
        let cp = g.critical_path(&times).unwrap();
        assert!((cp - 4.0).abs() < 1e-9, "cp={cp}");
        assert!((g.serial_time(&times) - 6.0).abs() < 1e-9);
        // Max 2-way parallelism over b/c: 6/4 = 1.5x.
        assert!((g.parallelism(&times).unwrap() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn launch_overhead_added_per_op() {
        let g = diamond();
        let t0 = g.op_times(1e9, 0.0);
        let t1 = g.op_times(1e9, 0.5);
        for (a, b) in t0.iter().zip(&t1) {
            assert!((b - a - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_contains_nodes_and_colors() {
        let g = diamond();
        let dot = g.to_dot(Some(&[0, 1, 0, 1]));
        assert!(dot.contains("digraph"));
        assert!(dot.contains("lightblue"));
        assert!(dot.contains("lightsalmon"));
        assert!(dot.contains("n0 -> n1"));
    }

    #[test]
    fn coarsen_merges_prefix_groups() {
        let mut g = Dfg::new("m");
        let a1 = g.add_op("blk1/conv", 1e9, 1e6, 1.0);
        let a2 = g.add_op("blk1/pool", 1e9, 1e6, 1.0);
        let b1 = g.add_op("blk2/conv", 1e9, 1e6, 1.0);
        g.add_edge(a1, a2);
        g.add_edge(a2, b1);
        let c = g.coarsen_by_prefix();
        assert_eq!(c.n_ops(), 2);
        assert_eq!(c.edges.len(), 1); // only the cross-block edge survives
        assert!((c.ops[0].flops - 2e9).abs() < 1.0);
    }

    #[test]
    fn edge_inherits_src_out_bytes() {
        let g = diamond();
        assert_eq!(g.edges[0].bytes, 4e6);
    }

    #[test]
    fn topo_covers_disconnected_components() {
        // Two islands: a -> b and c -> d with no edges between them.  The
        // order must still visit every op exactly once, edges respected.
        let mut g = Dfg::new("islands");
        let a = g.add_op("a", 1.0, 1.0, 1.0);
        let b = g.add_op("b", 1.0, 1.0, 1.0);
        let c = g.add_op("c", 1.0, 1.0, 1.0);
        let d = g.add_op("d", 1.0, 1.0, 1.0);
        g.add_edge(a, b);
        g.add_edge(c, d);
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 4);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "each op exactly once");
        let pos = |v: usize| order.iter().position(|&x| x == v).unwrap();
        assert!(pos(a) < pos(b));
        assert!(pos(c) < pos(d));
        // An edgeless graph is trivially ordered too.
        let mut lone = Dfg::new("edgeless");
        lone.add_op("x", 1.0, 1.0, 1.0);
        lone.add_op("y", 1.0, 1.0, 1.0);
        assert_eq!(lone.topo_order().unwrap().len(), 2);
    }

    #[test]
    fn coarsen_merges_diamond_into_block_chain() {
        // Prefix groups across a diamond: head -> {mid/b, mid/c} -> tail
        // coarsens to the 3-block chain head -> mid -> tail, with the
        // parallel-branch edges merged (bytes summed) and the intra-group
        // edge (none here) dropped.
        let mut g = Dfg::new("dia");
        let a = g.add_op("head", 1e9, 4e6, 1e6);
        let b = g.add_op("mid/b", 2e9, 4e6, 1e6);
        let c = g.add_op("mid/c", 2e9, 4e6, 1e6);
        let d = g.add_op("tail", 1e9, 4e6, 1e6);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        let coarse = g.coarsen_by_prefix();
        assert_eq!(coarse.n_ops(), 3);
        // BTreeMap grouping: alphabetical block order head, mid, tail.
        let names: Vec<&str> =
            coarse.ops.iter().map(|o| o.name.as_str()).collect();
        assert_eq!(names, vec!["head", "mid", "tail"]);
        let mid = &coarse.ops[1];
        assert!((mid.flops - 4e9).abs() < 1.0, "branch flops summed");
        assert_eq!(coarse.edges.len(), 2, "parallel edges merge");
        for e in &coarse.edges {
            assert!((e.bytes - 8e6).abs() < 1.0,
                    "merged edge sums both branch transfers: {}", e.bytes);
        }
        assert!(coarse.topo_order().is_ok());
    }

    #[test]
    fn coarsen_keeps_disconnected_groups_apart() {
        // Disconnected prefix groups stay disconnected — coarsening must
        // not invent edges, and the result still topo-sorts.
        let mut g = Dfg::new("split");
        let a1 = g.add_op("left/x", 1e9, 1e6, 2.0);
        let a2 = g.add_op("left/y", 1e9, 1e6, 2.0);
        g.add_op("right/x", 3e9, 1e6, 4.0);
        g.add_edge(a1, a2);
        let coarse = g.coarsen_by_prefix();
        assert_eq!(coarse.n_ops(), 2);
        assert!(coarse.edges.is_empty(),
                "no cross-group edge exists in the source");
        assert_eq!(coarse.topo_order().unwrap().len(), 2);
        assert!((coarse.total_mem() - 8.0).abs() < 1e-9,
                "footprints survive the merge");
    }
}
