//! DLPlacer: ILP-based operation-to-device placement (paper §6).
//!
//! Maps a compute DFG onto a hardware graph to minimise per-step training
//! time, implementing the paper's constraint system:
//!
//! * Eq. 7  — each op placed on exactly one device (`P_kn` binaries);
//! * Eq. 10/11 — dependency scheduling with communication delay
//!   `Δe = D(e)/B(l) + L(l)` on cut edges (cut-ness is encoded with
//!   continuous `cut_e ≥ |P_i· − P_j·|` rows — exact under minimisation);
//! * Eq. 12 — co-located ops cannot overlap (disjunctive big-M rows with
//!   ordering binaries, only for pairs not already ordered by reachability);
//! * Eq. 13 — per-device memory capacity.
//!
//! **Routing (Eq. 8/9)**: on the paper's DGX-1 quad every device pair is a
//! single NVLink hop, so explicit routing variables are unnecessary; for
//! multi-hop topologies the shortest route (Dijkstra over the hardware
//! graph) supplies `Δe`.  This is the one simplification vs the paper's
//! full formulation and is recorded in DESIGN.md.
//!
//! **Decomposition**: DFGs like Inception-V3 are chains of blocks joined by
//! filter-concats; every path passes through each concat, so the ILP
//! decomposes exactly at these sync points.  Each segment is solved
//! optimally and the makespans add (the paper coarsens to "tensorflow
//! operation" granularity for the same tractability reason).  A HLFET
//! list-scheduling heuristic provides both the B&B warm start and the
//! "expert manual placement" baseline of §5 (21% vs DLPlacer's 32%).

pub mod anneal;

use std::time::Duration;

use anyhow::{bail, Result};

use crate::cluster::HwGraph;
use crate::dfg::Dfg;
use crate::milp::{solve_milp, BnbConfig, MilpOutcome, Problem};
use crate::sim::{simulate, SimConfig};

/// Placement outcome.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Hardware node index per op.
    pub assignment: Vec<usize>,
    /// ILP-predicted (or heuristic-predicted) step time.
    pub predicted_time: f64,
    /// True if every segment was solved to proven optimality.
    pub optimal: bool,
}

/// DLPlacer options.
#[derive(Clone, Debug)]
pub struct PlacerOptions {
    /// Max devices to use (defaults to all compute nodes).
    pub max_devices: usize,
    /// B&B budget per segment.
    pub bnb: BnbConfig,
    /// Decompose at sync points (exact for chain-of-blocks DFGs).
    pub decompose: bool,
}

impl Default for PlacerOptions {
    fn default() -> Self {
        PlacerOptions {
            max_devices: usize::MAX,
            bnb: BnbConfig {
                max_nodes: 20_000,
                time_limit: Duration::from_secs(30),
                gap: 1e-6,
                int_tol: 1e-6,
            },
            decompose: true,
        }
    }
}

/// Transfer delay of edge bytes between two devices (shortest route).
fn edge_delay(hw: &HwGraph, a: usize, b: usize, bytes: f64) -> f64 {
    hw.transfer_time(a, b, bytes)
}

/// Reachability matrix over the DAG (transitive closure).
fn reachability(dfg: &Dfg) -> Result<Vec<Vec<bool>>> {
    let n = dfg.n_ops();
    let order = dfg.topo_order()?;
    let succ = dfg.successors();
    let mut reach = vec![vec![false; n]; n];
    for &v in order.iter().rev() {
        for &s in &succ[v] {
            reach[v][s] = true;
            // v reaches everything s reaches.
            let (row_s, row_v) = if v < s {
                let (a, b) = reach.split_at_mut(s);
                (&b[0], &mut a[v])
            } else {
                let (a, b) = reach.split_at_mut(v);
                (&a[s], &mut b[0])
            };
            for i in 0..n {
                if row_s[i] {
                    row_v[i] = true;
                }
            }
        }
    }
    Ok(reach)
}

/// Sync points: topo positions `i` such that no edge jumps across the
/// boundary between position `i` and `i+1`... i.e. vertices every path
/// passes through.  Returns topo order + the indices (into that order) of
/// sync vertices.
fn sync_points(dfg: &Dfg) -> Result<(Vec<usize>, Vec<usize>)> {
    let order = dfg.topo_order()?;
    let n = order.len();
    let mut pos = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }
    let mut max_reach = vec![0usize; n];
    for e in &dfg.edges {
        let (a, b) = (pos[e.src], pos[e.dst]);
        max_reach[a] = max_reach[a].max(b);
    }
    // Position i is a sync point iff no edge from a position < i lands
    // past i — every path goes through the vertex at i.
    let mut run = 0usize;
    let mut syncs = Vec::new();
    for i in 0..n {
        if run <= i {
            syncs.push(i);
        }
        run = run.max(max_reach[i]);
    }
    Ok((order, syncs))
}

/// Build + solve the placement ILP for a sub-DAG given by `ops` (indices
/// into the full DFG).  `pinned` optionally pins specific ops to devices.
/// Returns (assignment per op-in-`ops`, makespan, proven_optimal).
fn solve_segment(dfg: &Dfg, hw: &HwGraph, times: &[f64], ops: &[usize],
                 devices: &[usize], pinned: &[(usize, usize)],
                 opts: &PlacerOptions)
                 -> Result<(Vec<usize>, f64, bool)> {
    let nd = devices.len();
    let k = ops.len();
    let mut local = vec![usize::MAX; dfg.n_ops()];
    for (li, &op) in ops.iter().enumerate() {
        local[op] = li;
    }
    let seg_edges: Vec<(usize, usize, f64)> = dfg
        .edges
        .iter()
        .filter(|e| local[e.src] != usize::MAX && local[e.dst] != usize::MAX)
        .map(|e| (local[e.src], local[e.dst], e.bytes))
        .collect();
    let seg_times: Vec<f64> = ops.iter().map(|&o| times[o]).collect();
    let serial: f64 = seg_times.iter().sum();
    let big_m = 2.0 * serial + 1.0;

    // Worst-case inter-device delay per edge (uniform on DGX quads).
    let delay = |bytes: f64| -> f64 {
        let mut worst: f64 = 0.0;
        for &a in devices {
            for &b in devices {
                if a != b {
                    worst = worst.max(edge_delay(hw, a, b, bytes));
                }
            }
        }
        worst
    };

    // ---- warm start: HLFET heuristic on the segment --------------------
    let (heur_assign, heur_time) =
        heuristic_segment(dfg, hw, times, ops, devices, pinned)?;

    if nd == 1 || k == 1 {
        return Ok((heur_assign, heur_time, true));
    }

    // ---- ILP ------------------------------------------------------------
    let mut p = Problem::minimize();
    // P[li][di]
    let mut pv = vec![vec![0usize; nd]; k];
    for li in 0..k {
        for di in 0..nd {
            pv[li][di] =
                p.add_binary(&format!("P_{}_{}", li, di), 0.0);
        }
        let row: Vec<(usize, f64)> =
            (0..nd).map(|di| (pv[li][di], 1.0)).collect();
        p.add_eq(&row, 1.0); // Eq. 7
    }
    // Pins.
    for &(op, dev) in pinned {
        if local[op] != usize::MAX {
            let li = local[op];
            let di = devices.iter().position(|&d| d == dev)
                .ok_or_else(|| anyhow::anyhow!("pin device not in set"))?;
            p.add_eq(&[(pv[li][di], 1.0)], 1.0);
        }
    }
    // T[li] and makespan C.
    let tv: Vec<usize> = (0..k)
        .map(|li| p.add_var(&format!("T_{li}"), 0.0, big_m, 0.0))
        .collect();
    let c = p.add_var("C", 0.0, big_m, 1.0);
    for li in 0..k {
        // C >= T + Δ
        p.add_ge(&[(c, 1.0), (tv[li], -1.0)], seg_times[li]);
    }
    // Edges: cut indicator + precedence (Eq. 10/11).
    for &(i, j, bytes) in &seg_edges {
        let d = delay(bytes);
        let cut = p.add_var(&format!("cut_{}_{}", i, j), 0.0, 1.0, 0.0);
        for di in 0..nd {
            // cut >= P[i][di] - P[j][di]  and symmetric.
            p.add_ge(&[(cut, 1.0), (pv[i][di], -1.0), (pv[j][di], 1.0)],
                     0.0);
            p.add_ge(&[(cut, 1.0), (pv[j][di], -1.0), (pv[i][di], 1.0)],
                     0.0);
        }
        // T[j] >= T[i] + Δi + d*cut.
        p.add_ge(&[(tv[j], 1.0), (tv[i], -1.0), (cut, -d)], seg_times[i]);
    }
    // Disjunctive no-overlap for unordered co-located pairs (Eq. 12):
    // ordering binary z (z=1 ⇒ a before b), big-M relaxed unless both ops
    // share device di.
    let reach = reachability(dfg)?;
    let mut pairs = Vec::new();
    for a in 0..k {
        for b in (a + 1)..k {
            let (oa, ob) = (ops[a], ops[b]);
            if !(reach[oa][ob] || reach[ob][oa]) {
                pairs.push((a, b));
            }
        }
    }
    for &(a, b) in &pairs {
        let z = p.add_binary(&format!("ord_{}_{}", a, b), 0.0);
        for di in 0..nd {
            // z=1 ∧ co-located on di ⇒ T[b] ≥ T[a] + Δa.
            // Relaxation: T[b] − T[a] ≥ Δa − M(1−z) − M(1−Pa) − M(1−Pb)
            //   ⇔ T[b] − T[a] − M·z − M·Pa − M·Pb ≥ Δa − 3M.
            p.add_ge(
                &[(tv[b], 1.0), (tv[a], -1.0), (z, -big_m),
                  (pv[a][di], -big_m), (pv[b][di], -big_m)],
                seg_times[a] - 3.0 * big_m,
            );
            // z=0 ∧ co-located on di ⇒ T[a] ≥ T[b] + Δb.
            //   ⇔ T[a] − T[b] + M·z − M·Pa − M·Pb ≥ Δb − 2M.
            p.add_ge(
                &[(tv[a], 1.0), (tv[b], -1.0), (z, big_m),
                  (pv[a][di], -big_m), (pv[b][di], -big_m)],
                seg_times[b] - 2.0 * big_m,
            );
        }
    }
    // Memory capacity (Eq. 13).
    for (di, &dev) in devices.iter().enumerate() {
        let row: Vec<(usize, f64)> = (0..k)
            .map(|li| (pv[li][di], dfg.ops[ops[li]].mem_bytes))
            .collect();
        p.add_le(&row, hw.nodes[dev].mem_capacity);
    }

    // Warm-start incumbent from the heuristic.
    let incumbent = build_incumbent(&p, &pv, &tv, c, &heur_assign, devices,
                                    dfg, hw, times, ops);

    let out = solve_milp(&p, opts.bnb, incumbent)?;
    let optimal = matches!(solve_status(&out), Status::Optimal);
    match out {
        MilpOutcome::Optimal { obj, x } | MilpOutcome::Feasible { obj, x, .. } => {
            let mut assign = vec![devices[0]; k];
            for li in 0..k {
                for di in 0..nd {
                    if x[pv[li][di]] > 0.5 {
                        assign[li] = devices[di];
                    }
                }
            }
            Ok((assign, obj, optimal))
        }
        MilpOutcome::Infeasible => {
            bail!("placement ILP infeasible (memory too small?)")
        }
        MilpOutcome::Unbounded => bail!("placement ILP unbounded (bug)"),
        MilpOutcome::Unknown => Ok((heur_assign, heur_time, false)),
    }
}

enum Status {
    Optimal,
    Other,
}

fn solve_status(o: &MilpOutcome) -> Status {
    match o {
        MilpOutcome::Optimal { .. } => Status::Optimal,
        _ => Status::Other,
    }
}

/// Encode a heuristic assignment as a feasible MILP point (P, T, C values
/// from an ideal-simulation of that assignment).
#[allow(clippy::too_many_arguments)]
fn build_incumbent(p: &Problem, pv: &[Vec<usize>], tv: &[usize], c: usize,
                   assign: &[usize], devices: &[usize], dfg: &Dfg,
                   hw: &HwGraph, times: &[f64], ops: &[usize])
                   -> Option<(f64, Vec<f64>)> {
    // Simulate the segment in the ILP's idealised model to get start times.
    let sub = segment_dfg(dfg, ops);
    let seg_times: Vec<f64> = ops.iter().map(|&o| times[o]).collect();
    let sim = simulate(&sub, hw, assign, &seg_times, SimConfig::ideal()).ok()?;
    let mut x = vec![0.0; p.vars.len()];
    for (li, &dev) in assign.iter().enumerate() {
        let di = devices.iter().position(|&d| d == dev)?;
        x[pv[li][di]] = 1.0;
        x[tv[li]] = sim.op_start[li];
    }
    x[c] = sim.makespan;
    // Ordering binaries / cut vars: set from the schedule.
    for (vi, var) in p.vars.iter().enumerate() {
        if var.name.starts_with("ord_") {
            let mut it = var.name.split('_').skip(1);
            let a: usize = it.next()?.parse().ok()?;
            let b: usize = it.next()?.parse().ok()?;
            x[vi] = if sim.op_start[a] <= sim.op_start[b] { 1.0 } else { 0.0 };
        } else if var.name.starts_with("cut_") {
            let mut it = var.name.split('_').skip(1);
            let a: usize = it.next()?.parse().ok()?;
            let b: usize = it.next()?.parse().ok()?;
            x[vi] = if assign[a] == assign[b] { 0.0 } else { 1.0 };
        }
    }
    if p.is_feasible(&x, 1e-5) {
        Some((sim.makespan, x))
    } else {
        None
    }
}

/// Extract a standalone DFG for the op subset (preserving order of `ops`).
fn segment_dfg(dfg: &Dfg, ops: &[usize]) -> Dfg {
    let mut local = vec![usize::MAX; dfg.n_ops()];
    let mut g = Dfg::new(&format!("{}/seg", dfg.name));
    for (li, &op) in ops.iter().enumerate() {
        local[op] = li;
        let o = &dfg.ops[op];
        g.add_op(&o.name, o.flops, o.out_bytes, o.mem_bytes);
    }
    for e in &dfg.edges {
        if local[e.src] != usize::MAX && local[e.dst] != usize::MAX {
            g.add_edge_bytes(local[e.src], local[e.dst], e.bytes);
        }
    }
    g
}

/// HLFET list-scheduling heuristic with communication awareness: assign
/// each ready op to the device minimising its completion time.
///
/// Memory-balanced objective: devices whose Eq. 13 capacity the op would
/// overflow are dropped from the candidate set while any fitting device
/// remains, so the heuristic (and the "expert manual placement" baseline
/// it stands in for) respects per-device footprints instead of piling
/// weights onto the fastest-finishing GPU.  When *no* device fits, the
/// full set is kept (the placement is validated downstream and reported
/// infeasible there, with the overflow amount).
fn heuristic_segment(dfg: &Dfg, hw: &HwGraph, times: &[f64], ops: &[usize],
                     devices: &[usize], pinned: &[(usize, usize)])
                     -> Result<(Vec<usize>, f64)> {
    let sub = segment_dfg(dfg, ops);
    let seg_times: Vec<f64> = ops.iter().map(|&o| times[o]).collect();
    let n = sub.n_ops();
    let preds = sub.predecessors();
    let succs = sub.successors();
    let order = sub.topo_order()?;
    // Priorities: downstream critical path.
    let mut prio = vec![0.0f64; n];
    for &v in order.iter().rev() {
        let down = succs[v].iter().map(|&s| prio[s]).fold(0.0f64, f64::max);
        prio[v] = seg_times[v] + down;
    }
    let mut pin_map = vec![usize::MAX; n];
    for &(op, dev) in pinned {
        if let Some(li) = ops.iter().position(|&o| o == op) {
            pin_map[li] = dev;
        }
    }
    let mut dev_free = vec![0.0f64; hw.nodes.len()];
    let mut mem_used = vec![0.0f64; hw.nodes.len()];
    let mut finish = vec![0.0f64; n];
    let mut assign = vec![devices[0]; n];
    let mut done = vec![false; n];
    let mut n_done = 0;
    while n_done < n {
        // Ready set.
        let mut ready: Vec<usize> = (0..n)
            .filter(|&v| !done[v] && preds[v].iter().all(|&q| done[q]))
            .collect();
        ready.sort_by(|&a, &b| prio[b].partial_cmp(&prio[a]).unwrap());
        let v = ready[0];
        // Choose device minimising completion.
        let mut best = (f64::INFINITY, devices[0]);
        let mut cands: Vec<usize> = if pin_map[v] != usize::MAX {
            vec![pin_map[v]]
        } else {
            devices.to_vec()
        };
        // Memory balance (Eq. 13): while any device still fits the op,
        // restrict the choice to those devices.
        let op_mem = sub.ops[v].mem_bytes;
        let fitting: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&d| mem_used[d] + op_mem <= hw.nodes[d].mem_capacity)
            .collect();
        if !fitting.is_empty() {
            cands = fitting;
        }
        for &d in &cands {
            let mut data_ready = 0.0f64;
            for &q in &preds[v] {
                let e_bytes = sub
                    .edges
                    .iter()
                    .find(|e| e.src == q && e.dst == v)
                    .map(|e| e.bytes)
                    .unwrap_or(0.0);
                let arrive = if assign[q] == d {
                    finish[q]
                } else {
                    finish[q] + edge_delay(hw, assign[q], d, e_bytes)
                };
                data_ready = data_ready.max(arrive);
            }
            let start = data_ready.max(dev_free[d]);
            let end = start + seg_times[v];
            if end < best.0 {
                best = (end, d);
            }
        }
        assign[v] = best.1;
        finish[v] = best.0;
        dev_free[best.1] = best.0;
        mem_used[best.1] += op_mem;
        done[v] = true;
        n_done += 1;
    }
    let makespan = finish.iter().fold(0.0f64, |a, &b| a.max(b));
    Ok((assign, makespan))
}

/// DLPlacer main entry: place `dfg` on the devices of `hw` with per-op
/// times `times` (Δ(k)).
pub fn place(dfg: &Dfg, hw: &HwGraph, times: &[f64], opts: &PlacerOptions)
             -> Result<Placement> {
    let devices: Vec<usize> = hw
        .devices()
        .into_iter()
        .take(opts.max_devices)
        .collect();
    if devices.is_empty() {
        bail!("no compute devices");
    }
    let (order, syncs) = sync_points(dfg)?;

    if !opts.decompose || syncs.len() <= 2 {
        let ops: Vec<usize> = order.clone();
        let (assign, time, optimal) = solve_segment(
            dfg, hw, times, &ops, &devices, &[], opts)?;
        let mut full = vec![devices[0]; dfg.n_ops()];
        for (li, &op) in ops.iter().enumerate() {
            full[op] = assign[li];
        }
        // Guard: if B&B exhausted its budget with a weaker incumbent, the
        // whole-graph heuristic may still win — return the best candidate
        // (only if it also satisfies the memory constraint, which the
        // list scheduler does not enforce).
        let heur = place_heuristic_on(dfg, hw, times, &devices)?;
        if heur.predicted_time < time
            && validate_placement(dfg, hw, &heur.assignment).is_ok()
        {
            return Ok(Placement { optimal: false, ..heur });
        }
        return Ok(Placement {
            assignment: full,
            predicted_time: time,
            optimal,
        });
    }

    // Segments: positions [sync_j ..= sync_{j+1}], boundaries shared and
    // pinned to device 0 (concats/sync ops are negligible compute).  The
    // final segment runs to the last vertex even if it is not a sync.
    let mut cut_positions: Vec<usize> = syncs.clone();
    let last = order.len() - 1;
    if *cut_positions.last().unwrap() != last {
        cut_positions.push(last);
    }
    let mut full = vec![devices[0]; dfg.n_ops()];
    let mut total = 0.0;
    let mut all_optimal = true;
    let mut double_counted = 0.0;
    for w in cut_positions.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a == b {
            continue;
        }
        let ops: Vec<usize> = (a..=b).map(|i| order[i]).collect();
        let mut pins = vec![(order[a], devices[0])];
        if syncs.contains(&b) {
            pins.push((order[b], devices[0]));
        }
        let (assign, time, optimal) =
            solve_segment(dfg, hw, times, &ops, &devices, &pins, opts)?;
        for (li, &op) in ops.iter().enumerate() {
            full[op] = assign[li];
        }
        total += time;
        all_optimal &= optimal;
        if a != cut_positions[0] {
            double_counted += times[order[a]];
        }
    }
    total -= double_counted;
    // The decomposition pins sync vertices to device 0, which is exact for
    // negligible-compute sync ops (concats) but can lose on graphs with
    // heavy sync vertices.  Fall back to the whole-graph heuristic when it
    // predicts better AND satisfies memory (the production placer returns
    // the best feasible candidate).
    let heur = place_heuristic_on(dfg, hw, times, &devices)?;
    if heur.predicted_time < total
        && validate_placement(dfg, hw, &heur.assignment).is_ok()
    {
        return Ok(Placement { optimal: false, ..heur });
    }
    Ok(Placement {
        assignment: full,
        predicted_time: total,
        optimal: all_optimal,
    })
}

/// Heuristic-only placement (the "expert/manual" baseline of §5).
pub fn place_heuristic(dfg: &Dfg, hw: &HwGraph, times: &[f64],
                       max_devices: usize) -> Result<Placement> {
    let devices: Vec<usize> =
        hw.devices().into_iter().take(max_devices).collect();
    place_heuristic_on(dfg, hw, times, &devices)
}

fn place_heuristic_on(dfg: &Dfg, hw: &HwGraph, times: &[f64],
                      devices: &[usize]) -> Result<Placement> {
    let ops: Vec<usize> = dfg.topo_order()?;
    let (assign, time) =
        heuristic_segment(dfg, hw, times, &ops, devices, &[])?;
    let mut full = vec![devices[0]; dfg.n_ops()];
    for (li, &op) in ops.iter().enumerate() {
        full[op] = assign[li];
    }
    Ok(Placement { assignment: full, predicted_time: time, optimal: false })
}

/// Check a placement satisfies Eq. 7 (total) and Eq. 13 (memory).
pub fn validate_placement(dfg: &Dfg, hw: &HwGraph, assignment: &[usize])
                          -> Result<()> {
    if assignment.len() != dfg.n_ops() {
        bail!("assignment length mismatch");
    }
    let mut mem = vec![0.0f64; hw.nodes.len()];
    for (op, &d) in assignment.iter().enumerate() {
        if d >= hw.nodes.len() || !hw.nodes[d].is_compute {
            bail!("op {op} on non-compute node {d}");
        }
        mem[d] += dfg.ops[op].mem_bytes;
    }
    for (d, &m) in mem.iter().enumerate() {
        if hw.nodes[d].is_compute && m > hw.nodes[d].mem_capacity {
            bail!("device {d} over memory: {m} > {}",
                  hw.nodes[d].mem_capacity);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dgx1;

    /// entry -> {b1 (slow), b2 (slow)} -> exit: optimal 2-device placement
    /// overlaps b1/b2.
    fn fork() -> (Dfg, Vec<f64>) {
        let mut g = Dfg::new("fork");
        let a = g.add_op("a", 1.0, 1e6, 1.0);
        let b = g.add_op("b", 1.0, 1e6, 1.0);
        let c = g.add_op("c", 1.0, 1e6, 1.0);
        let d = g.add_op("d", 1.0, 1e6, 1.0);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        (g, vec![0.1, 1.0, 1.0, 0.1])
    }

    #[test]
    fn ilp_overlaps_fork() {
        let (g, t) = fork();
        let hw = dgx1(2);
        let p = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        validate_placement(&g, &hw, &p.assignment).unwrap();
        // serial = 2.2; with overlap ≈ 1.2 + ε.
        assert!(p.predicted_time < 1.4, "predicted {}", p.predicted_time);
        assert_ne!(p.assignment[1], p.assignment[2],
                   "branches must go to different devices");
    }

    #[test]
    fn ilp_keeps_chain_on_one_device() {
        let mut g = Dfg::new("chain");
        let mut prev = g.add_op("op0", 1.0, 100e6, 1.0); // expensive comm
        for i in 1..4 {
            let cur = g.add_op(&format!("op{i}"), 1.0, 100e6, 1.0);
            g.add_edge(prev, cur);
            prev = cur;
        }
        let t = vec![0.01; 4];
        let hw = dgx1(2);
        let p = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        let first = p.assignment[0];
        assert!(p.assignment.iter().all(|&d| d == first),
                "chain with heavy edges must not be cut: {:?}", p.assignment);
    }

    #[test]
    fn heuristic_feasible_and_close() {
        let (g, t) = fork();
        let hw = dgx1(2);
        let h = place_heuristic(&g, &hw, &t, 2).unwrap();
        validate_placement(&g, &hw, &h.assignment).unwrap();
        let ilp = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        assert!(ilp.predicted_time <= h.predicted_time + 1e-9,
                "ILP {} must not lose to heuristic {}",
                ilp.predicted_time, h.predicted_time);
    }

    #[test]
    fn memory_constraint_forces_split() {
        let mut g = Dfg::new("mem");
        let a = g.add_op("a", 1.0, 1.0, 9e9);
        let b = g.add_op("b", 1.0, 1.0, 9e9);
        g.add_edge(a, b);
        let hw = dgx1(2); // 16 GB per device
        let p = place(&g, &hw, &[1.0, 1.0],
                      &PlacerOptions { decompose: false,
                                       ..Default::default() }).unwrap();
        validate_placement(&g, &hw, &p.assignment).unwrap();
        assert_ne!(p.assignment[0], p.assignment[1],
                   "memory must force a split");
    }

    #[test]
    fn heuristic_respects_memory_capacity() {
        // Two independent-ish heavy-memory ops after a root: completion
        // time alone would co-locate the cheap chain, but 9 GB + 9 GB
        // overflows one 16 GB V100 — the heuristic must spread them.
        let mut g = Dfg::new("mem-heur");
        let a = g.add_op("a", 1.0, 1e3, 1e6);
        let b = g.add_op("b", 1.0, 1e3, 9e9);
        let c = g.add_op("c", 1.0, 1e3, 9e9);
        g.add_edge(a, b);
        g.add_edge(b, c);
        let hw = dgx1(2); // 16 GB per device
        let h = place_heuristic(&g, &hw, &[0.01, 0.01, 0.01], 2).unwrap();
        validate_placement(&g, &hw, &h.assignment).unwrap();
        assert_ne!(h.assignment[1], h.assignment[2],
                   "heuristic must memory-balance: {:?}", h.assignment);
    }

    #[test]
    fn sync_point_decomposition_matches_monolithic() {
        // Two fork blocks joined by a concat: decomposition must give the
        // same makespan as the monolithic ILP.
        let mut g = Dfg::new("blocks");
        let a = g.add_op("in", 1.0, 1e3, 1.0);
        let b1 = g.add_op("b1", 1.0, 1e3, 1.0);
        let b2 = g.add_op("b2", 1.0, 1e3, 1.0);
        let cat = g.add_op("cat", 1.0, 1e3, 1.0);
        let c1 = g.add_op("c1", 1.0, 1e3, 1.0);
        let c2 = g.add_op("c2", 1.0, 1e3, 1.0);
        let out = g.add_op("out", 1.0, 1e3, 1.0);
        g.add_edge(a, b1);
        g.add_edge(a, b2);
        g.add_edge(b1, cat);
        g.add_edge(b2, cat);
        g.add_edge(cat, c1);
        g.add_edge(cat, c2);
        g.add_edge(c1, out);
        g.add_edge(c2, out);
        let t = vec![0.01, 0.5, 0.5, 0.01, 0.5, 0.5, 0.01];
        let hw = dgx1(2);
        let mono = place(&g, &hw, &t,
                         &PlacerOptions { decompose: false,
                                          ..Default::default() }).unwrap();
        let deco = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        assert!((mono.predicted_time - deco.predicted_time).abs() < 0.02,
                "mono {} vs decomposed {}", mono.predicted_time,
                deco.predicted_time);
    }

    #[test]
    fn single_device_serialises() {
        let (g, t) = fork();
        let hw = dgx1(1);
        let p = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        assert!((p.predicted_time - 2.2).abs() < 1e-6,
                "serial time {}", p.predicted_time);
    }

    #[test]
    fn validate_rejects_bad() {
        let (g, _) = fork();
        let hw = dgx1(2);
        assert!(validate_placement(&g, &hw, &[0, 0, 9, 0]).is_err());
        assert!(validate_placement(&g, &hw, &[0, 0]).is_err());
    }
}
