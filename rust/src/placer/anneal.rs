//! Stochastic placement search — the paper's §7.4 comparison point.
//!
//! The paper contrasts DLPlacer's exact ILP with RL-based placement
//! (Mirhoseini et al.): "RL-based approaches can be long-running and
//! compute-intensive with no notion of optimality."  This module implements
//! that class of method as simulated annealing over placements, scored by
//! the ideal-model simulator — a stochastic learner with exactly the
//! properties the paper describes (anytime, no optimality certificate),
//! used as the ablation baseline in `placer_scaling`.

use crate::cluster::HwGraph;
use crate::dfg::Dfg;
use crate::sim::{simulate, SimConfig};
use crate::util::rng::Rng;

use super::{validate_placement, Placement};

/// Annealing options.
#[derive(Clone, Copy, Debug)]
pub struct AnnealOptions {
    pub iterations: usize,
    pub t_start: f64,
    pub t_end: f64,
    pub seed: u64,
}

impl Default for AnnealOptions {
    fn default() -> Self {
        AnnealOptions {
            iterations: 2000,
            t_start: 0.3,
            t_end: 1e-3,
            seed: 0,
        }
    }
}

/// Score a placement: ideal-model makespan, +inf when invalid (memory).
fn score(dfg: &Dfg, hw: &HwGraph, assignment: &[usize], times: &[f64])
         -> f64 {
    if validate_placement(dfg, hw, assignment).is_err() {
        return f64::INFINITY;
    }
    simulate(dfg, hw, assignment, times, SimConfig::ideal())
        .map(|r| r.makespan)
        .unwrap_or(f64::INFINITY)
}

/// Simulated-annealing placement over `max_devices` devices.
pub fn place_annealed(dfg: &Dfg, hw: &HwGraph, times: &[f64],
                      max_devices: usize, opts: AnnealOptions)
                      -> anyhow::Result<Placement> {
    let devices: Vec<usize> =
        hw.devices().into_iter().take(max_devices).collect();
    anyhow::ensure!(!devices.is_empty(), "no devices");
    let n = dfg.n_ops();
    let mut rng = Rng::new(opts.seed);

    // Start from everything-on-device-0 (always memory-feasible if any
    // placement is, for single-device-fitting graphs; otherwise random
    // restarts below explore).
    let mut cur = vec![devices[0]; n];
    let mut cur_score = score(dfg, hw, &cur, times);
    if cur_score.is_infinite() {
        // Random feasible start.
        for _ in 0..50 {
            for a in cur.iter_mut() {
                *a = devices[rng.below(devices.len() as u64) as usize];
            }
            cur_score = score(dfg, hw, &cur, times);
            if cur_score.is_finite() {
                break;
            }
        }
    }
    let mut best = cur.clone();
    let mut best_score = cur_score;

    let cool = (opts.t_end / opts.t_start)
        .powf(1.0 / opts.iterations.max(1) as f64);
    let mut temp = opts.t_start;
    for _ in 0..opts.iterations {
        // Move: reassign one random op to a random device.
        let op = rng.below(n as u64) as usize;
        let old = cur[op];
        let new = devices[rng.below(devices.len() as u64) as usize];
        if new == old {
            temp *= cool;
            continue;
        }
        cur[op] = new;
        let s = score(dfg, hw, &cur, times);
        let accept = s <= cur_score
            || (s.is_finite()
                && rng.f64()
                    < (-(s - cur_score) / (temp * cur_score.max(1e-12)))
                        .exp());
        if accept {
            cur_score = s;
            if s < best_score {
                best_score = s;
                best = cur.clone();
            }
        } else {
            cur[op] = old;
        }
        temp *= cool;
    }

    anyhow::ensure!(best_score.is_finite(), "no feasible placement found");
    Ok(Placement {
        assignment: best,
        predicted_time: best_score,
        optimal: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dgx1;
    use crate::placer::{place, PlacerOptions};

    fn fork() -> (Dfg, Vec<f64>) {
        let mut g = Dfg::new("fork");
        let a = g.add_op("a", 1.0, 1e6, 1.0);
        let b = g.add_op("b", 1.0, 1e6, 1.0);
        let c = g.add_op("c", 1.0, 1e6, 1.0);
        let d = g.add_op("d", 1.0, 1e6, 1.0);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        (g, vec![0.1, 1.0, 1.0, 0.1])
    }

    #[test]
    fn anneal_finds_the_overlap() {
        let (g, t) = fork();
        let hw = dgx1(2);
        let p = place_annealed(&g, &hw, &t, 2,
                               AnnealOptions::default()).unwrap();
        validate_placement(&g, &hw, &p.assignment).unwrap();
        // Must discover branch overlap: well under serial 2.2.
        assert!(p.predicted_time < 1.5, "score {}", p.predicted_time);
    }

    #[test]
    fn anneal_never_beats_ilp_optimum() {
        let (g, t) = fork();
        let hw = dgx1(2);
        let ilp = place(&g, &hw, &t, &PlacerOptions::default()).unwrap();
        let sa = place_annealed(&g, &hw, &t, 2,
                                AnnealOptions::default()).unwrap();
        // ILP is optimal in the same ideal model: SA can only tie or lose.
        assert!(sa.predicted_time >= ilp.predicted_time - 1e-6,
                "SA {} vs ILP {}", sa.predicted_time, ilp.predicted_time);
    }

    #[test]
    fn anneal_deterministic_per_seed() {
        let (g, t) = fork();
        let hw = dgx1(2);
        let a = place_annealed(&g, &hw, &t, 2,
                               AnnealOptions::default()).unwrap();
        let b = place_annealed(&g, &hw, &t, 2,
                               AnnealOptions::default()).unwrap();
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn anneal_respects_memory() {
        let mut g = Dfg::new("mem");
        let a = g.add_op("a", 1.0, 1.0, 9e9);
        let b = g.add_op("b", 1.0, 1.0, 9e9);
        g.add_edge(a, b);
        let hw = dgx1(2);
        let p = place_annealed(&g, &hw, &[1.0, 1.0], 2,
                               AnnealOptions::default()).unwrap();
        validate_placement(&g, &hw, &p.assignment).unwrap();
        assert_ne!(p.assignment[0], p.assignment[1]);
    }
}
