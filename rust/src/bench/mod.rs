//! Micro-benchmark harness (criterion unavailable offline).
//!
//! Provides warmup + timed iterations with mean/p50/p95 statistics and a
//! criterion-like console report, plus a table printer used by the
//! paper-figure benches to emit the same rows/series the paper reports.

use std::time::Instant;

use crate::util::{fmt_secs, mean, percentile};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

/// Run `f` with warmup and timing. `min_iters`/`min_time_s` bound the
/// sampling effort.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, min_time_s: f64,
                         mut f: F) -> Measurement {
    // Warmup: 2 calls or 10% of budget.
    f();
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || (start.elapsed().as_secs_f64() < min_time_s
            && samples.len() < 10_000)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let m = Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean(&samples),
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!(
        "bench {:<44} {:>10}/iter (p50 {:>10}, p95 {:>10}, n={})",
        m.name,
        fmt_secs(m.mean_s),
        fmt_secs(m.p50_s),
        fmt_secs(m.p95_s),
        m.iters
    );
    m
}

/// Simple aligned table printer for paper-figure data series.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        for r in &self.rows {
            line(r);
        }
    }

    /// CSV dump for EXPERIMENTS.md ingestion.
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }
}

/// `f2` helper: format a float with 2 decimals (bench tables).
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}

/// `f3` helper.
pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iters() {
        let mut count = 0;
        let m = bench("noop", 5, 0.0, || count += 1);
        assert!(m.iters >= 5);
        assert!(count >= 7); // warmup + iters
        assert!(m.min_s <= m.mean_s);
        assert!(m.mean_s <= m.p95_s + 1e-9);
    }

    #[test]
    fn table_round_trip() {
        let mut t = Table::new(&["n", "speedup"]);
        t.row(&["2".into(), f2(1.45)]);
        t.row(&["4".into(), f2(1.65)]);
        let csv = t.to_csv();
        assert!(csv.contains("n,speedup"));
        assert!(csv.contains("2,1.45"));
        t.print("test table");
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
