//! Statistical-efficiency models: epochs-to-converge E(B) vs global batch
//! size (paper §3.1, Fig. 4).
//!
//! The paper measures E_N by training each network to a fixed quality
//! target at emulated global batch sizes (delayed gradient updates, §4.2).
//! Here E(B) comes from two sources:
//!
//! 1. **Calibrated curves** ([`EpochModel::calibrated`]) digitised from the
//!    paper's Fig. 4 for Inception-V3 / GNMT / BigLSTM — these drive the
//!    Fig. 4/5 reproductions so the projection math is exercised against
//!    the paper's own statistical-efficiency data;
//! 2. **Measured curves** ([`EpochModel::from_points`]) produced by the
//!    coordinator's real convergence runs on the small transformer
//!    (`examples/batch_size_sweep.rs`), demonstrating the same mechanism
//!    end-to-end on this testbed.
//!
//! Between calibration points, E(B) is interpolated geometrically
//! (log-log linear), matching the power-law-like growth past the critical
//! batch size that the paper and Shallue et al. (2018) report.

use anyhow::{bail, Result};

/// Epochs-to-converge as a function of global batch size.
#[derive(Clone, Debug)]
pub struct EpochModel {
    pub name: String,
    /// (global_batch_size, epochs) calibration points, sorted by batch.
    pub points: Vec<(f64, f64)>,
    /// Batch size beyond which training failed to converge (paper: BigLSTM
    /// "beyond 32-way DP, training did not converge within a meaningful
    /// time limit").
    pub diverges_beyond: Option<f64>,
}

impl EpochModel {
    /// Build from measured (batch, epochs) points.
    pub fn from_points(name: &str, mut points: Vec<(f64, f64)>)
                       -> Result<Self> {
        if points.is_empty() {
            bail!("no calibration points");
        }
        points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        Ok(EpochModel {
            name: name.to_string(),
            points,
            diverges_beyond: None,
        })
    }

    pub fn with_divergence(mut self, beyond: f64) -> Self {
        self.diverges_beyond = Some(beyond);
        self
    }

    /// Epochs to converge at global batch size `b` (log-log interpolation,
    /// clamped at the ends).
    pub fn epochs(&self, b: f64) -> Option<f64> {
        if let Some(limit) = self.diverges_beyond {
            if b > limit {
                return None;
            }
        }
        let pts = &self.points;
        if b <= pts[0].0 {
            return Some(pts[0].1);
        }
        if b >= pts[pts.len() - 1].0 {
            return Some(pts[pts.len() - 1].1);
        }
        for w in pts.windows(2) {
            let ((b0, e0), (b1, e1)) = (w[0], w[1]);
            if b >= b0 && b <= b1 {
                let t = (b.ln() - b0.ln()) / (b1.ln() - b0.ln());
                return Some((e0.ln() + t * (e1.ln() - e0.ln())).exp());
            }
        }
        unreachable!()
    }

    /// E_1 / E_N — the statistical-efficiency ratio in Eq. 3 (computed at
    /// the model's smallest calibrated batch as the N=1 anchor).
    pub fn efficiency_ratio(&self, b: f64) -> Option<f64> {
        let e1 = self.points[0].1;
        self.epochs(b).map(|en| e1 / en)
    }

    // --- paper-calibrated curves (Fig. 4) --------------------------------
    // x-axis: #GPUs with the paper's per-GPU mini-batch; we store global
    // batch sizes directly.

    /// Inception-V3: mini-batch 64/GPU; "epochs increase sharply from four
    /// to seven beyond batch 2048 (32 GPUs), 23 epochs at 16384 (256)".
    pub fn inception_v3() -> Self {
        EpochModel {
            name: "inception-v3".into(),
            points: vec![
                (64.0, 4.0),     // 1 GPU
                (256.0, 4.0),    // 4
                (1024.0, 4.0),   // 16
                (2048.0, 4.0),   // 32
                (4096.0, 7.0),   // 64
                (8192.0, 12.0),  // 128
                (16384.0, 23.0), // 256
            ],
            diverges_beyond: None,
        }
    }

    /// GNMT: mini-batch 128/GPU; tuned hyper-parameters keep E flat to 64
    /// GPUs ("epoch count decreases slightly from two to four GPUs"), then
    /// grows, "dramatically beyond 128".
    pub fn gnmt() -> Self {
        EpochModel {
            name: "gnmt".into(),
            points: vec![
                (128.0, 5.0),    // 1 GPU
                (256.0, 5.0),    // 2
                (512.0, 4.8),    // 4 (slight decrease, tuned LR)
                (2048.0, 4.8),   // 16
                (8192.0, 5.0),   // 64
                (16384.0, 6.0),  // 128
                (32768.0, 11.2), // 256 (dramatic slowdown)
            ],
            diverges_beyond: None,
        }
    }

    /// BigLSTM: mini-batch 64/GPU; "beyond 16 GPUs epochs increase rapidly;
    /// 3.2x the epochs at 32-way vs 16-way; beyond 32-way did not
    /// converge".
    pub fn biglstm() -> Self {
        EpochModel {
            name: "biglstm".into(),
            points: vec![
                (64.0, 5.0),    // 1 GPU
                (256.0, 5.0),   // 4
                (512.0, 5.2),   // 8
                (1024.0, 6.0),  // 16
                (2048.0, 19.2), // 32 (3.2x of 16-way)
            ],
            diverges_beyond: Some(2048.0),
        }
    }

    /// The hypothetical example of Fig. 3: mild epoch growth making DP
    /// saturate past 32 devices.
    pub fn fig3_example() -> Self {
        EpochModel {
            name: "fig3-example".into(),
            points: vec![
                (1.0, 10.0),
                (32.0, 10.0),
                (64.0, 14.0),
                (128.0, 22.0),
                (256.0, 40.0),
            ],
            diverges_beyond: None,
        }
    }
}

/// Delayed-gradient-update emulation math (paper §4.2): emulating a
/// `target_ways`-way DP system on `physical` devices requires
/// `target_ways / physical` sequential mini-batches per device per step.
pub fn delayed_update_factor(target_ways: usize, physical: usize)
                             -> Result<usize> {
    if physical == 0 || target_ways == 0 {
        bail!("zero device count");
    }
    if target_ways % physical != 0 {
        bail!("target {target_ways} not a multiple of physical {physical}");
    }
    Ok(target_ways / physical)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_exact_at_points() {
        let m = EpochModel::inception_v3();
        for &(b, e) in &m.points {
            assert!((m.epochs(b).unwrap() - e).abs() < 1e-9);
        }
    }

    #[test]
    fn interpolation_monotone_between() {
        let m = EpochModel::inception_v3();
        let e = m.epochs(6000.0).unwrap();
        assert!(e > 7.0 && e < 12.0, "e={e}");
    }

    #[test]
    fn clamps_outside_range() {
        let m = EpochModel::gnmt();
        assert_eq!(m.epochs(1.0).unwrap(), 5.0);
        assert_eq!(m.epochs(1e9).unwrap(), 11.2);
    }

    #[test]
    fn biglstm_divergence() {
        let m = EpochModel::biglstm();
        assert!(m.epochs(2048.0).is_some());
        assert!(m.epochs(4096.0).is_none());
    }

    #[test]
    fn efficiency_ratio_at_scale_below_one() {
        let m = EpochModel::inception_v3();
        assert!((m.efficiency_ratio(64.0).unwrap() - 1.0).abs() < 1e-9);
        let r = m.efficiency_ratio(16384.0).unwrap();
        assert!((r - 4.0 / 23.0).abs() < 1e-9);
    }

    #[test]
    fn from_points_sorts() {
        let m = EpochModel::from_points("x", vec![(100.0, 8.0), (10.0, 4.0)])
            .unwrap();
        assert_eq!(m.points[0].0, 10.0);
        assert!(m.epochs(30.0).unwrap() > 4.0);
    }

    #[test]
    fn empty_points_rejected() {
        assert!(EpochModel::from_points("x", vec![]).is_err());
    }

    #[test]
    fn delayed_update() {
        assert_eq!(delayed_update_factor(16, 4).unwrap(), 4);
        assert_eq!(delayed_update_factor(4, 4).unwrap(), 1);
        assert!(delayed_update_factor(6, 4).is_err());
        assert!(delayed_update_factor(0, 4).is_err());
    }

    #[test]
    fn loglog_interpolation_is_geometric() {
        // Points (10,1) and (1000,100): at b=100 expect 10.
        let m = EpochModel::from_points(
            "geo", vec![(10.0, 1.0), (1000.0, 100.0)]).unwrap();
        assert!((m.epochs(100.0).unwrap() - 10.0).abs() < 1e-9);
    }
}
