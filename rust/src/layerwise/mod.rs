//! Layer-wise strategy search: per-op parallelization configurations
//! composed into a mixed whole-model strategy (PaSE-style, see
//! PAPERS.md).
//!
//! The paper scores a *fixed* whole-model candidate family — DP, placed
//! MP, GPipe hybrids at each degree M — but its own premise (the best
//! split depends on per-layer compute/comm/memory shape) is left
//! unexploited.  This module searches the per-op space instead: every op
//! of the DFG independently picks one of
//!
//! * **replicate** — every device of the M-wide group computes the full
//!   op (no intra-op comm: the replicas produce identical results);
//! * **split-batch** — the mini-batch is sharded M ways; compute drops to
//!   1/M but the op's *weight gradients* must be all-reduced inside the
//!   group every step;
//! * **split-feature** — the output features (and so the weights) are
//!   sharded M ways; compute drops to 1/M and weight gradients stay
//!   local, at the price of re-layout collectives on the op's edges;
//! * **stage d** — the whole op is placed on group device `d`
//!   (placement-style model parallelism; cross-stage edges pay
//!   point-to-point transfers over [`crate::cluster::HwGraph`] links).
//!
//! Edge re-layout costs between adjacent ops are priced through
//! [`crate::collective::best_allreduce_on`] (collective-class reshards)
//! and [`crate::cluster::HwGraph::path_profile`] (stage-to-stage
//! transfers).  A dynamic program over the topo-linearised DFG composes
//! the per-op choices into the cheapest mixed assignment: exact Viterbi
//! on chains (GNMT, BigLSTM, the transformer LM — and Inception once
//! coarsened to blocks via [`crate::dfg::Dfg::coarsen_by_prefix`]),
//! greedy-committed on irreducibly branchy DAGs.  An optional MILP
//! refinement lowers the same pricing onto [`crate::milp::Problem`] /
//! [`crate::milp::solve_milp`] and cross-checks (or improves) the DP
//! optimum on small graphs.
//!
//! The objective is the serialised sum of intra-op times and edge
//! re-layout costs — exact for chains executed one op at a time,
//! conservative for DAGs whose branches could overlap.  The planner
//! surfaces the result as `mechanism = "layerwise"` scorecard rows and a
//! [`crate::coordinator::Strategy::LayerWise`] per-op assignment.

use anyhow::{bail, Result};

use crate::cluster::HwGraph;
use crate::collective::{best_allreduce_on, TopoProfile, DEFAULT_ALPHA};
use crate::dfg::Dfg;
use crate::memory::{op_activation_bytes, op_weight_bytes};
use crate::milp::{solve_milp, BnbConfig, MilpOutcome, Problem};

/// One op's parallelization configuration inside an M-device group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpConfig {
    /// Full op on every device (identical replicas, no intra comm).
    Replicate,
    /// Mini-batch sharded M ways; weight grads all-reduced in-group.
    SplitBatch,
    /// Output features (and weights) sharded M ways; grads stay local.
    SplitFeature,
    /// Whole op placed on group device `d` (placement-style MP).
    Stage(usize),
}

impl OpConfig {
    /// Wire label ("replicate", "split-batch", "split-feature", "stage3").
    pub fn label(&self) -> String {
        match self {
            OpConfig::Replicate => "replicate".to_string(),
            OpConfig::SplitBatch => "split-batch".to_string(),
            OpConfig::SplitFeature => "split-feature".to_string(),
            OpConfig::Stage(d) => format!("stage{d}"),
        }
    }

    pub fn parse(s: &str) -> Result<OpConfig> {
        Ok(match s {
            "replicate" => OpConfig::Replicate,
            "split-batch" => OpConfig::SplitBatch,
            "split-feature" => OpConfig::SplitFeature,
            other => match other.strip_prefix("stage") {
                Some(d) => OpConfig::Stage(d.parse::<usize>().map_err(
                    |e| anyhow::anyhow!("bad stage index '{other}': {e}"))?),
                None => bail!("unknown op config '{other}' (known: \
                               replicate, split-batch, split-feature, \
                               stage<d>)"),
            },
        })
    }
}

/// Search knobs.  `flops_per_sec` / `launch_overhead_s` derive the per-op
/// Δ(k) exactly as the planner's cost models do
/// ([`crate::planner::CostModel::op_time_params`]), so layer-wise rows
/// are comparable with the fixed candidates they sit next to.
#[derive(Clone, Debug)]
pub struct LayerwiseOptions {
    pub flops_per_sec: f64,
    pub launch_overhead_s: f64,
    /// Per-step software overhead for collective re-layout pricing.
    pub alpha: f64,
    /// Cap on enumerated `Stage(d)` configs per op (placement choices).
    pub max_stage_configs: usize,
    /// Lower the problem onto the MILP solver and adopt its solution
    /// when it beats the DP (exact on any DAG; the DP is exact on chains
    /// only).  Bounded by `milp_max_ops` — branch-and-bound over
    /// `n_ops × n_configs` binaries is for small graphs.
    pub refine_milp: bool,
    pub milp_max_ops: usize,
}

impl Default for LayerwiseOptions {
    fn default() -> Self {
        LayerwiseOptions {
            flops_per_sec: 7e12,
            launch_overhead_s: 15e-6,
            alpha: DEFAULT_ALPHA,
            max_stage_configs: 8,
            refine_milp: false,
            milp_max_ops: 8,
        }
    }
}

/// The search result: a per-op assignment (at the *original* op
/// granularity, even when the DP ran block-level) plus the priced step
/// time and the per-device footprint inputs the memory-feasibility layer
/// needs ([`crate::memory::layerwise`]).
#[derive(Clone, Debug)]
pub struct LayerWiseSolution {
    /// Device-group width M the assignment targets.
    pub degree: usize,
    /// (op name, config label) per original op, in op-index order.
    pub assignment: Vec<(String, String)>,
    /// Priced step time: Σ intra-op + Σ edge re-layout (seconds).
    pub step_time_s: f64,
    /// Compute part of the step (Δ(k) terms).
    pub compute_s: f64,
    /// Communication part (grad sync + re-layout collectives + stage
    /// transfers).
    pub comm_s: f64,
    /// Per group-device (weight bytes, raw activation bytes).
    pub per_device: Vec<(f64, f64)>,
    /// True when the assignment mixes ≥ 2 distinct configurations — the
    /// cases where the search found something no fixed candidate is.
    pub mixed: bool,
    /// Search granularity: "op" (chain DFGs) or "block" (coarsened).
    pub granularity: &'static str,
    /// DP objective before any MILP refinement.
    pub dp_step_time_s: f64,
    /// MILP objective when refinement ran (cross-check artifact).
    pub milp_step_time_s: Option<f64>,
}

// ==========================================================================
// Pricing
// ==========================================================================

/// Priced search space over one work graph (op- or block-granular):
/// per-(op, config) intra costs and per-edge config-pair re-layout
/// matrices.  The DP and the MILP lowering read the *same* tables, so
/// their optima can only differ by search power, never by pricing.
struct Pricing {
    m: usize,
    configs: Vec<OpConfig>,
    /// intra[i][c]: compute + intra-op comm of op i under config c.
    intra: Vec<Vec<f64>>,
    /// compute part of `intra` (for the solution's breakdown).
    intra_compute: Vec<Vec<f64>>,
    /// Work-graph edges (src, dst, relay[c_src][c_dst]).
    edges: Vec<(usize, usize, Vec<Vec<f64>>)>,
}

/// Re-layout cost between a producer in `src` layout and a consumer in
/// `dst` layout, in seconds.  `ar` is one group collective
/// (allgather/reduce class) of the edge's bytes, `p2p` one point-to-point
/// transfer of them.  Costs charge forward re-layout plus the mirrored
/// backward-gradient re-layout:
///
/// * aligned batch shards, identical replicas, and same-device stages
///   move nothing;
/// * a replicated producer is free to consume forward (every device
///   already holds the full tensor) and pays one collective backward to
///   reassemble its output gradient;
/// * any genuine reshard (batch↔feature, shard↔full, shard↔stage) pays
///   one collective each way;
/// * stage-to-stage hops pay the link path forward and backward.
fn relayout(src: OpConfig, dst: OpConfig, ar: f64, p2p: f64) -> f64 {
    use OpConfig::*;
    match (src, dst) {
        (Replicate, Replicate) | (SplitBatch, SplitBatch) => 0.0,
        (Replicate, _) => ar,
        (Stage(a), Stage(b)) if a == b => 0.0,
        (Stage(_), Stage(_)) => 2.0 * p2p,
        _ => 2.0 * ar,
    }
}

impl Pricing {
    fn build(work: &Dfg, hw: &HwGraph, m: usize, opts: &LayerwiseOptions)
             -> Pricing {
        let profile = TopoProfile::for_budget(hw, m);
        // Stage-to-stage link: the co-located pair's path (NVLink-class
        // defaults when the graph is degenerate), matching the pipeline
        // estimator's stage link.
        let devs = hw.devices();
        let (link_bw, link_lat) = if devs.len() >= 2 {
            hw.path_profile(devs[0], devs[1], 64e6)
                .unwrap_or((25e9, 1.3e-6))
        } else {
            (25e9, 1.3e-6)
        };
        let ar = |bytes: f64| best_allreduce_on(m, bytes, &profile,
                                                opts.alpha).cost_s;
        let p2p = |bytes: f64| bytes / link_bw + link_lat;

        let mut configs = vec![OpConfig::Replicate, OpConfig::SplitBatch,
                               OpConfig::SplitFeature];
        for d in 0..m.min(opts.max_stage_configs) {
            configs.push(OpConfig::Stage(d));
        }

        let n = work.n_ops();
        let mut intra = vec![vec![0.0; configs.len()]; n];
        let mut intra_compute = vec![vec![0.0; configs.len()]; n];
        for (i, op) in work.ops.iter().enumerate() {
            let full = op.flops / opts.flops_per_sec + opts.launch_overhead_s;
            let split =
                op.flops / (opts.flops_per_sec * m as f64)
                    + opts.launch_overhead_s;
            let w = op_weight_bytes(op);
            for (c, cfg) in configs.iter().enumerate() {
                let (compute, comm) = match cfg {
                    OpConfig::Replicate | OpConfig::Stage(_) => (full, 0.0),
                    OpConfig::SplitBatch => (split, ar(w)),
                    OpConfig::SplitFeature => (split, 0.0),
                };
                intra_compute[i][c] = compute;
                intra[i][c] = compute + comm;
            }
        }

        let edges = work
            .edges
            .iter()
            .map(|e| {
                let ar_e = ar(e.bytes);
                let p2p_e = p2p(e.bytes);
                let relay: Vec<Vec<f64>> = configs
                    .iter()
                    .map(|&cs| {
                        configs
                            .iter()
                            .map(|&cd| relayout(cs, cd, ar_e, p2p_e))
                            .collect()
                    })
                    .collect();
                (e.src, e.dst, relay)
            })
            .collect();

        Pricing { m, configs, intra, intra_compute, edges }
    }

    /// Total objective of a full assignment (config index per op).
    fn price(&self, assign: &[usize]) -> f64 {
        let intra: f64 =
            assign.iter().enumerate().map(|(i, &c)| self.intra[i][c]).sum();
        let relay: f64 = self
            .edges
            .iter()
            .map(|(u, v, r)| r[assign[*u]][assign[*v]])
            .sum();
        intra + relay
    }
}

// ==========================================================================
// Dynamic program
// ==========================================================================

/// Linear order of a pure chain (≤ 1 pred and ≤ 1 succ everywhere, one
/// source, fully connected); `None` for anything branchy or disconnected.
fn chain_order(dfg: &Dfg) -> Option<Vec<usize>> {
    let n = dfg.n_ops();
    if n == 0 {
        return None;
    }
    let succ = dfg.successors();
    let pred = dfg.predecessors();
    if succ.iter().any(|s| s.len() > 1) || pred.iter().any(|p| p.len() > 1) {
        return None;
    }
    let sources: Vec<usize> =
        (0..n).filter(|&v| pred[v].is_empty()).collect();
    if sources.len() != 1 {
        return None;
    }
    let mut order = Vec::with_capacity(n);
    let mut at = sources[0];
    loop {
        order.push(at);
        match succ[at].first() {
            Some(&next) => at = next,
            None => break,
        }
    }
    if order.len() == n { Some(order) } else { None }
}

/// Exact Viterbi over a chain: `best[i][c]` = cheapest prefix ending with
/// op `order[i]` in config `c`; backpointers recover the argmin.
fn viterbi(p: &Pricing, order: &[usize]) -> Vec<usize> {
    let nc = p.configs.len();
    // Summed relay matrix per consecutive (u, v) pair (parallel edges
    // accumulate).
    let pair_relay = |u: usize, v: usize| -> Vec<Vec<f64>> {
        let mut acc = vec![vec![0.0; nc]; nc];
        for (eu, ev, r) in &p.edges {
            if *eu == u && *ev == v {
                for a in 0..nc {
                    for b in 0..nc {
                        acc[a][b] += r[a][b];
                    }
                }
            }
        }
        acc
    };
    let mut best: Vec<Vec<f64>> = vec![p.intra[order[0]].clone()];
    let mut back: Vec<Vec<usize>> = Vec::new();
    for w in order.windows(2) {
        let relay = pair_relay(w[0], w[1]);
        let prev = best.last().unwrap().clone();
        let mut row = vec![f64::INFINITY; nc];
        let mut arg = vec![0usize; nc];
        for c in 0..nc {
            for (cp, &pv) in prev.iter().enumerate() {
                let v = pv + relay[cp][c] + p.intra[w[1]][c];
                if v < row[c] {
                    row[c] = v;
                    arg[c] = cp;
                }
            }
        }
        best.push(row);
        back.push(arg);
    }
    // Backtrack from the cheapest final config.
    let last = best.last().unwrap();
    let mut c = last
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut rev = vec![c];
    for arg in back.iter().rev() {
        c = arg[c];
        rev.push(c);
    }
    rev.reverse();
    // rev[i] is the config of order[i]; scatter to op-index order.
    let mut assign = vec![0usize; p.intra.len()];
    for (i, &v) in order.iter().enumerate() {
        assign[v] = rev[i];
    }
    assign
}

/// Greedy forward pass for branchy work graphs: ops commit in topo order,
/// each picking the config that is cheapest against its already-committed
/// predecessors.  A heuristic (no lookahead); the MILP refinement path is
/// the exact solver for these graphs.
fn greedy(p: &Pricing, order: &[usize]) -> Vec<usize> {
    let nc = p.configs.len();
    let n = p.intra.len();
    let mut assign = vec![usize::MAX; n];
    // Incoming relay matrices per op.
    for &v in order {
        let mut bc = 0usize;
        let mut bv = f64::INFINITY;
        for c in 0..nc {
            let mut cost = p.intra[v][c];
            for (eu, ev, r) in &p.edges {
                if *ev == v && assign[*eu] != usize::MAX {
                    cost += r[assign[*eu]][c];
                }
            }
            if cost < bv {
                bv = cost;
                bc = c;
            }
        }
        assign[v] = bc;
    }
    assign
}

// ==========================================================================
// MILP lowering
// ==========================================================================

/// Lower the priced search space onto [`crate::milp::Problem`]: one
/// binary `x[i,c]` per (op, config) with the intra cost as objective and
/// `Σ_c x[i,c] = 1`, plus one continuous `y ∈ [0,1]` per (edge, config
/// pair) with positive re-layout cost and `y ≥ x[u,cu] + x[v,cv] − 1`
/// (the standard exact product linearisation — minimisation presses every
/// `y` to the bound, so the LP relaxation's integral optima equal the
/// combinatorial optimum).  Returns the problem and the `x` index map.
fn lower_to_milp(p: &Pricing) -> (Problem, Vec<Vec<usize>>) {
    let nc = p.configs.len();
    let mut prob = Problem::minimize();
    let x: Vec<Vec<usize>> = p
        .intra
        .iter()
        .enumerate()
        .map(|(i, row)| {
            (0..nc)
                .map(|c| {
                    prob.add_binary(
                        &format!("x_{i}_{}", p.configs[c].label()), row[c])
                })
                .collect()
        })
        .collect();
    for row in &x {
        let coeffs: Vec<(usize, f64)> =
            row.iter().map(|&j| (j, 1.0)).collect();
        prob.add_eq(&coeffs, 1.0);
    }
    for (ei, (u, v, relay)) in p.edges.iter().enumerate() {
        for cu in 0..nc {
            for cv in 0..nc {
                let cost = relay[cu][cv];
                if cost <= 0.0 {
                    continue;
                }
                let y = prob.add_var(&format!("y_{ei}_{cu}_{cv}"), 0.0, 1.0,
                                     cost);
                prob.add_ge(&[(y, 1.0), (x[*u][cu], -1.0),
                              (x[*v][cv], -1.0)],
                            -1.0);
            }
        }
    }
    (prob, x)
}

/// Solve the MILP lowering, warm-started from the DP assignment.
/// Returns (objective, assignment) of the best solution found.  The
/// objective is re-priced through [`Pricing::price`] rather than taken
/// from the LP arithmetic, so DP and MILP optima are bit-comparable:
/// identical assignments price identically.
fn milp_solve(p: &Pricing, dp_assign: &[usize])
              -> Result<Option<(f64, Vec<usize>)>> {
    let (prob, x) = lower_to_milp(p);
    // Warm start: the DP solution as the incumbent upper bound.
    let mut x0 = vec![0.0; prob.vars.len()];
    for (i, &c) in dp_assign.iter().enumerate() {
        x0[x[i][c]] = 1.0;
    }
    for (ei, (u, v, relay)) in p.edges.iter().enumerate() {
        let (cu, cv) = (dp_assign[*u], dp_assign[*v]);
        if relay[cu][cv] > 0.0 {
            // y var order matches lower_to_milp's insertion; find by name
            // cost instead of replaying the index arithmetic.
            let name = format!("y_{ei}_{cu}_{cv}");
            if let Some(j) =
                prob.vars.iter().position(|vr| vr.name == name)
            {
                x0[j] = 1.0;
            }
        }
    }
    let incumbent = if prob.is_feasible(&x0, 1e-6) {
        Some((p.price(dp_assign), x0))
    } else {
        None
    };
    let out = solve_milp(&prob, BnbConfig::default(), incumbent)?;
    let xs = match out {
        MilpOutcome::Optimal { x, .. }
        | MilpOutcome::Feasible { x, .. } => x,
        _ => return Ok(None),
    };
    let nc = p.configs.len();
    let assign: Vec<usize> = x
        .iter()
        .map(|row| {
            (0..nc)
                .max_by(|&a, &b| {
                    xs[row[a]].partial_cmp(&xs[row[b]]).unwrap()
                })
                .unwrap_or(0)
        })
        .collect();
    let obj = p.price(&assign);
    Ok(Some((obj, assign)))
}

// ==========================================================================
// Solver entry point
// ==========================================================================

/// Find the cheapest per-op configuration assignment for running `dfg`
/// on an `m`-device group of `hw`.  Chain DFGs solve exactly at op
/// granularity; branchy DFGs are coarsened to blocks
/// ([`Dfg::coarsen_by_prefix`]) first and solve exactly if the block
/// graph is a chain (Inception's is), greedily otherwise — with the
/// optional MILP refinement recovering exactness on small graphs.
pub fn solve(dfg: &Dfg, hw: &HwGraph, m: usize, opts: &LayerwiseOptions)
             -> Result<LayerWiseSolution> {
    if m < 2 {
        bail!("layer-wise search needs a device group of at least 2 \
               (got {m})");
    }
    let physical = hw.devices().len();
    if m > physical {
        bail!("layer-wise device group of {m} exceeds the {physical} \
               physical devices of the topology");
    }
    if dfg.n_ops() == 0 {
        bail!("layer-wise search over an empty DFG");
    }

    // Pick the work granularity.
    let (work, granularity) = match chain_order(dfg) {
        Some(_) => (dfg.clone(), "op"),
        None => (dfg.coarsen_by_prefix(), "block"),
    };
    let pricing = Pricing::build(&work, hw, m, opts);
    let order = work.topo_order()?;

    let dp_assign = match chain_order(&work) {
        Some(chain) => viterbi(&pricing, &chain),
        None => greedy(&pricing, &order),
    };
    let dp_obj = pricing.price(&dp_assign);

    let (mut assign, mut obj) = (dp_assign.clone(), dp_obj);
    let mut milp_obj = None;
    if opts.refine_milp && work.n_ops() <= opts.milp_max_ops {
        if let Some((mo, ma)) = milp_solve(&pricing, &dp_assign)? {
            milp_obj = Some(mo);
            if mo < obj - 1e-12 {
                obj = mo;
                assign = ma;
            }
        }
    }

    // Expand the work-graph assignment to original ops.  At block
    // granularity every original op inherits its block's config; the
    // block key is the op-name prefix up to the first '/'.
    let per_op: Vec<OpConfig> = if granularity == "op" {
        assign.iter().map(|&c| pricing.configs[c]).collect()
    } else {
        let key_of = |name: &str| -> String {
            name.split('/').next().unwrap_or(name).to_string()
        };
        dfg.ops
            .iter()
            .map(|op| {
                let key = key_of(&op.name);
                let gi = work
                    .ops
                    .iter()
                    .position(|g| g.name == key)
                    .unwrap_or(0);
                pricing.configs[assign[gi]]
            })
            .collect()
    };

    // Per group-device footprint inputs for the memory layer.
    let mut per_device = vec![(0.0f64, 0.0f64); m];
    for (op, cfg) in dfg.ops.iter().zip(&per_op) {
        let w = op_weight_bytes(op);
        let a = op_activation_bytes(op);
        let mf = m as f64;
        match cfg {
            OpConfig::Replicate => {
                for d in per_device.iter_mut() {
                    d.0 += w;
                    d.1 += a;
                }
            }
            OpConfig::SplitBatch => {
                for d in per_device.iter_mut() {
                    d.0 += w;
                    d.1 += a / mf;
                }
            }
            OpConfig::SplitFeature => {
                for d in per_device.iter_mut() {
                    d.0 += w / mf;
                    d.1 += a / mf;
                }
            }
            OpConfig::Stage(k) => {
                let slot = (*k).min(m - 1);
                per_device[slot].0 += w;
                per_device[slot].1 += a;
            }
        }
    }

    let compute_s: f64 = assign
        .iter()
        .enumerate()
        .map(|(i, &c)| pricing.intra_compute[i][c])
        .sum();
    let comm_s = obj - compute_s;
    let mixed = {
        let first = per_op.first().copied();
        per_op.iter().any(|c| Some(*c) != first)
    };

    Ok(LayerWiseSolution {
        degree: m,
        assignment: dfg
            .ops
            .iter()
            .zip(&per_op)
            .map(|(op, cfg)| (op.name.clone(), cfg.label()))
            .collect(),
        step_time_s: obj,
        compute_s,
        comm_s: comm_s.max(0.0),
        per_device,
        mixed,
        granularity,
        dp_step_time_s: dp_obj,
        milp_step_time_s: milp_obj,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster;
    use crate::models;

    fn chain(specs: &[(f64, f64, f64)]) -> Dfg {
        // (flops, out_bytes, mem_bytes) per op, linearly connected.
        let mut g = Dfg::new("chain");
        let mut prev = None;
        for (i, &(f, o, m)) in specs.iter().enumerate() {
            let op = g.add_op(&format!("op{i}"), f, o, m);
            if let Some(p) = prev {
                g.add_edge(p, op);
            }
            prev = Some(op);
        }
        g
    }

    fn diamond() -> Dfg {
        let mut g = Dfg::new("diamond");
        let a = g.add_op("a", 1e12, 4e6, 40e6);
        let b = g.add_op("b", 2e12, 4e6, 40e6);
        let c = g.add_op("c", 2e12, 4e6, 40e6);
        let d = g.add_op("d", 1e12, 4e6, 40e6);
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn config_labels_round_trip() {
        for c in [OpConfig::Replicate, OpConfig::SplitBatch,
                  OpConfig::SplitFeature, OpConfig::Stage(0),
                  OpConfig::Stage(7)] {
            assert_eq!(OpConfig::parse(&c.label()).unwrap(), c);
        }
        assert!(OpConfig::parse("magic").is_err());
        assert!(OpConfig::parse("stagex").is_err());
    }

    #[test]
    fn solver_beats_every_uniform_configuration() {
        // The DP minimises over a superset of the uniform assignments, so
        // it can never be worse than replicate-all / split-all.
        let hw = cluster::dgx1(8);
        let opts = LayerwiseOptions::default();
        for m in [2usize, 4] {
            let prof = models::gnmt(128);
            let sol = solve(&prof.dfg, &hw, m, &opts).unwrap();
            let pricing = Pricing::build(&prof.dfg, &hw, m, &opts);
            let nc = pricing.configs.len();
            for c in 0..nc {
                let uniform = vec![c; prof.dfg.n_ops()];
                assert!(sol.step_time_s
                        <= pricing.price(&uniform) + 1e-12,
                        "m={m} config {:?} beat the DP",
                        pricing.configs[c]);
            }
        }
    }

    #[test]
    fn big_weights_push_ops_off_split_batch() {
        // One op with huge weights and modest compute: split-batch's grad
        // all-reduce dwarfs the compute saving, so the DP must choose
        // split-feature (grads local) or replicate for it.
        let g = chain(&[
            (2e12, 5e6, 40e6),   // compute-heavy, light weights
            (1e10, 5e6, 3e9),    // weight-heavy (3 GB), light compute
            (2e12, 5e6, 40e6),
        ]);
        let hw = cluster::dgx1(8);
        let sol = solve(&g, &hw, 2,
                        &LayerwiseOptions::default()).unwrap();
        let cfg1 = OpConfig::parse(&sol.assignment[1].1).unwrap();
        assert_ne!(cfg1, OpConfig::SplitBatch,
                   "3 GB of grads cannot be worth all-reducing: {:?}",
                   sol.assignment);
    }

    #[test]
    fn tiny_ops_prefer_replication() {
        // An op with negligible compute and weights feeding a sharded
        // consumer: replicate (edge cost 1 collective) must beat the
        // sharded configs (2 collectives on the out-edge).
        let prof = models::biglstm(64);
        let hw = cluster::dgx1(8);
        let sol = solve(&prof.dfg, &hw, 2,
                        &LayerwiseOptions::default()).unwrap();
        assert!(sol.mixed, "biglstm must mix configs: {:?}",
                sol.assignment);
        assert_eq!(sol.assignment[0].0, "embed");
        // The big softmax (3.2 GB weights) must not pick split-batch.
        let sm = sol.assignment.last().unwrap();
        assert_eq!(sm.0, "softmax");
        assert_ne!(sm.1, "split-batch");
    }

    #[test]
    fn chains_solve_at_op_granularity_and_branchy_at_block() {
        let hw = cluster::dgx1(8);
        let opts = LayerwiseOptions::default();
        let g = models::gnmt(128);
        assert_eq!(solve(&g.dfg, &hw, 2, &opts).unwrap().granularity,
                   "op");
        let inc = models::inception_v3(32);
        let sol = solve(&inc.dfg, &hw, 2, &opts).unwrap();
        assert_eq!(sol.granularity, "block");
        assert_eq!(sol.assignment.len(), inc.dfg.n_ops());
        // Ops of one block share one config.
        for (name, cfg) in &sol.assignment {
            if name.starts_with("mixed0a/") {
                assert_eq!(cfg, &sol.assignment
                           .iter()
                           .find(|(n, _)| n.starts_with("mixed0a/"))
                           .unwrap().1);
            }
        }
    }

    #[test]
    fn dp_matches_milp_on_small_chains() {
        // The Viterbi DP is exact on chains; the MILP lowering of the
        // same pricing must agree to numerical tolerance.
        let hw = cluster::dgx1(4);
        let opts = LayerwiseOptions {
            refine_milp: true,
            ..Default::default()
        };
        let graphs = [
            chain(&[(1e12, 4e6, 40e6), (1e10, 4e6, 2e9),
                    (2e12, 8e6, 80e6)]),
            chain(&[(5e11, 2e6, 1e9), (5e11, 2e6, 20e6),
                    (5e11, 2e6, 1e9), (5e11, 2e6, 20e6)]),
        ];
        for g in &graphs {
            for m in [2usize, 3] {
                let sol = solve(g, &hw, m, &opts).unwrap();
                let milp = sol.milp_step_time_s.expect("refinement ran");
                let gap = (milp - sol.dp_step_time_s).abs()
                    / sol.dp_step_time_s.max(1e-12);
                assert!(gap < 1e-9,
                        "m={m}: DP {} vs MILP {milp}",
                        sol.dp_step_time_s);
                assert!((sol.step_time_s - sol.dp_step_time_s).abs()
                        < 1e-12,
                        "agreement must keep the DP assignment");
            }
        }
    }

    #[test]
    fn milp_refines_greedy_on_branchy_graphs() {
        // On a diamond the greedy forward pass has no lookahead; the MILP
        // is exact, so refinement can only improve (or match) it — and
        // the reported step time is the better of the two.
        let g = diamond();
        let hw = cluster::dgx1(4);
        let opts = LayerwiseOptions {
            refine_milp: true,
            ..Default::default()
        };
        let sol = solve(&g, &hw, 2, &opts).unwrap();
        let milp = sol.milp_step_time_s.expect("refinement ran");
        assert!(milp <= sol.dp_step_time_s + 1e-12);
        assert!((sol.step_time_s - sol.dp_step_time_s.min(milp)).abs()
                < 1e-12);
    }

    #[test]
    fn per_device_footprints_cover_the_model() {
        // Weight bytes across the group ≥ the model's (replication can
        // only add); activations shrink with sharding.
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(8);
        let sol = solve(&prof.dfg, &hw, 2,
                        &LayerwiseOptions::default()).unwrap();
        assert_eq!(sol.per_device.len(), 2);
        let total_w: f64 = prof.dfg.ops.iter()
            .map(op_weight_bytes).sum();
        let group_w: f64 = sol.per_device.iter().map(|d| d.0).sum();
        assert!(group_w >= total_w * (1.0 - 1e-9),
                "group weights {group_w} < model {total_w}");
        assert!(sol.compute_s > 0.0);
        assert!(sol.step_time_s >= sol.compute_s);
    }

    #[test]
    fn solve_rejects_degenerate_inputs() {
        let prof = models::gnmt(128);
        let hw = cluster::dgx1(8);
        assert!(solve(&prof.dfg, &hw, 1,
                      &LayerwiseOptions::default()).is_err());
        assert!(solve(&Dfg::new("empty"), &hw, 2,
                      &LayerwiseOptions::default()).is_err());
        assert!(solve(&prof.dfg, &hw, 64,
                      &LayerwiseOptions::default()).is_err(),
                "a 64-wide group cannot exist on an 8-device box");
    }
}
