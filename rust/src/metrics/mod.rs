//! Metrics: loss curves, step timing, CSV export, and the lock-free
//! counter/histogram primitives the planner service exports in
//! Prometheus text format (`GET /metrics`).

use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

// ==========================================================================
// Service-grade primitives: Counter + Histogram
// ==========================================================================

/// A monotonically increasing event counter (Prometheus `counter`).
/// Lock-free; safe to share across request-handling threads.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// One Prometheus exposition line: `name{labels} value` (`labels`
    /// empty = no brace block).
    pub fn render(&self, name: &str, labels: &str) -> String {
        if labels.is_empty() {
            format!("{name} {}\n", self.get())
        } else {
            format!("{name}{{{labels}}} {}\n", self.get())
        }
    }
}

/// A point-in-time value that can move both ways (Prometheus `gauge`) —
/// open connections, queue depth.  Lock-free, like [`Counter`].
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement — a stray extra `dec` must not wrap a
    /// "connections open" gauge to 2^64.
    pub fn dec(&self) {
        let _ = self.value.fetch_update(
            Ordering::Relaxed, Ordering::Relaxed,
            |v| Some(v.saturating_sub(1)));
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// One Prometheus exposition line, like [`Counter::render`].
    pub fn render(&self, name: &str, labels: &str) -> String {
        if labels.is_empty() {
            format!("{name} {}\n", self.get())
        } else {
            format!("{name}{{{labels}}} {}\n", self.get())
        }
    }
}

/// Latency bucket upper bounds (seconds) shared by every service
/// endpoint histogram: 100 µs to 10 s on a 1-2.5-5 ladder, wide enough
/// for a cache hit (~sub-ms) and a cold DLPlacer ILP (~seconds) to land
/// in distinct buckets.
pub const LATENCY_BUCKETS_S: [f64; 16] = [
    100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3, 50e-3,
    100e-3, 250e-3, 500e-3, 1.0, 2.5, 5.0, 10.0,
];

/// A fixed-bucket histogram (Prometheus `histogram`): cumulative bucket
/// counts, total observation count and sum.  Lock-free — observations
/// touch one bucket counter, the total and a CAS-looped f64 sum.
#[derive(Debug)]
pub struct Histogram {
    /// Upper bounds, strictly increasing; an implicit `+Inf` bucket
    /// catches everything beyond the last bound.
    bounds: Vec<f64>,
    /// Per-bound observation counts (non-cumulative internally;
    /// cumulated at render time, as the exposition format requires).
    counts: Vec<AtomicU64>,
    inf_count: AtomicU64,
    total: AtomicU64,
    /// Sum of observed values, stored as f64 bits.
    sum_bits: AtomicU64,
}

impl Histogram {
    /// Build with the given upper bounds (must be strictly increasing).
    pub fn new(bounds: &[f64]) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]),
                "histogram bounds must be strictly increasing");
        Histogram {
            bounds: bounds.to_vec(),
            counts: bounds.iter().map(|_| AtomicU64::new(0)).collect(),
            inf_count: AtomicU64::new(0),
            total: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// The shared service latency ladder.
    pub fn latency() -> Self {
        Histogram::new(&LATENCY_BUCKETS_S)
    }

    pub fn observe(&self, v: f64) {
        match self.bounds.iter().position(|&b| v <= b) {
            Some(i) => self.counts[i].fetch_add(1, Ordering::Relaxed),
            None => self.inf_count.fetch_add(1, Ordering::Relaxed),
        };
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Estimate the `p`-quantile (`p` in `[0, 1]`) from the bucket
    /// counts, the way Prometheus' `histogram_quantile` does: find the
    /// bucket where the cumulative count crosses `p * total`, then
    /// interpolate linearly inside it (the first bucket interpolates
    /// from zero).  Observations beyond the last bound clamp to it —
    /// a finite answer for a `+Inf` quantile is the standard convention.
    /// Returns `None` on an empty histogram or `p` outside `[0, 1]`.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if !(0.0..=1.0).contains(&p) {
            return None;
        }
        let total = self.count();
        if total == 0 {
            return None;
        }
        let rank = p * total as f64;
        let mut cum = 0u64;
        for (i, (b, c)) in self.bounds.iter().zip(&self.counts).enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                cum += n;
                continue;
            }
            if (cum + n) as f64 >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let frac = ((rank - cum as f64) / n as f64).clamp(0.0, 1.0);
                return Some(lo + (b - lo) * frac);
            }
            cum += n;
        }
        // Rank lands in the +Inf bucket: clamp to the last finite bound
        // (or, with no finite bounds at all, fall back to mean).
        match self.bounds.last() {
            Some(&b) => Some(b),
            None => Some(self.sum() / total as f64),
        }
    }

    /// Prometheus exposition lines: `name_bucket{labels,le="…"}`
    /// (cumulative), `name_sum`, `name_count`.  `labels` may be empty.
    pub fn render(&self, name: &str, labels: &str) -> String {
        let mut s = String::new();
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (b, c) in self.bounds.iter().zip(&self.counts) {
            cum += c.load(Ordering::Relaxed);
            let _ = writeln!(s, "{name}_bucket{{{labels}{sep}le=\"{b}\"}} \
                                 {cum}");
        }
        cum += self.inf_count.load(Ordering::Relaxed);
        let _ = writeln!(s, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {cum}");
        if labels.is_empty() {
            let _ = writeln!(s, "{name}_sum {}", self.sum());
            let _ = writeln!(s, "{name}_count {}", self.count());
        } else {
            let _ = writeln!(s, "{name}_sum{{{labels}}} {}", self.sum());
            let _ = writeln!(s, "{name}_count{{{labels}}} {}", self.count());
        }
        s
    }
}

/// One record per training step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    /// Host wall-clock of all real compute this step.
    pub wall_s: f64,
    /// Simulated parallel step time (slowest worker + collective).
    pub sim_s: f64,
}

/// Loss curve accumulator.
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub records: Vec<StepRecord>,
}

impl LossCurve {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, step: usize, loss: f32, wall_s: f64, sim_s: f64) {
        self.records.push(StepRecord { step, loss, wall_s, sim_s });
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `k` steps (None until k records exist).
    pub fn smoothed_loss(&self, k: usize) -> Option<f32> {
        if self.records.len() < k || k == 0 {
            return None;
        }
        let tail = &self.records[self.records.len() - k..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / k as f32)
    }

    /// First step index where the k-smoothed loss reached `target`.
    pub fn steps_to_reach(&self, target: f32, k: usize) -> Option<usize> {
        for i in k..=self.records.len() {
            let window = &self.records[i - k..i];
            let m = window.iter().map(|r| r.loss).sum::<f32>() / k as f32;
            if m <= target {
                return Some(self.records[i - 1].step);
            }
        }
        None
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,wall_s,sim_s\n");
        for r in &self.records {
            let _ = writeln!(s, "{},{},{},{}", r.step, r.loss, r.wall_s,
                             r.sim_s);
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Total simulated time.
    pub fn total_sim_s(&self) -> f64 {
        self.records.iter().map(|r| r.sim_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(losses: &[f32]) -> LossCurve {
        let mut c = LossCurve::new();
        for (i, &l) in losses.iter().enumerate() {
            c.push(i, l, 0.1, 0.2);
        }
        c
    }

    #[test]
    fn smoothing() {
        let c = curve(&[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(c.smoothed_loss(2), Some(1.5));
        assert_eq!(c.smoothed_loss(4), Some(2.5));
        assert_eq!(c.smoothed_loss(5), None);
        assert_eq!(c.last_loss(), Some(1.0));
    }

    #[test]
    fn steps_to_reach_finds_first_window() {
        let c = curve(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        // 2-window means: 4.5, 3.5, 2.5, 1.5 — target 3.0 hit at idx 3.
        assert_eq!(c.steps_to_reach(3.0, 2), Some(3));
        assert_eq!(c.steps_to_reach(0.5, 2), None);
    }

    #[test]
    fn csv_format() {
        let c = curve(&[1.0]);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert!(csv.contains("0,1,0.1,0.2"));
    }

    #[test]
    fn totals() {
        let c = curve(&[1.0, 2.0]);
        assert!((c.total_sim_s() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn counter_counts_and_renders() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(c.render("reqs", ""), "reqs 5\n");
        assert_eq!(c.render("reqs", "endpoint=\"plan\""),
                   "reqs{endpoint=\"plan\"} 5\n");
    }

    #[test]
    fn gauge_moves_both_ways_and_saturates() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec();
        assert_eq!(g.get(), 0, "dec saturates at zero");
        g.set(42);
        assert_eq!(g.render("depth", ""), "depth 42\n");
        assert_eq!(g.render("depth", "q=\"pending\""),
                   "depth{q=\"pending\"} 42\n");
    }

    #[test]
    fn histogram_buckets_cumulate() {
        let h = Histogram::new(&[1.0, 10.0]);
        for v in [0.5, 0.7, 5.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 106.2).abs() < 1e-9);
        let text = h.render("lat", "endpoint=\"plan\"");
        assert!(text.contains("lat_bucket{endpoint=\"plan\",le=\"1\"} 2"),
                "{text}");
        assert!(text.contains("lat_bucket{endpoint=\"plan\",le=\"10\"} 3"),
                "{text}");
        assert!(text.contains("lat_bucket{endpoint=\"plan\",le=\"+Inf\"} 4"),
                "{text}");
        assert!(text.contains("lat_count{endpoint=\"plan\"} 4"), "{text}");
        // Unlabelled render carries no brace block on sum/count.
        let bare = h.render("lat", "");
        assert!(bare.contains("lat_bucket{le=\"1\"} 2"), "{bare}");
        assert!(bare.contains("lat_count 4"), "{bare}");
    }

    #[test]
    fn inf_bucket_equals_count_in_every_render() {
        // The +Inf cumulative bucket, _count, and the raw counter must
        // agree no matter where observations land — including entirely
        // beyond the last bound.
        let h = Histogram::new(&[1e-3, 1.0]);
        for v in [1e-4, 0.5, 2.0, 300.0, 1e9] {
            h.observe(v);
        }
        let text = h.render("lat", "");
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 5"), "{text}");
        assert!(text.contains("lat_count 5"), "{text}");
        assert_eq!(h.count(), 5);
        let want_sum: f64 = 1e-4 + 0.5 + 2.0 + 300.0 + 1e9;
        assert!((h.sum() - want_sum).abs() < 1e-3, "{}", h.sum());
        // _sum in the rendered text is the same f64, formatted by {}.
        assert!(text.contains(&format!("lat_sum {want_sum}")), "{text}");
    }

    #[test]
    fn percentile_interpolates_within_buckets() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        // 2 obs in (0,1], 2 in (1,2], none beyond.
        for v in [0.5, 0.9, 1.5, 1.9] {
            h.observe(v);
        }
        // p50 → rank 2.0, crossing at the end of the first bucket.
        assert!((h.percentile(0.5).unwrap() - 1.0).abs() < 1e-12);
        // p75 → rank 3.0, halfway through the (1,2] bucket.
        assert!((h.percentile(0.75).unwrap() - 1.5).abs() < 1e-12);
        // p100 → upper bound of the last occupied bucket.
        assert!((h.percentile(1.0).unwrap() - 2.0).abs() < 1e-12);
        // Out-of-range p and empty histograms answer None.
        assert_eq!(h.percentile(1.5), None);
        assert_eq!(Histogram::latency().percentile(0.5), None);
    }

    #[test]
    fn percentile_clamps_overflow_to_last_bound() {
        let h = Histogram::new(&[1.0, 2.0]);
        h.observe(0.5);
        h.observe(50.0); // +Inf bucket
        // p99 lands in the +Inf bucket; answer clamps to the last finite
        // bound rather than inventing a value.
        assert!((h.percentile(0.99).unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_observe_is_thread_safe() {
        let h = Histogram::latency();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..1000 {
                        h.observe(1e-3);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert!((h.sum() - 4.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn histogram_rejects_unsorted_bounds() {
        let _ = Histogram::new(&[1.0, 0.5]);
    }
}
