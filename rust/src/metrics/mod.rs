//! Metrics: loss curves, step timing, CSV export.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// One record per training step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    /// Host wall-clock of all real compute this step.
    pub wall_s: f64,
    /// Simulated parallel step time (slowest worker + collective).
    pub sim_s: f64,
}

/// Loss curve accumulator.
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub records: Vec<StepRecord>,
}

impl LossCurve {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, step: usize, loss: f32, wall_s: f64, sim_s: f64) {
        self.records.push(StepRecord { step, loss, wall_s, sim_s });
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `k` steps (None until k records exist).
    pub fn smoothed_loss(&self, k: usize) -> Option<f32> {
        if self.records.len() < k || k == 0 {
            return None;
        }
        let tail = &self.records[self.records.len() - k..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / k as f32)
    }

    /// First step index where the k-smoothed loss reached `target`.
    pub fn steps_to_reach(&self, target: f32, k: usize) -> Option<usize> {
        for i in k..=self.records.len() {
            let window = &self.records[i - k..i];
            let m = window.iter().map(|r| r.loss).sum::<f32>() / k as f32;
            if m <= target {
                return Some(self.records[i - 1].step);
            }
        }
        None
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,wall_s,sim_s\n");
        for r in &self.records {
            let _ = writeln!(s, "{},{},{},{}", r.step, r.loss, r.wall_s,
                             r.sim_s);
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Total simulated time.
    pub fn total_sim_s(&self) -> f64 {
        self.records.iter().map(|r| r.sim_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(losses: &[f32]) -> LossCurve {
        let mut c = LossCurve::new();
        for (i, &l) in losses.iter().enumerate() {
            c.push(i, l, 0.1, 0.2);
        }
        c
    }

    #[test]
    fn smoothing() {
        let c = curve(&[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(c.smoothed_loss(2), Some(1.5));
        assert_eq!(c.smoothed_loss(4), Some(2.5));
        assert_eq!(c.smoothed_loss(5), None);
        assert_eq!(c.last_loss(), Some(1.0));
    }

    #[test]
    fn steps_to_reach_finds_first_window() {
        let c = curve(&[5.0, 4.0, 3.0, 2.0, 1.0]);
        // 2-window means: 4.5, 3.5, 2.5, 1.5 — target 3.0 hit at idx 3.
        assert_eq!(c.steps_to_reach(3.0, 2), Some(3));
        assert_eq!(c.steps_to_reach(0.5, 2), None);
    }

    #[test]
    fn csv_format() {
        let c = curve(&[1.0]);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert!(csv.contains("0,1,0.1,0.2"));
    }

    #[test]
    fn totals() {
        let c = curve(&[1.0, 2.0]);
        assert!((c.total_sim_s() - 0.4).abs() < 1e-12);
    }
}
