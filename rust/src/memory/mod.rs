//! Per-device memory footprint model — the feasibility layer the paper's
//! projections assume away.
//!
//! The hybrid-vs-DP curves of §4 implicitly assume every candidate fits on
//! the device, but the reason model parallelism exists at all is that
//! weights, gradients, optimizer state and activations overflow a single
//! GPU (the paper's BigLSTM needed the 32 GB V100, §4.1).  PaSE (Elango
//! 2024) and the hybrid-ConvNet Oracle (Kahira et al. 2021) both show that
//! memory feasibility is what actually prunes the strategy space at scale.
//!
//! This module models the resident footprint of one worker:
//!
//! * **weights** W — per-op parameter bytes (from the DFG's M(k) minus the
//!   activation share);
//! * **gradients** — one more W (f32 accumulation);
//! * **optimizer state** — `W × multiplier` ([`Optimizer::Sgd`] 0,
//!   [`Optimizer::Momentum`] 1, [`Optimizer::Adam`] 2);
//! * **activations** — per-op output bytes (already scaled by the
//!   profile's mini-batch) times [`MemoryModel::act_factor`], the stash of
//!   backward-pass intermediates kept alive beyond the raw outputs;
//! * **GPipe stashing** — a pipeline stage holds activations for every
//!   in-flight micro-batch (all `m` of them under the GPipe schedule), so
//!   the stash is the *full mini-batch* stage activation plus the stage
//!   input boundary;
//! * **recompute** ([`MemoryModel::recompute`]) — gradient checkpointing:
//!   only checkpoints (raw op outputs / stage boundaries) stay resident
//!   and intermediates are recomputed during backward, trading footprint
//!   for one extra forward pass
//!   ([`MemoryModel::time_factor`] ≈ 4/3 of the fwd+bwd step);
//! * **ZeRO sharding** ([`MemoryModel::zero`], [`zero_sharded`]) —
//!   optimizer state / gradients / weights partitioned across the DP
//!   ranks (ZeRO-1/2/3, FSDP), which makes DP feasibility *N-dependent*:
//!   the per-replica footprint shrinks as the data-parallel group grows,
//!   at the price of extra allgather traffic on the exchange
//!   ([`ZeroMode::allgather_volume_factor`]).
//!
//! Estimators mirror the planner's three candidate layouts:
//! [`single_device`] (DP replicas and the M = 1 baseline), [`placed`]
//! (DLPlacer assignments) and [`pipelined`] (GPipe stage partitions).  The
//! planner compares the peak-device total against the topology's
//! `Mem(n)` ([`crate::cluster::HwNode::mem_capacity`]) and marks
//! candidates [`Feasibility::Infeasible`] instead of scoring them.

use anyhow::{bail, Result};

use crate::dfg::Op;
use crate::models::ModelProfile;
use crate::util::json::Json;

/// Optimizer family — sets the per-parameter state multiplier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    /// Plain SGD: no state beyond weights + gradients.
    Sgd,
    /// SGD with momentum: one extra weight-sized buffer.
    Momentum,
    /// Adam/AdamW: first + second moment, two extra buffers.
    Adam,
}

impl Optimizer {
    /// Extra weight-sized state buffers this optimizer keeps resident.
    pub fn state_multiplier(self) -> f64 {
        match self {
            Optimizer::Sgd => 0.0,
            Optimizer::Momentum => 1.0,
            Optimizer::Adam => 2.0,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Optimizer::Sgd => "sgd",
            Optimizer::Momentum => "momentum",
            Optimizer::Adam => "adam",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => Optimizer::Sgd,
            "momentum" | "sgd-momentum" => Optimizer::Momentum,
            "adam" | "adamw" => Optimizer::Adam,
            other => bail!("unknown optimizer '{other}' \
                            (known: sgd, momentum, adam)"),
        })
    }
}

/// ZeRO / FSDP sharding stage — which training-state components are
/// partitioned across the data-parallel ranks instead of replicated.
///
/// Each stage subsumes the previous one (ZeRO-2 shards gradients *and*
/// optimizer state; ZeRO-3 shards all three).  Sharding trades footprint
/// for exchange traffic: the sharded components must be re-materialised
/// on demand, and [`ZeroMode::allgather_volume_factor`] is the extra
/// weight-sized allgather volume the gradient exchange is charged per
/// step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ZeroMode {
    /// No sharding: every DP rank replicates the full training state
    /// (the paper's assumption — feasibility independent of N).
    Off,
    /// ZeRO-1: optimizer state sharded across DP ranks.
    Optimizer,
    /// ZeRO-2: optimizer state + gradient buffers sharded.
    Gradients,
    /// ZeRO-3 / FSDP: optimizer state + gradients + weights sharded.
    Weights,
}

impl ZeroMode {
    pub fn as_str(self) -> &'static str {
        match self {
            ZeroMode::Off => "off",
            ZeroMode::Optimizer => "optimizer",
            ZeroMode::Gradients => "gradients",
            ZeroMode::Weights => "weights",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" | "none" => ZeroMode::Off,
            "optimizer" | "os" | "zero1" | "zero-1" | "stage1" => {
                ZeroMode::Optimizer
            }
            "gradients" | "grads" | "zero2" | "zero-2" | "stage2" => {
                ZeroMode::Gradients
            }
            "weights" | "params" | "zero3" | "zero-3" | "stage3"
            | "fsdp" | "full" => ZeroMode::Weights,
            other => bail!("unknown zero mode '{other}' \
                            (known: off, optimizer, gradients, weights)"),
        })
    }

    /// Does this stage shard the optimizer state?  (All stages ≥ ZeRO-1.)
    pub fn shards_optimizer(self) -> bool {
        self >= ZeroMode::Optimizer
    }

    /// Does this stage shard the gradient buffers?  (ZeRO-2 and up.)
    pub fn shards_gradients(self) -> bool {
        self >= ZeroMode::Gradients
    }

    /// Does this stage shard the weights themselves?  (ZeRO-3 / FSDP.)
    pub fn shards_weights(self) -> bool {
        self == ZeroMode::Weights
    }

    /// Extra per-step exchange volume, in units of the model's weight
    /// bytes, charged on top of the gradient all-reduce: ZeRO-1/2 pay
    /// one weight-sized allgather (the updated parameter shards),
    /// ZeRO-3 pays two (parameters re-gathered for forward *and*
    /// backward).
    pub fn allgather_volume_factor(self) -> f64 {
        match self {
            ZeroMode::Off => 0.0,
            ZeroMode::Optimizer | ZeroMode::Gradients => 1.0,
            ZeroMode::Weights => 2.0,
        }
    }
}

/// The accounting knobs of the footprint model.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryModel {
    pub optimizer: Optimizer,
    /// Gradient checkpointing: keep only checkpoints resident and
    /// recompute intermediates during backward (costs
    /// [`MemoryModel::time_factor`] extra step time).
    pub recompute: bool,
    /// Backward-pass stash per op ≈ `act_factor ×` its output bytes (the
    /// intermediates kept alive beyond the raw output; 1.0 = outputs
    /// only).  Recompute drops the stash back to the raw outputs.
    pub act_factor: f64,
    /// Fixed per-device reserve: CUDA context, cuDNN workspaces,
    /// allocator fragmentation.
    pub reserved_bytes: f64,
    /// Step-time inflation of recompute, as a fraction of the fwd+bwd
    /// step.  One extra forward ≈ 1/3 of a 3×-forward training step.
    pub recompute_overhead: f64,
    /// ZeRO / FSDP sharding stage applied across the DP ranks (see
    /// [`zero_sharded`]).  `Off` keeps the paper's replicated-state
    /// accounting bit-for-bit.
    pub zero: ZeroMode,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel {
            optimizer: Optimizer::Adam,
            recompute: false,
            act_factor: 2.0,
            reserved_bytes: 0.75e9,
            recompute_overhead: 1.0 / 3.0,
            zero: ZeroMode::Off,
        }
    }
}

impl MemoryModel {
    /// Multiplier on the per-worker step time (1.0 unless recompute).
    pub fn time_factor(&self) -> f64 {
        if self.recompute {
            1.0 + self.recompute_overhead
        } else {
            1.0
        }
    }

    /// Serialise the accounting knobs (the `memory` object of the
    /// service's `POST /plan` / `POST /sweep` wire format).
    pub fn to_json(&self) -> Json {
        crate::planner::jobj(vec![
            ("optimizer", Json::Str(self.optimizer.as_str().into())),
            ("recompute", Json::Bool(self.recompute)),
            ("act_factor", Json::Num(self.act_factor)),
            ("reserved_bytes", Json::Num(self.reserved_bytes)),
            ("recompute_overhead", Json::Num(self.recompute_overhead)),
            ("zero", Json::Str(self.zero.as_str().into())),
        ])
    }

    /// Parse the wire-format `memory` object.  Missing keys take the
    /// [`MemoryModel::default`] values; unknown keys are rejected so a
    /// typoed knob cannot silently fall back to a default.
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = MemoryModel::default();
        const KEYS: [&str; 6] = ["optimizer", "recompute", "act_factor",
                                 "reserved_bytes", "recompute_overhead",
                                 "zero"];
        for key in j.as_obj()?.keys() {
            if !KEYS.contains(&key.as_str()) {
                bail!("unknown memory key '{key}' (known: {})",
                      KEYS.join(", "));
            }
        }
        Ok(MemoryModel {
            optimizer: match j.opt("optimizer") {
                None | Some(Json::Null) => d.optimizer,
                Some(v) => Optimizer::parse(v.as_str()?)?,
            },
            recompute: match j.opt("recompute") {
                None | Some(Json::Null) => d.recompute,
                Some(Json::Bool(b)) => *b,
                Some(other) => {
                    bail!("recompute must be a bool, got {other:?}")
                }
            },
            act_factor: match j.opt("act_factor") {
                None | Some(Json::Null) => d.act_factor,
                Some(v) => v.as_f64()?,
            },
            reserved_bytes: match j.opt("reserved_bytes") {
                None | Some(Json::Null) => d.reserved_bytes,
                Some(v) => v.as_f64()?,
            },
            recompute_overhead: match j.opt("recompute_overhead") {
                None | Some(Json::Null) => d.recompute_overhead,
                Some(v) => v.as_f64()?,
            },
            zero: match j.opt("zero") {
                None | Some(Json::Null) => d.zero,
                Some(v) => ZeroMode::parse(v.as_str()?)?,
            },
        })
    }
}

/// Peak per-device footprint of one worker, by component.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryEstimate {
    /// Parameter bytes resident on the peak device.
    pub weight_bytes: f64,
    /// Gradient accumulation buffers (= weights, f32).
    pub grad_bytes: f64,
    /// Optimizer state (`weights × multiplier`).
    pub optimizer_bytes: f64,
    /// Activation working set + backward/pipeline stash.
    pub activation_bytes: f64,
    /// Fixed per-device reserve.
    pub reserved_bytes: f64,
    /// Peak per-device total — what feasibility compares against Mem(n).
    pub total_bytes: f64,
    /// Whether this estimate assumed gradient checkpointing.
    pub recompute: bool,
}

impl MemoryEstimate {
    fn from_parts(model: &MemoryModel, weights: f64, activations: f64)
                  -> Self {
        let grads = weights;
        let opt = weights * model.optimizer.state_multiplier();
        let total = weights + grads + opt + activations
            + model.reserved_bytes;
        MemoryEstimate {
            weight_bytes: weights,
            grad_bytes: grads,
            optimizer_bytes: opt,
            activation_bytes: activations,
            reserved_bytes: model.reserved_bytes,
            total_bytes: total,
            recompute: model.recompute,
        }
    }

    /// Does the peak device fit in `available_bytes` of device memory?
    pub fn fits(&self, available_bytes: f64) -> bool {
        self.total_bytes <= available_bytes
    }

    pub fn to_json(&self) -> Json {
        crate::planner::jobj(vec![
            ("weight_bytes", Json::Num(self.weight_bytes)),
            ("grad_bytes", Json::Num(self.grad_bytes)),
            ("optimizer_bytes", Json::Num(self.optimizer_bytes)),
            ("activation_bytes", Json::Num(self.activation_bytes)),
            ("reserved_bytes", Json::Num(self.reserved_bytes)),
            ("total_bytes", Json::Num(self.total_bytes)),
            ("recompute", Json::Bool(self.recompute)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(MemoryEstimate {
            weight_bytes: j.get("weight_bytes")?.as_f64()?,
            grad_bytes: j.get("grad_bytes")?.as_f64()?,
            optimizer_bytes: j.get("optimizer_bytes")?.as_f64()?,
            activation_bytes: j.get("activation_bytes")?.as_f64()?,
            reserved_bytes: j.get("reserved_bytes")?.as_f64()?,
            total_bytes: j.get("total_bytes")?.as_f64()?,
            recompute: matches!(j.get("recompute")?, Json::Bool(true)),
        })
    }
}

/// Whether a candidate fits the device, and by how much it misses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Feasibility {
    Feasible,
    /// Peak device needs `required_bytes` but only `available_bytes` of
    /// Mem(n) exist.
    Infeasible { required_bytes: f64, available_bytes: f64 },
}

impl Feasibility {
    /// Classify an estimate against a capacity.
    pub fn check(est: &MemoryEstimate, available_bytes: f64) -> Self {
        if est.fits(available_bytes) {
            Feasibility::Feasible
        } else {
            Feasibility::Infeasible {
                required_bytes: est.total_bytes,
                available_bytes,
            }
        }
    }

    pub fn is_feasible(&self) -> bool {
        matches!(self, Feasibility::Feasible)
    }

    pub fn to_json(&self) -> Json {
        match *self {
            Feasibility::Feasible => crate::planner::jobj(vec![
                ("kind", Json::Str("feasible".into())),
            ]),
            Feasibility::Infeasible { required_bytes, available_bytes } => {
                crate::planner::jobj(vec![
                    ("kind", Json::Str("infeasible".into())),
                    ("required_bytes", Json::Num(required_bytes)),
                    ("available_bytes", Json::Num(available_bytes)),
                ])
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(match j.get("kind")?.as_str()? {
            "feasible" => Feasibility::Feasible,
            "infeasible" => Feasibility::Infeasible {
                required_bytes: j.get("required_bytes")?.as_f64()?,
                available_bytes: j.get("available_bytes")?.as_f64()?,
            },
            other => bail!("unknown feasibility kind '{other}'"),
        })
    }
}

/// Parameter bytes of an op: the resident M(k) minus its activation
/// output share (the DFG builders fold both into `mem_bytes`).
pub fn op_weight_bytes(op: &Op) -> f64 {
    (op.mem_bytes - op.out_bytes).max(0.0)
}

/// Activation output bytes of an op (already mini-batch-scaled by the
/// profile builder).
pub fn op_activation_bytes(op: &Op) -> f64 {
    op.out_bytes
}

/// Activation residency of a set of ops outside a pipeline: raw outputs ×
/// the backward-stash factor, or outputs only under recompute.
fn act_resident(model: &MemoryModel, raw_out: f64) -> f64 {
    if model.recompute {
        raw_out
    } else {
        raw_out * model.act_factor
    }
}

/// Footprint of the whole model resident on one device — the M = 1
/// baseline, and every replica of an N-way DP worker (per-device
/// mini-batch is constant as DP scales, so DP feasibility is independent
/// of N).
pub fn single_device(prof: &ModelProfile, model: &MemoryModel)
                     -> MemoryEstimate {
    let weights: f64 = prof.dfg.ops.iter().map(op_weight_bytes).sum();
    let raw_out: f64 = prof.dfg.ops.iter().map(op_activation_bytes).sum();
    MemoryEstimate::from_parts(model, weights, act_resident(model, raw_out))
}

/// Re-account a per-replica footprint under ZeRO sharding across
/// `dp_ranks` data-parallel ranks: the components
/// [`MemoryModel::zero`] shards are divided by the rank count and the
/// total is rebuilt.  Identity when the mode is [`ZeroMode::Off`] or the
/// group has a single rank — so every pre-ZeRO number in the repo is
/// reproduced bit-for-bit.  Activations are *never* sharded (each rank
/// still runs its full per-device mini-batch), which is why ZeRO alone
/// cannot rescue an activation-bound model.
///
/// ```
/// use hybridpar::memory::{self, MemoryModel, ZeroMode};
/// use hybridpar::models;
///
/// let prof = models::transformer_70b(4);
/// let mm = MemoryModel { zero: ZeroMode::Weights, ..Default::default() };
/// let whole = memory::single_device(&prof, &mm);
/// // ZeRO-3 over 64 ranks shards weights, gradients and optimizer state…
/// let sharded = memory::zero_sharded(&whole, &mm, 64);
/// assert!(sharded.weight_bytes < whole.weight_bytes / 63.0);
/// assert!(sharded.total_bytes < whole.total_bytes);
/// // …but the activations stay whole: ZeRO alone still misses 80 GB.
/// assert_eq!(sharded.activation_bytes, whole.activation_bytes);
/// assert!(!sharded.fits(80e9));
/// ```
pub fn zero_sharded(est: &MemoryEstimate, model: &MemoryModel,
                    dp_ranks: usize) -> MemoryEstimate {
    if model.zero == ZeroMode::Off || dp_ranks <= 1 {
        return *est;
    }
    let n = dp_ranks as f64;
    let w = if model.zero.shards_weights() {
        est.weight_bytes / n
    } else {
        est.weight_bytes
    };
    let g = if model.zero.shards_gradients() {
        est.grad_bytes / n
    } else {
        est.grad_bytes
    };
    let o = if model.zero.shards_optimizer() {
        est.optimizer_bytes / n
    } else {
        est.optimizer_bytes
    };
    MemoryEstimate {
        weight_bytes: w,
        grad_bytes: g,
        optimizer_bytes: o,
        total_bytes: w + g + o + est.activation_bytes + est.reserved_bytes,
        ..*est
    }
}

/// Footprint of one rank of a `degree`-way Megatron-style tensor-parallel
/// group: every op's weights *and* activations are split 1/degree across
/// the group (each rank computes a feature shard of every layer), unlike
/// a pipeline stage which concentrates whole layers.  The M = 1 case is
/// byte-identical to [`single_device`].
pub fn tensor_sharded(prof: &ModelProfile, model: &MemoryModel,
                      degree: usize) -> MemoryEstimate {
    let d = degree.max(1) as f64;
    let weights: f64 = prof.dfg.ops.iter().map(op_weight_bytes).sum();
    let raw_out: f64 = prof.dfg.ops.iter().map(op_activation_bytes).sum();
    MemoryEstimate::from_parts(model, weights / d,
                               act_resident(model, raw_out / d))
}

/// Footprint of a DLPlacer placement: per-device weight/activation sums
/// over the op → device `assignment`, peak device reported.
pub fn placed(prof: &ModelProfile, model: &MemoryModel,
              assignment: &[usize]) -> MemoryEstimate {
    let n_dev = assignment.iter().copied().max().map_or(1, |d| d + 1);
    let mut w = vec![0.0f64; n_dev];
    let mut a = vec![0.0f64; n_dev];
    for (op, &d) in assignment.iter().enumerate().take(prof.dfg.n_ops()) {
        w[d] += op_weight_bytes(&prof.dfg.ops[op]);
        a[d] += op_activation_bytes(&prof.dfg.ops[op]);
    }
    (0..n_dev)
        .map(|d| {
            MemoryEstimate::from_parts(model, w[d],
                                       act_resident(model, a[d]))
        })
        .max_by(|x, y| x.total_bytes.partial_cmp(&y.total_bytes).unwrap())
        .unwrap_or_else(|| MemoryEstimate::from_parts(model, 0.0, 0.0))
}

/// Footprint of a GPipe pipeline: stages are contiguous topo-order slices
/// `bounds[s]..bounds[s+1]`.  Each stage stashes activations for every
/// in-flight micro-batch — all `m` under the GPipe schedule, i.e. the
/// full mini-batch stage activation plus the stage input boundary.  With
/// recompute, only the boundary checkpoints stay stashed and a single
/// micro-batch's working set is resident at a time.
pub fn pipelined(prof: &ModelProfile, model: &MemoryModel,
                 bounds: &[usize], microbatches: usize)
                 -> Result<MemoryEstimate> {
    if bounds.len() < 2 {
        bail!("pipeline bounds need at least one stage: {bounds:?}");
    }
    let order = prof.dfg.topo_order()?;
    if *bounds.last().unwrap() != order.len() {
        bail!("pipeline bounds {bounds:?} do not cover {} ops",
              order.len());
    }
    let m = microbatches.max(1) as f64;
    let mut pos = vec![0usize; prof.dfg.n_ops()];
    for (p, &v) in order.iter().enumerate() {
        pos[v] = p;
    }
    let mut peak: Option<MemoryEstimate> = None;
    for s in 0..bounds.len() - 1 {
        let ops = &order[bounds[s]..bounds[s + 1]];
        let w: f64 =
            ops.iter().map(|&o| op_weight_bytes(&prof.dfg.ops[o])).sum();
        let raw_out: f64 = ops
            .iter()
            .map(|&o| op_activation_bytes(&prof.dfg.ops[o]))
            .sum();
        // Input boundary bytes stashed per micro-batch; × m in flight.
        let cut_in: f64 = if s == 0 {
            0.0
        } else {
            let b = bounds[s];
            prof.dfg
                .edges
                .iter()
                .filter(|e| pos[e.src] < b && pos[e.dst] >= b)
                .map(|e| e.bytes)
                .sum()
        };
        let act = if model.recompute {
            // Checkpoints (boundary, all m micro-batches) + one
            // micro-batch's working intermediates.
            cut_in + raw_out * model.act_factor / m
        } else {
            // GPipe stash: every micro-batch's activations stay alive
            // until its backward — the full mini-batch worth.
            cut_in + raw_out * model.act_factor
        };
        let est = MemoryEstimate::from_parts(model, w, act);
        if peak.map_or(true, |p| est.total_bytes > p.total_bytes) {
            peak = Some(est);
        }
    }
    Ok(peak.expect("at least one stage"))
}

/// Footprint of a layer-wise mixed assignment: the solver
/// ([`crate::layerwise::solve`]) accumulates per group-device
/// (weight bytes, raw activation bytes) pairs from each op's
/// configuration — full on replicas, 1/M shards under tensor splits,
/// single-device under stage placement — and this applies the same
/// backward-stash / recompute accounting as the fixed-candidate
/// estimators, reporting the peak device.
pub fn layerwise(model: &MemoryModel, per_device: &[(f64, f64)])
                 -> MemoryEstimate {
    per_device
        .iter()
        .map(|&(w, raw)| {
            MemoryEstimate::from_parts(model, w, act_resident(model, raw))
        })
        .max_by(|x, y| x.total_bytes.partial_cmp(&y.total_bytes).unwrap())
        .unwrap_or_else(|| MemoryEstimate::from_parts(model, 0.0, 0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn optimizer_parse_round_trip() {
        for o in [Optimizer::Sgd, Optimizer::Momentum, Optimizer::Adam] {
            assert_eq!(Optimizer::parse(o.as_str()).unwrap(), o);
        }
        assert_eq!(Optimizer::parse("adamw").unwrap(), Optimizer::Adam);
        assert!(Optimizer::parse("lion").is_err());
    }

    #[test]
    fn optimizer_state_ordering() {
        // sgd ⊂ momentum ⊂ adam on the same model.
        let prof = models::gnmt(128);
        let mut totals = Vec::new();
        for opt in [Optimizer::Sgd, Optimizer::Momentum, Optimizer::Adam] {
            let m = MemoryModel { optimizer: opt, ..Default::default() };
            totals.push(single_device(&prof, &m).total_bytes);
        }
        assert!(totals[0] < totals[1] && totals[1] < totals[2],
                "state multipliers must order totals: {totals:?}");
        // Adam adds exactly 2× the weights over SGD.
        let w = single_device(&prof, &MemoryModel::default()).weight_bytes;
        assert!((totals[2] - totals[0] - 2.0 * w).abs() < 1.0);
    }

    #[test]
    fn activations_scale_with_batch() {
        let m = MemoryModel::default();
        let small = single_device(&models::gnmt(32), &m);
        let large = single_device(&models::gnmt(256), &m);
        assert!(large.activation_bytes > 7.0 * small.activation_bytes,
                "activations must scale ~linearly with batch: {} vs {}",
                large.activation_bytes, small.activation_bytes);
        assert!((large.weight_bytes - small.weight_bytes).abs()
                    < 1e-6 * small.weight_bytes,
                "weights must not scale with batch");
    }

    #[test]
    fn recompute_trades_memory_for_time() {
        let full = MemoryModel::default();
        let rc = MemoryModel { recompute: true, ..Default::default() };
        let prof = models::inception_v3(64);
        let f = single_device(&prof, &full);
        let r = single_device(&prof, &rc);
        assert!(r.activation_bytes < f.activation_bytes);
        assert!(r.total_bytes < f.total_bytes);
        assert!(r.recompute && !f.recompute);
        assert!((full.time_factor() - 1.0).abs() < 1e-12);
        assert!(rc.time_factor() > 1.30 && rc.time_factor() < 1.37,
                "one extra forward ≈ 4/3: {}", rc.time_factor());
    }

    #[test]
    fn biglstm_needs_more_than_16gb_under_adam() {
        // The paper's §4.1 motivation: BigLSTM needed the 32 GB V100.
        let prof = models::biglstm(64);
        let est = single_device(&prof, &MemoryModel::default());
        assert!(est.total_bytes > 16e9,
                "BigLSTM + Adam must overflow a 16 GB part: {:.1} GB",
                est.total_bytes / 1e9);
        assert!(est.total_bytes < 32e9,
                "…but fit the 32 GB V100: {:.1} GB",
                est.total_bytes / 1e9);
        assert!(!est.fits(16e9));
        assert!(est.fits(32e9) && est.fits(80e9));
    }

    #[test]
    fn pipeline_stages_shrink_the_peak() {
        // Splitting BigLSTM across 2 stages must reduce peak weights (the
        // 3.25 GB softmax projection no longer shares a device with the
        // LSTM stacks).
        let prof = models::biglstm(64);
        let m = MemoryModel::default();
        let whole = single_device(&prof, &m);
        let n = prof.dfg.n_ops();
        // Balanced-ish manual split: first half / second half.
        let est = pipelined(&prof, &m, &[0, n / 2, n], 4).unwrap();
        assert!(est.weight_bytes < whole.weight_bytes);
        assert!(est.total_bytes < whole.total_bytes);
        assert!(est.fits(16e9),
                "2-stage BigLSTM must fit 16 GB: {:.1} GB",
                est.total_bytes / 1e9);
    }

    #[test]
    fn pipelined_recompute_reduces_stash() {
        let prof = models::inception_v3(64);
        let full = MemoryModel::default();
        let rc = MemoryModel { recompute: true, ..Default::default() };
        let n = prof.dfg.n_ops();
        let bounds = [0, n / 2, n];
        let f = pipelined(&prof, &full, &bounds, 8).unwrap();
        let r = pipelined(&prof, &rc, &bounds, 8).unwrap();
        assert!(r.activation_bytes < f.activation_bytes,
                "recompute must shrink the GPipe stash: {} vs {}",
                r.activation_bytes, f.activation_bytes);
    }

    #[test]
    fn placed_peaks_on_the_heavy_device() {
        let prof = models::gnmt(128);
        let m = MemoryModel::default();
        let n = prof.dfg.n_ops();
        // Everything on device 0 ≡ single device.
        let all0 = placed(&prof, &m, &vec![0; n]);
        let single = single_device(&prof, &m);
        assert!((all0.total_bytes - single.total_bytes).abs() < 1.0);
        // An even split strictly reduces the peak.
        let alt: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let split = placed(&prof, &m, &alt);
        assert!(split.total_bytes < single.total_bytes);
    }

    #[test]
    fn layerwise_peaks_on_the_heavy_device_and_matches_single() {
        let m = MemoryModel::default();
        let prof = models::gnmt(128);
        let w: f64 = prof.dfg.ops.iter().map(op_weight_bytes).sum();
        let a: f64 = prof.dfg.ops.iter().map(op_activation_bytes).sum();
        // Everything replicated on one device ≡ the single-device model.
        let rep = layerwise(&m, &[(w, a)]);
        let single = single_device(&prof, &m);
        assert!((rep.total_bytes - single.total_bytes).abs() < 1.0);
        // The peak device wins, not the sum.
        let uneven = layerwise(&m, &[(w, a), (w / 4.0, a / 4.0)]);
        assert!((uneven.total_bytes - single.total_bytes).abs() < 1.0);
        // Empty group degenerates to the reserve-only estimate.
        let empty = layerwise(&m, &[]);
        assert!((empty.total_bytes - m.reserved_bytes).abs() < 1.0);
    }

    #[test]
    fn bad_pipeline_bounds_rejected() {
        let prof = models::gnmt(128);
        let m = MemoryModel::default();
        assert!(pipelined(&prof, &m, &[0], 2).is_err());
        assert!(pipelined(&prof, &m, &[0, 3], 2).is_err(), "short cover");
    }

    #[test]
    fn feasibility_check_and_json() {
        let prof = models::biglstm(64);
        let est = single_device(&prof, &MemoryModel::default());
        let ok = Feasibility::check(&est, 80e9);
        let bad = Feasibility::check(&est, 16e9);
        assert!(ok.is_feasible());
        assert!(!bad.is_feasible());
        for f in [ok, bad] {
            let j = f.to_json().to_string();
            let back = Feasibility::from_json(
                &Json::parse(&j).unwrap()).unwrap();
            assert_eq!(f, back);
        }
        let j = est.to_json().to_string();
        let back =
            MemoryEstimate::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(est, back);
    }

    #[test]
    fn memory_model_json_round_trip() {
        let m = MemoryModel {
            optimizer: Optimizer::Momentum,
            recompute: true,
            act_factor: 1.5,
            reserved_bytes: 1e9,
            recompute_overhead: 0.25,
            zero: ZeroMode::Gradients,
        };
        let j = m.to_json().to_string();
        let back = MemoryModel::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
        // Missing keys default; unknown keys are rejected.
        let partial = MemoryModel::from_json(
            &Json::parse(r#"{"optimizer":"sgd"}"#).unwrap()).unwrap();
        assert_eq!(partial.optimizer, Optimizer::Sgd);
        assert_eq!(partial.act_factor, MemoryModel::default().act_factor);
        assert_eq!(partial.zero, ZeroMode::Off);
        let z = MemoryModel::from_json(
            &Json::parse(r#"{"zero":"zero3"}"#).unwrap()).unwrap();
        assert_eq!(z.zero, ZeroMode::Weights);
        assert!(MemoryModel::from_json(
            &Json::parse(r#"{"zero":"zero4"}"#).unwrap()).is_err());
        assert!(MemoryModel::from_json(
            &Json::parse(r#"{"optimiser":"sgd"}"#).unwrap()).is_err());
        assert!(MemoryModel::from_json(
            &Json::parse(r#"{"optimizer":"rmsprop"}"#).unwrap()).is_err());
        // A mistyped recompute must error, not silently mean "off".
        assert!(MemoryModel::from_json(
            &Json::parse(r#"{"recompute":"true"}"#).unwrap()).is_err());
    }

    #[test]
    fn zero_mode_parse_and_stage_nesting() {
        for z in [ZeroMode::Off, ZeroMode::Optimizer, ZeroMode::Gradients,
                  ZeroMode::Weights] {
            assert_eq!(ZeroMode::parse(z.as_str()).unwrap(), z);
        }
        assert_eq!(ZeroMode::parse("zero1").unwrap(), ZeroMode::Optimizer);
        assert_eq!(ZeroMode::parse("fsdp").unwrap(), ZeroMode::Weights);
        assert!(ZeroMode::parse("zero0").is_err());
        // Each stage subsumes the previous one.
        assert!(!ZeroMode::Off.shards_optimizer());
        assert!(ZeroMode::Optimizer.shards_optimizer()
                && !ZeroMode::Optimizer.shards_gradients());
        assert!(ZeroMode::Gradients.shards_gradients()
                && !ZeroMode::Gradients.shards_weights());
        assert!(ZeroMode::Weights.shards_weights()
                && ZeroMode::Weights.shards_gradients());
        // Allgather charge grows with the stage, zero when off.
        assert_eq!(ZeroMode::Off.allgather_volume_factor(), 0.0);
        assert_eq!(ZeroMode::Weights.allgather_volume_factor(), 2.0);
    }

    #[test]
    fn zero_sharding_divides_state_but_not_activations() {
        let prof = models::biglstm(64);
        let mm = MemoryModel {
            zero: ZeroMode::Weights,
            ..Default::default()
        };
        let whole = single_device(&prof, &mm);
        let sharded = zero_sharded(&whole, &mm, 8);
        assert!((sharded.weight_bytes - whole.weight_bytes / 8.0).abs()
                    < 1.0);
        assert!((sharded.grad_bytes - whole.grad_bytes / 8.0).abs() < 1.0);
        assert!((sharded.optimizer_bytes - whole.optimizer_bytes / 8.0)
                    .abs() < 1.0);
        assert_eq!(sharded.activation_bytes, whole.activation_bytes);
        assert_eq!(sharded.reserved_bytes, whole.reserved_bytes);
        assert!(sharded.total_bytes < whole.total_bytes);
        // ZeRO-1 shards only the optimizer state.
        let z1 = MemoryModel {
            zero: ZeroMode::Optimizer,
            ..Default::default()
        };
        let s1 = zero_sharded(&single_device(&prof, &z1), &z1, 8);
        assert_eq!(s1.weight_bytes, whole.weight_bytes);
        assert_eq!(s1.grad_bytes, whole.grad_bytes);
        assert!((s1.optimizer_bytes - whole.optimizer_bytes / 8.0).abs()
                    < 1.0);
        // Identity when off or single-rank — bit-for-bit.
        let off = MemoryModel::default();
        let base = single_device(&prof, &off);
        assert_eq!(zero_sharded(&base, &off, 8), base);
        assert_eq!(zero_sharded(&whole, &mm, 1), whole);
    }

    #[test]
    fn tensor_sharding_splits_weights_and_activations() {
        let prof = models::gnmt(128);
        let mm = MemoryModel::default();
        let whole = single_device(&prof, &mm);
        // Degree 1 is byte-identical to the single-device estimate.
        assert_eq!(tensor_sharded(&prof, &mm, 1), whole);
        let t8 = tensor_sharded(&prof, &mm, 8);
        assert!((t8.weight_bytes - whole.weight_bytes / 8.0).abs() < 1.0);
        assert!((t8.activation_bytes - whole.activation_bytes / 8.0).abs()
                    < 1.0);
        // Unlike ZeRO, TP shrinks the activation term — the combination
        // is what unlocks activation-bound models.
        assert!(t8.activation_bytes < whole.activation_bytes);
    }
}
