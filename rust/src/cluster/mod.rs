//! Hardware graph (paper §6, "Inputs: Hardware Graph").
//!
//! A system is compute nodes `N` (GPUs/TPUs) and router nodes `R`
//! (NVSwitch / PCIe switches / NICs) connected by bidirectional physical
//! links `L` with bandwidth B(l) and latency L(l).  Topology builders cover
//! the paper's testbed (DGX-1 NVLink mesh) and the multi-node scale-out
//! systems its projections assume.

use anyhow::{bail, Result};

/// Kind of physical interconnect; sets default bandwidth/latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// NVLink 2.0 per-direction (DGX-1 era): 25 GB/s, ~1.3 µs.
    NvLink,
    /// GPU ↔ NVSwitch fabric port (DGX-2 era): all six NVLink 2.0 bricks
    /// ganged through the switch, 150 GB/s per direction, ~1 µs.
    NvSwitch,
    /// PCIe 3.0 x16: 12 GB/s effective, ~2 µs.
    Pcie,
    /// 100 Gb InfiniBand inter-node: 12 GB/s, ~2.5 µs.
    Infiniband,
    /// 25 GbE cloud-instance networking: ~3.1 GB/s, ~20 µs (TCP stack).
    Ethernet25,
    /// Custom.
    Custom,
}

impl LinkKind {
    pub fn bandwidth(self) -> f64 {
        match self {
            LinkKind::NvLink => 25e9,
            LinkKind::NvSwitch => 150e9,
            LinkKind::Pcie => 12e9,
            LinkKind::Infiniband => 12e9,
            LinkKind::Ethernet25 => 3.125e9,
            LinkKind::Custom => 10e9,
        }
    }

    pub fn latency(self) -> f64 {
        match self {
            LinkKind::NvLink => 1.3e-6,
            LinkKind::NvSwitch => 1.0e-6,
            LinkKind::Pcie => 2.0e-6,
            LinkKind::Infiniband => 2.5e-6,
            LinkKind::Ethernet25 => 20.0e-6,
            LinkKind::Custom => 2.0e-6,
        }
    }
}

/// A node in the hardware graph: a compute device or a router.
#[derive(Clone, Debug)]
pub struct HwNode {
    pub name: String,
    pub is_compute: bool,
    /// Sustained FLOP/s for compute nodes (V100 fp32 ≈ 14 TFLOP/s, with
    /// tensor cores ≈ 112 TFLOP/s on GEMM; we use a blended sustained rate).
    pub flops_per_sec: f64,
    /// Device memory capacity Mem(n), bytes.
    pub mem_capacity: f64,
    /// Chassis (physical machine) this node sits in.  Single-box builders
    /// leave everything on node 0; multi-node builders assign each GPU and
    /// its NIC to the chassis index and park backbone switches on their
    /// own pseudo-node, so any link touching one reads as inter-node.
    pub node: usize,
}

/// Physical link `l ∈ L` (bidirectional).
#[derive(Clone, Copy, Debug)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    pub bandwidth: f64,
    pub latency: f64,
}

/// The hardware graph.
#[derive(Clone, Debug, Default)]
pub struct HwGraph {
    pub name: String,
    pub nodes: Vec<HwNode>,
    pub links: Vec<Link>,
}

/// V100-16GB-like device profile used by the builders.
pub const V100_FLOPS: f64 = 14e12;
pub const V100_MEM: f64 = 16e9;
/// V100-32GB (the paper's BigLSTM system).
pub const V100_32G_MEM: f64 = 32e9;
/// A100-80GB-class device (post-paper hardware the memory-feasibility
/// scenarios compare against).
pub const A100_FLOPS: f64 = 19.5e12;
pub const A100_80G_MEM: f64 = 80e9;
/// A100 NVLink 3 through NVSwitch: 300 GB/s per direction.
pub const A100_FABRIC_BW: f64 = 300e9;

impl HwGraph {
    pub fn new(name: &str) -> Self {
        HwGraph { name: name.to_string(), ..Default::default() }
    }

    pub fn add_compute(&mut self, name: &str, flops: f64, mem: f64) -> usize {
        self.nodes.push(HwNode {
            name: name.to_string(),
            is_compute: true,
            flops_per_sec: flops,
            mem_capacity: mem,
            node: 0,
        });
        self.nodes.len() - 1
    }

    pub fn add_router(&mut self, name: &str) -> usize {
        self.nodes.push(HwNode {
            name: name.to_string(),
            is_compute: false,
            flops_per_sec: 0.0,
            mem_capacity: 0.0,
            node: 0,
        });
        self.nodes.len() - 1
    }

    /// Assign a hardware-graph node to a chassis (multi-node builders).
    pub fn assign_node(&mut self, id: usize, node: usize) {
        self.nodes[id].node = node;
    }

    /// Chassis index of a hardware-graph node.
    pub fn node_of(&self, id: usize) -> usize {
        self.nodes[id].node
    }

    /// Compute devices grouped by chassis, ascending chassis index.
    /// Single-box graphs return one group holding every device.
    pub fn node_groups(&self) -> Vec<Vec<usize>> {
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for d in self.devices() {
            let nd = self.nodes[d].node;
            match groups.iter_mut().find(|(n, _)| *n == nd) {
                Some((_, g)) => g.push(d),
                None => groups.push((nd, vec![d])),
            }
        }
        groups.sort_by_key(|(n, _)| *n);
        groups.into_iter().map(|(_, g)| g).collect()
    }

    /// Whether the compute devices span more than one chassis.
    pub fn is_multi_node(&self) -> bool {
        self.node_groups().len() > 1
    }

    /// Does this link cross a chassis boundary?  Backbone switches sit on
    /// their own pseudo-node, so their links count as inter-node.
    pub fn link_crosses_nodes(&self, li: usize) -> bool {
        let l = &self.links[li];
        self.nodes[l.a].node != self.nodes[l.b].node
    }

    /// Effective (bandwidth, latency) of the route chosen for
    /// `bytes`-sized transfers between two nodes: store-and-forward
    /// serialisation sums per-link transfer times, so the effective
    /// bandwidth of a multi-hop path is `1 / Σ(1/B_l)` and its latency
    /// `Σ L_l` — the α-β parameters an analytic collective cost should
    /// use so it matches what [`HwGraph::transfer_time`] charges.
    pub fn path_profile(&self, from: usize, to: usize, bytes: f64)
                        -> Option<(f64, f64)> {
        if from == to {
            return None;
        }
        let (_, path) = self.route(from, to, bytes).ok()?;
        let mut inv_bw = 0.0;
        let mut lat = 0.0;
        for li in path {
            inv_bw += 1.0 / self.links[li].bandwidth;
            lat += self.links[li].latency;
        }
        if inv_bw <= 0.0 {
            return None;
        }
        Some((1.0 / inv_bw, lat))
    }

    pub fn add_link(&mut self, a: usize, b: usize, kind: LinkKind) {
        self.links.push(Link {
            a,
            b,
            bandwidth: kind.bandwidth(),
            latency: kind.latency(),
        });
    }

    pub fn add_link_custom(&mut self, a: usize, b: usize, bandwidth: f64,
                           latency: f64) {
        self.links.push(Link { a, b, bandwidth, latency });
    }

    /// Indices of compute nodes.
    pub fn devices(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].is_compute).collect()
    }

    pub fn n_devices(&self) -> usize {
        self.devices().len()
    }

    /// Adjacency list of (neighbor, link index).
    pub fn adjacency(&self) -> Vec<Vec<(usize, usize)>> {
        let mut adj = vec![Vec::new(); self.nodes.len()];
        for (li, l) in self.links.iter().enumerate() {
            adj[l.a].push((l.b, li));
            adj[l.b].push((l.a, li));
        }
        adj
    }

    /// Dijkstra shortest path (by transfer time of `bytes`) between two
    /// nodes.  Returns (total_time, link indices along the path).
    pub fn route(&self, from: usize, to: usize, bytes: f64)
                 -> Result<(f64, Vec<usize>)> {
        if from == to {
            return Ok((0.0, Vec::new()));
        }
        let adj = self.adjacency();
        let n = self.nodes.len();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev: Vec<Option<(usize, usize)>> = vec![None; n];
        dist[from] = 0.0;
        let mut visited = vec![false; n];
        for _ in 0..n {
            // O(n^2) Dijkstra: hardware graphs are tiny (≤ hundreds).
            let mut u = usize::MAX;
            let mut best = f64::INFINITY;
            for v in 0..n {
                if !visited[v] && dist[v] < best {
                    best = dist[v];
                    u = v;
                }
            }
            if u == usize::MAX {
                break;
            }
            visited[u] = true;
            if u == to {
                break;
            }
            for &(v, li) in &adj[u] {
                let l = self.links[li];
                let cost = bytes / l.bandwidth + l.latency;
                if dist[u] + cost < dist[v] {
                    dist[v] = dist[u] + cost;
                    prev[v] = Some((u, li));
                }
            }
        }
        if dist[to].is_infinite() {
            bail!("no path from {} to {} in '{}'", from, to, self.name);
        }
        let mut path = Vec::new();
        let mut cur = to;
        while cur != from {
            let (p, li) = prev[cur].unwrap();
            path.push(li);
            cur = p;
        }
        path.reverse();
        Ok((dist[to], path))
    }

    /// Transfer time of `bytes` between two devices over the best route
    /// (Eq. 11's Δe for a shortest-path C_el assignment).
    pub fn transfer_time(&self, from: usize, to: usize, bytes: f64) -> f64 {
        if from == to {
            return 0.0;
        }
        self.route(from, to, bytes).map(|(t, _)| t).unwrap_or(f64::INFINITY)
    }

    /// Smallest per-device memory capacity Mem(n) over the compute nodes
    /// — the bound every per-device footprint must fit under (infinite
    /// when the graph has no compute nodes).
    pub fn min_device_mem(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| n.is_compute)
            .map(|n| n.mem_capacity)
            .fold(f64::INFINITY, f64::min)
    }

    /// Override every compute node's memory capacity — the planner's
    /// `device_mem_gb` knob ("what if these GPUs were 16 GB parts?").
    pub fn set_device_mem(&mut self, bytes: f64) {
        for n in &mut self.nodes {
            if n.is_compute {
                n.mem_capacity = bytes;
            }
        }
    }

    /// Minimum *raw link* bandwidth along the ring of the given devices.
    /// Note this is the single slowest wire, not what a transfer
    /// achieves end to end: collective pricing uses
    /// [`HwGraph::path_profile`]'s store-and-forward effective bandwidth
    /// instead (a PCIe+IB+IB+PCIe crossing is 3 GB/s effective even
    /// though every link is 12 GB/s).  Kept as a topology diagnostic.
    pub fn ring_bottleneck_bw(&self, ring: &[usize]) -> f64 {
        let mut bw = f64::INFINITY;
        for i in 0..ring.len() {
            let a = ring[i];
            let b = ring[(i + 1) % ring.len()];
            if let Ok((_, path)) = self.route(a, b, 1.0) {
                for li in path {
                    bw = bw.min(self.links[li].bandwidth);
                }
            }
        }
        bw
    }
}

/// DGX-1-like single node: `n` V100s in an NVLink hybrid-cube-mesh.
/// For n<=4 we use the fully-connected NVLink quad of the paper's testbed.
pub fn dgx1(n_gpus: usize) -> HwGraph {
    dgx1_mem(n_gpus, V100_MEM)
}

/// DGX-1 with configurable per-GPU memory (32 GB for the BigLSTM system).
pub fn dgx1_mem(n_gpus: usize, mem: f64) -> HwGraph {
    let mut g = HwGraph::new(&format!("dgx1-{}gpu", n_gpus));
    let ids: Vec<usize> = (0..n_gpus)
        .map(|i| g.add_compute(&format!("gpu{}", i), V100_FLOPS, mem))
        .collect();
    // Fully-connected NVLink quad for the paper's 4-GPU subset, hybrid
    // cube-mesh (two quads + cross links) up to 8.
    wire_dgx1_box(&mut g, &ids);
    g
}

/// DGX-2-style single node: up to 16 V100-32GB GPUs, every GPU attached to
/// a central NVSwitch fabric at full NVLink aggregate bandwidth — uniform
/// 2-hop any-to-any connectivity, no cube-mesh asymmetry.  A scenario the
/// paper did not evaluate: the flat fabric removes the bisection bottleneck
/// that penalises >4-way MP groups on the DGX-1.
pub fn dgx2(n_gpus: usize) -> HwGraph {
    let n = n_gpus.clamp(1, 16);
    let mut g = HwGraph::new(&format!("dgx2-{}gpu", n));
    let ids: Vec<usize> = (0..n)
        .map(|i| g.add_compute(&format!("gpu{}", i), V100_FLOPS,
                               V100_32G_MEM))
        .collect();
    let switch = g.add_router("nvswitch");
    for &gpu in &ids {
        g.add_link(gpu, switch, LinkKind::NvSwitch);
    }
    g
}

/// DGX-A100-style box: up to 8 A100-80GB GPUs on an NVLink 3 / NVSwitch
/// fabric (300 GB/s per direction per GPU).  Post-paper hardware: paired
/// with the 16 GB V100 in a sweep's `device_mem_gb` axis it expresses the
/// "fits on A100, infeasible on V100" scenario family.
pub fn dgx_a100(n_gpus: usize) -> HwGraph {
    let n = n_gpus.clamp(1, 8);
    let mut g = HwGraph::new(&format!("dgx-a100-{}gpu", n));
    let ids: Vec<usize> = (0..n)
        .map(|i| g.add_compute(&format!("gpu{}", i), A100_FLOPS,
                               A100_80G_MEM))
        .collect();
    let switch = g.add_router("nvswitch");
    for &gpu in &ids {
        g.add_link_custom(gpu, switch, A100_FABRIC_BW,
                          LinkKind::NvSwitch.latency());
    }
    g
}

/// Multi-node cluster: `nodes` DGX boxes of `gpus_per_node`, joined through
/// per-node NICs and a single IB switch (the slower inter-node fabric the
/// paper cites as the SE_N killer at scale).
pub fn multi_node(nodes: usize, gpus_per_node: usize) -> HwGraph {
    let mut g = HwGraph::new(&format!("cluster-{}x{}", nodes, gpus_per_node));
    let switch = g.add_router("ib-switch");
    g.assign_node(switch, nodes); // backbone pseudo-node
    for nd in 0..nodes {
        let gpus: Vec<usize> = (0..gpus_per_node)
            .map(|i| {
                let id = g.add_compute(&format!("n{}g{}", nd, i),
                                       V100_FLOPS, V100_MEM);
                g.assign_node(id, nd);
                id
            })
            .collect();
        for i in 0..gpus_per_node {
            for j in (i + 1)..gpus_per_node {
                g.add_link(gpus[i], gpus[j], LinkKind::NvLink);
            }
        }
        let nic = g.add_router(&format!("n{}nic", nd));
        g.assign_node(nic, nd);
        for &gpu in &gpus {
            g.add_link(gpu, nic, LinkKind::Pcie);
        }
        g.add_link(nic, switch, LinkKind::Infiniband);
    }
    g
}

/// Wire one chassis of `gpus` as a DGX-1: fully-connected NVLink quad for
/// ≤ 4 GPUs, the hybrid cube-mesh (two quads + cross links) for up to 8.
fn wire_dgx1_box(g: &mut HwGraph, ids: &[usize]) {
    let n_gpus = ids.len();
    if n_gpus <= 4 {
        for i in 0..n_gpus {
            for j in (i + 1)..n_gpus {
                g.add_link(ids[i], ids[j], LinkKind::NvLink);
            }
        }
    } else {
        for q in 0..2 {
            let base = q * 4;
            for i in 0..4.min(n_gpus - base) {
                for j in (i + 1)..4.min(n_gpus - base) {
                    g.add_link(ids[base + i], ids[base + j], LinkKind::NvLink);
                }
            }
        }
        for i in 0..4 {
            if i + 4 < n_gpus {
                g.add_link(ids[i], ids[i + 4], LinkKind::NvLink);
            }
        }
    }
}

/// A pod of `nodes` chassis, each `gpus_per_node` GPUs wired as a DGX-1
/// cube-mesh, NICs reached over PCIe and joined by `backbone` links to one
/// central switch.  The shared scale-out shape behind [`dgx1_pod`] and
/// [`cloud_25gbe`].
fn pod(name: &str, nodes: usize, gpus_per_node: usize, mem: f64,
       backbone: LinkKind) -> HwGraph {
    let nodes = nodes.max(1);
    let gpus_per_node = gpus_per_node.clamp(1, 8);
    let mut g = HwGraph::new(&format!("{}-{}x{}", name, nodes,
                                      gpus_per_node));
    let switch = g.add_router("backbone-switch");
    g.assign_node(switch, nodes); // backbone pseudo-node
    for nd in 0..nodes {
        let gpus: Vec<usize> = (0..gpus_per_node)
            .map(|i| {
                let id = g.add_compute(&format!("n{}g{}", nd, i),
                                       V100_FLOPS, mem);
                g.assign_node(id, nd);
                id
            })
            .collect();
        wire_dgx1_box(&mut g, &gpus);
        let nic = g.add_router(&format!("n{}nic", nd));
        g.assign_node(nic, nd);
        for &gpu in &gpus {
            g.add_link(gpu, nic, LinkKind::Pcie);
        }
        g.add_link(nic, switch, backbone);
    }
    g
}

/// DGX-1 pod: `nodes` × 8 V100-32GB cube-mesh chassis over 100 Gb
/// InfiniBand — the scale-out system the paper's projections assume,
/// with the same 32 GB parts as the `dgx1` registry entry so every paper
/// network stays memory-feasible.
pub fn dgx1_pod(nodes: usize) -> HwGraph {
    dgx1_pod_sized(nodes, 8)
}

/// [`dgx1_pod`] with a configurable chassis width (1–8 GPUs) — the
/// `[cluster] gpus_per_node` knob.
pub fn dgx1_pod_sized(nodes: usize, gpus_per_node: usize) -> HwGraph {
    pod("dgx1-pod", nodes, gpus_per_node, V100_32G_MEM,
        LinkKind::Infiniband)
}

/// Cloud GPU cluster: `nodes` × 8 V100-16GB instances (p3.16xlarge-class,
/// NVLink inside the instance) joined by 25 GbE — the slowest inter-node
/// fabric in the registry, where collective choice matters most.
pub fn cloud_25gbe(nodes: usize) -> HwGraph {
    cloud_25gbe_sized(nodes, 8)
}

/// [`cloud_25gbe`] with a configurable instance width (1–8 GPUs) — the
/// `[cluster] gpus_per_node` knob.
pub fn cloud_25gbe_sized(nodes: usize, gpus_per_node: usize) -> HwGraph {
    pod("cloud-25gbe", nodes, gpus_per_node, V100_MEM,
        LinkKind::Ethernet25)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgx1_quad_fully_connected() {
        let g = dgx1(4);
        assert_eq!(g.n_devices(), 4);
        assert_eq!(g.links.len(), 6);
        // Direct NVLink between any pair.
        let (t, path) = g.route(0, 3, 1e6).unwrap();
        assert_eq!(path.len(), 1);
        assert!((t - (1e6 / 25e9 + 1.3e-6)).abs() < 1e-12);
    }

    #[test]
    fn dgx1_8gpu_cube_mesh_connected() {
        let g = dgx1(8);
        assert_eq!(g.n_devices(), 8);
        for i in 0..8 {
            for j in 0..8 {
                assert!(g.transfer_time(i, j, 1e6).is_finite());
            }
        }
        // Cross-quad non-paired GPUs need 2 hops.
        let (_, path) = g.route(0, 5, 1e6).unwrap();
        assert!(path.len() >= 2);
    }

    #[test]
    fn dgx2_uniform_two_hop_fabric() {
        let g = dgx2(16);
        assert_eq!(g.n_devices(), 16);
        assert_eq!(g.links.len(), 16, "one fabric port per GPU");
        // Any-to-any: exactly 2 hops, identical cost for every pair.
        let t01 = g.transfer_time(0, 1, 64e6);
        for i in 0..16usize {
            for j in 0..16usize {
                if i != j {
                    let t = g.transfer_time(i, j, 64e6);
                    assert!((t - t01).abs() < 1e-12,
                            "fabric must be uniform: {t} vs {t01}");
                    let (_, path) = g.route(i, j, 64e6).unwrap();
                    assert_eq!(path.len(), 2);
                }
            }
        }
        // Faster than the DGX-1 NVLink mesh for large transfers.
        let d1 = dgx1(8);
        assert!(t01 < d1.transfer_time(0, 1, 64e6));
        // Ring all-reduce bottleneck is the fabric port, not a mesh link.
        let bw = g.ring_bottleneck_bw(&g.devices());
        assert!((bw - LinkKind::NvSwitch.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn dgx2_clamps_device_count() {
        assert_eq!(dgx2(64).n_devices(), 16);
        assert_eq!(dgx2(0).n_devices(), 1);
        // 32 GB parts, as on the real machine.
        let g = dgx2(2);
        assert!((g.nodes[0].mem_capacity - V100_32G_MEM).abs() < 1.0);
    }

    #[test]
    fn dgx_a100_faster_fabric_and_bigger_memory() {
        let g = dgx_a100(8);
        assert_eq!(g.n_devices(), 8);
        assert!((g.min_device_mem() - A100_80G_MEM).abs() < 1.0);
        // NVLink 3 fabric beats the DGX-2 NVSwitch for large transfers.
        let d2 = dgx2(8);
        assert!(g.transfer_time(0, 1, 256e6)
                    < d2.transfer_time(0, 1, 256e6));
        assert_eq!(dgx_a100(64).n_devices(), 8, "clamped to the box");
    }

    #[test]
    fn device_mem_surfaces_and_overrides() {
        let mut g = dgx1(4); // 16 GB parts
        assert!((g.min_device_mem() - V100_MEM).abs() < 1.0);
        g.set_device_mem(80e9);
        assert!((g.min_device_mem() - 80e9).abs() < 1.0);
        for d in g.devices() {
            assert!((g.nodes[d].mem_capacity - 80e9).abs() < 1.0);
        }
        // Routers untouched; empty graphs report an infinite bound.
        let mut h = HwGraph::new("r");
        h.add_router("sw");
        h.set_device_mem(1.0);
        assert_eq!(h.nodes[0].mem_capacity, 0.0);
        assert!(h.min_device_mem().is_infinite());
    }

    #[test]
    fn multi_node_routes_through_switch() {
        let g = multi_node(2, 4);
        assert_eq!(g.n_devices(), 8);
        let devs = g.devices();
        let (t_intra, p_intra) = g.route(devs[0], devs[1], 1e6).unwrap();
        let (t_inter, p_inter) = g.route(devs[0], devs[4], 1e6).unwrap();
        assert!(p_intra.len() < p_inter.len());
        assert!(t_intra < t_inter, "intra {t_intra} inter {t_inter}");
    }

    #[test]
    fn self_transfer_free() {
        let g = dgx1(2);
        assert_eq!(g.transfer_time(0, 0, 1e9), 0.0);
    }

    #[test]
    fn no_path_errors() {
        let mut g = HwGraph::new("split");
        g.add_compute("a", 1.0, 1.0);
        g.add_compute("b", 1.0, 1.0);
        assert!(g.route(0, 1, 1.0).is_err());
    }

    #[test]
    fn ring_bottleneck_multi_node_is_ib() {
        let g = multi_node(2, 2);
        let devs = g.devices();
        let bw = g.ring_bottleneck_bw(&devs);
        assert!((bw - LinkKind::Infiniband.bandwidth()).abs() < 1.0);
        let g1 = dgx1(4);
        assert!((g1.ring_bottleneck_bw(&g1.devices())
                 - LinkKind::NvLink.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn node_membership_classifies_links() {
        let g = multi_node(2, 4);
        let groups = g.node_groups();
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|grp| grp.len() == 4));
        assert!(g.is_multi_node());
        for grp in &groups {
            for &d in grp {
                assert_eq!(g.node_of(d), g.node_of(grp[0]));
            }
        }
        assert_ne!(g.node_of(groups[0][0]), g.node_of(groups[1][0]));
        // NVLink links stay intra-node; NIC→switch links cross.
        let mut intra = 0;
        let mut inter = 0;
        for li in 0..g.links.len() {
            if g.link_crosses_nodes(li) {
                inter += 1;
            } else {
                intra += 1;
            }
        }
        assert_eq!(intra, 2 * 6, "two NVLink quads");
        assert_eq!(inter, 2 * 4 + 2, "PCIe GPU→NIC + IB NIC→switch");
        // Single-box graphs are one group.
        let d = dgx1(8);
        assert!(!d.is_multi_node());
        assert_eq!(d.node_groups(), vec![d.devices()]);
    }

    #[test]
    fn path_profile_matches_transfer_time() {
        let g = multi_node(2, 4);
        let devs = g.devices();
        // Intra: one direct NVLink hop.
        let (bw, lat) = g.path_profile(devs[0], devs[1], 64e6).unwrap();
        assert!((bw - 25e9).abs() < 1.0);
        assert!((lat - 1.3e-6).abs() < 1e-12);
        // Inter: PCIe + IB + IB + PCIe store-and-forward → 3 GB/s, 9 µs.
        let (bw, lat) = g.path_profile(devs[0], devs[4], 64e6).unwrap();
        assert!((bw - 3e9).abs() < 1e3, "effective inter bw {bw}");
        assert!((lat - 9e-6).abs() < 1e-12);
        // The profile reproduces transfer_time exactly.
        let bytes = 64e6;
        let t = g.transfer_time(devs[0], devs[4], bytes);
        assert!((t - (bytes / bw + lat)).abs() < 1e-12);
        assert!(g.path_profile(devs[0], devs[0], 1e6).is_none());
    }

    #[test]
    fn dgx1_pod_is_cube_mesh_chassis_over_ib() {
        let g = dgx1_pod(4);
        assert_eq!(g.n_devices(), 32);
        assert_eq!(g.node_groups().len(), 4);
        assert!((g.min_device_mem() - V100_32G_MEM).abs() < 1.0,
                "pod uses the 32 GB parts of the dgx1 registry entry");
        let devs = g.devices();
        // Intra chassis: NVLink; across chassis: through NIC + IB.
        let (bw_in, _) = g.path_profile(devs[0], devs[1], 64e6).unwrap();
        assert!((bw_in - 25e9).abs() < 1.0);
        let (bw_out, _) = g.path_profile(devs[0], devs[8], 64e6).unwrap();
        assert!(bw_out < 4e9, "inter-chassis must be IB-limited: {bw_out}");
        // Same cube-mesh inside a chassis as the single dgx1 box.
        let box8 = dgx1(8);
        let intra_links = g
            .links
            .iter()
            .filter(|l| g.nodes[l.a].node == 0 && g.nodes[l.b].node == 0
                        && g.nodes[l.a].is_compute
                        && g.nodes[l.b].is_compute)
            .count();
        assert_eq!(intra_links, box8.links.len());
    }

    #[test]
    fn cloud_25gbe_is_the_slowest_backbone() {
        let g = cloud_25gbe(2);
        assert_eq!(g.n_devices(), 16);
        assert!((g.min_device_mem() - V100_MEM).abs() < 1.0);
        let devs = g.devices();
        let (bw, lat) = g.path_profile(devs[0], devs[8], 64e6).unwrap();
        // PCIe + 25GbE + 25GbE + PCIe store-and-forward ≈ 1.24 GB/s.
        assert!(bw < 1.5e9, "25 GbE backbone must dominate: {bw}");
        assert!(lat > 40e-6, "TCP-class latencies: {lat}");
        let ib = dgx1_pod(2);
        let (ib_bw, _) = ib
            .path_profile(ib.devices()[0], ib.devices()[8], 64e6)
            .unwrap();
        assert!(bw < ib_bw, "25 GbE slower than the IB pod");
    }

    #[test]
    fn route_prefers_faster_path() {
        let mut g = HwGraph::new("tri");
        let a = g.add_compute("a", 1.0, 1.0);
        let b = g.add_compute("b", 1.0, 1.0);
        let r = g.add_router("r");
        // Slow direct link vs fast 2-hop via router.
        g.add_link_custom(a, b, 1e9, 1e-6);
        g.add_link_custom(a, r, 100e9, 1e-7);
        g.add_link_custom(r, b, 100e9, 1e-7);
        let (_, path) = g.route(a, b, 100e6).unwrap();
        assert_eq!(path.len(), 2, "should take the fast 2-hop route");
    }
}
